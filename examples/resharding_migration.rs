//! Heterogeneous-cluster migration (§4.2.1d): move a trained model from a
//! 10-shard master cluster to a 20-shard one (scale-out) and then to a
//! 4-shard one (scale-in), with automatic data-slice remapping, verifying
//! bit-exact parameter state at every hop.
//!
//!     cargo run --release --example resharding_migration

use std::sync::Arc;
use std::time::Instant;

use weips::config::{ModelKind, ModelSpec};
use weips::proto::{SparsePull, SparsePush};
use weips::runtime::Engine;
use weips::server::master::MasterShard;
use weips::sync::Router;
use weips::util::clock::SystemClock;

fn build(shards: u32, spec: &ModelSpec) -> Vec<Arc<MasterShard>> {
    let clock = Arc::new(SystemClock);
    (0..shards)
        .map(|i| Arc::new(MasterShard::new(i, spec.clone(), None, 1, clock.clone()).unwrap()))
        .collect()
}

fn migrate(src: &[Arc<MasterShard>], dst: &[Arc<MasterShard>]) -> (usize, std::time::Duration) {
    let router = Router::new(dst.len() as u32);
    let t0 = Instant::now();
    let mut moved = 0;
    for s in src {
        let snapshot = s.snapshot();
        for (di, d) in dst.iter().enumerate() {
            moved += d.absorb(&snapshot, &router, di as u32).unwrap();
        }
    }
    (moved, t0.elapsed())
}

fn spot_check(a: &[Arc<MasterShard>], b: &[Arc<MasterShard>], ids: &[u64]) -> bool {
    let ra = Router::new(a.len() as u32);
    let rb = Router::new(b.len() as u32);
    ids.iter().all(|&id| {
        let pull = |cluster: &[Arc<MasterShard>], router: &Router| {
            cluster[router.shard_of(id) as usize]
                .sparse_pull(&SparsePull {
                    model: "ctr".into(),
                    table: "w".into(),
                    ids: vec![id],
                    slot: "*".into(),
                })
                .unwrap()
                .values
        };
        pull(a, &ra) == pull(b, &rb)
    })
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let engine = Engine::load(weips::runtime::default_artifacts_dir())?;
    let spec = ModelSpec::derive("ctr", ModelKind::Fm, engine.config());

    // Train 200k rows into a 10-shard cluster.
    println!("== populate source cluster (10 shards) ==");
    let src = build(10, &spec);
    let router10 = Router::new(10);
    let n_ids = 200_000u64;
    let t0 = Instant::now();
    for base in (0..n_ids).step_by(1024) {
        for shard_ids in chunked_by_shard(&router10, base, 1024.min(n_ids - base)) {
            let (shard, ids) = shard_ids;
            if ids.is_empty() {
                continue;
            }
            let grads = vec![0.8f32; ids.len()];
            src[shard as usize]
                .sparse_push(&SparsePush {
                    model: "ctr".into(),
                    table: "w".into(),
                    ids,
                    grads,
                })
                .unwrap();
        }
    }
    let total: usize = src.iter().map(|m| m.total_rows()).sum();
    println!("  {} rows across 10 shards in {:?}", total, t0.elapsed());
    println!(
        "  per-shard: {:?}",
        src.iter().map(|m| m.total_rows()).collect::<Vec<_>>()
    );

    // Scale out 10 -> 20.
    println!("\n== migrate 10 -> 20 shards (scale-out) ==");
    let dst20 = build(20, &spec);
    let (moved, took) = migrate(&src, &dst20);
    println!("  moved {moved} rows in {took:?}");
    assert_eq!(moved, total);
    let sample_ids: Vec<u64> = (0..n_ids).step_by(997).collect();
    println!("  value spot-check: {}", spot_check(&src, &dst20, &sample_ids));

    // Scale in 20 -> 4.
    println!("\n== migrate 20 -> 4 shards (scale-in) ==");
    let dst4 = build(4, &spec);
    let (moved2, took2) = migrate(&dst20, &dst4);
    println!("  moved {moved2} rows in {took2:?}");
    assert_eq!(moved2, total);
    println!("  value spot-check: {}", spot_check(&src, &dst4, &sample_ids));
    println!(
        "  per-shard after scale-in: {:?}",
        dst4.iter().map(|m| m.total_rows()).collect::<Vec<_>>()
    );
    println!("\nmigration drill complete — every id remapped, state bit-identical.");
    Ok(())
}

fn chunked_by_shard(router: &Router, base: u64, count: u64) -> Vec<(u32, Vec<u64>)> {
    let mut buckets: Vec<(u32, Vec<u64>)> =
        (0..router.shards()).map(|s| (s, Vec::new())).collect();
    for id in base..base + count {
        buckets[router.shard_of(id) as usize].1.push(id);
    }
    buckets
}
