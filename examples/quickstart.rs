//! Quickstart: bring up a full in-process WeiPS cluster, train an FM CTR
//! model on the synthetic feed, stream updates to the serving replicas,
//! and issue predictions against the freshly synced slaves.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` (the AOT-compiled model graphs) first.

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::sample::WorkloadConfig;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Assemble the cluster: 4 master shards (training-facing), 2 slave
    //    shards x 2 replicas (serving-facing), streaming sync between them.
    let cluster = LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Fm,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 2,
            queue_partitions: 4,
            gather_mode: GatherMode::Threshold(2048),
            ..Default::default()
        },
        workload: WorkloadConfig { ids_per_field: 5_000, seed: 42, ..Default::default() },
        ..Default::default()
    })?;

    // 2. Online training: every step pulls weights from the masters, runs
    //    the AOT-compiled train graph through PJRT, pushes gradients back,
    //    and drives the sync pipeline toward the slaves.
    println!("training 120 steps of {} samples...", cluster.spec.batch_train);
    for step in 1..=120u32 {
        let loss = cluster.train_step()?;
        cluster.sync_tick()?;
        if step % 20 == 0 {
            let snap = cluster.monitor.snapshot();
            println!(
                "  step {step:>4}: loss={loss:.4}  streaming-auc={:.4}  logloss={:.4}",
                snap.window_auc, snap.logloss
            );
        }
    }

    // 3. Make sure every update has reached the serving side, then take a
    //    checkpoint (cold backup for the masters).
    cluster.flush_sync()?;
    let version = cluster.checkpoint()?;
    println!("checkpoint v{version} written; sync lag = {}", cluster.sync_lag());

    // 4. Serve: requests hit slave replicas through the load balancer and
    //    run the AOT predict graph.
    let requests = cluster.serving_requests(16);
    let preds = cluster.predict(&requests)?;
    println!("served {} predictions:", preds.len());
    for (i, p) in preds.iter().take(8).enumerate() {
        println!("  request {i}: ctr = {p:.4}");
    }

    let snap = cluster.monitor.snapshot();
    println!(
        "\ndone: {} samples trained, cumulative auc {:.4}, window auc {:.4}",
        snap.samples, snap.auc, snap.window_auc
    );
    Ok(())
}
