//! Fault-tolerance drill (§4.2): walks through every failure mode WeiPS
//! guards against, with live measurements.
//!
//!   1. hot backup    — kill slave replicas, serving fails over instantly;
//!   2. slave recovery — full sync + incremental queue replay;
//!   3. cold backup   — crash a master shard, partial recovery from
//!                      checkpoint + that shard's queue partition;
//!   4. domino        — corrupt the model, smoothed trigger fires, version
//!                      rolls back, metric recovers.
//!
//!     cargo run --release --example failover_drill

use std::time::Instant;

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::downgrade::SwitchStrategy;
use weips::sample::WorkloadConfig;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut cluster = LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 3,
            queue_partitions: 4,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        workload: WorkloadConfig { ids_per_field: 1_000, zipf_s: 1.3, seed: 99, ..Default::default() },
        trigger_threshold: 0.55,
        trigger_smooth: 3,
        switch_strategy: SwitchStrategy::LatestStable,
        ..Default::default()
    })?;

    println!("== warmup: 150 training steps ==");
    for _ in 0..150 {
        cluster.train_step()?;
        cluster.sync_tick()?;
    }
    cluster.flush_sync()?;
    let stable = cluster.checkpoint()?;
    let healthy = cluster.monitor.snapshot();
    println!("healthy: window auc {:.4}, checkpoint v{stable}\n", healthy.window_auc);

    // -- drill 1: hot backup ---------------------------------------------------
    println!("== drill 1: slave replica failover (hot backup) ==");
    let reqs = cluster.serving_requests(8);
    let before = cluster.predict(&reqs)?;
    cluster.kill_slave(0, 0);
    cluster.kill_slave(0, 1); // two of three replicas down
    let t0 = Instant::now();
    let after = cluster.predict(&reqs)?;
    println!(
        "  2/3 replicas of shard 0 killed; serving continued in {:?} (predictions identical: {})",
        t0.elapsed(),
        before
            .iter()
            .zip(&after)
            .all(|(a, b)| (a - b).abs() < 1e-6)
    );

    // -- drill 2: slave recovery ------------------------------------------------
    println!("== drill 2: replica recovery (full sync + replay) ==");
    for _ in 0..20 {
        cluster.train_step()?; // updates the dead replicas miss
        cluster.sync_tick()?;
    }
    cluster.flush_sync()?;
    let t0 = Instant::now();
    cluster.recover_slave(0, 0)?;
    cluster.recover_slave(0, 1)?;
    cluster.flush_sync()?;
    let healthy_rows = cluster.slaves[0][2].total_rows();
    println!(
        "  recovered 2 replicas in {:?}; rows match healthy peer: {} == {}",
        t0.elapsed(),
        cluster.slaves[0][0].total_rows(),
        healthy_rows
    );

    // -- drill 3: master partial recovery ----------------------------------------
    println!("== drill 3: master shard crash + partial recovery (cold backup) ==");
    cluster.flush_sync()?;
    cluster.checkpoint()?;
    for _ in 0..15 {
        cluster.train_step()?; // post-checkpoint increments
        cluster.sync_tick()?;
    }
    cluster.flush_sync()?;
    let victim = 1usize;
    let rows_before = cluster.crash_master(victim)?;
    let t0 = Instant::now();
    let recovered_version = cluster.recover_master(victim)?;
    println!(
        "  shard {victim} crashed ({rows_before} rows) -> recovered from v{recovered_version} + queue replay in {:?}; rows now {}",
        t0.elapsed(),
        cluster.masters[victim].total_rows()
    );
    println!(
        "  other shards untouched: {:?}",
        cluster.masters.iter().map(|m| m.total_rows()).collect::<Vec<_>>()
    );

    // -- drill 4: domino downgrade -------------------------------------------------
    println!("== drill 4: corruption -> smoothed trigger -> domino downgrade ==");
    cluster.flush_sync()?;
    cluster.checkpoint()?;
    cluster.corrupt_model()?;
    cluster.flush_sync()?;
    let corrupt_t = Instant::now();
    let mut fired_at = None;
    for step in 0..80 {
        cluster.train_step()?;
        cluster.sync_tick()?;
        if let Some(plan) = cluster.control_tick()? {
            fired_at = Some((step, plan));
            break;
        }
    }
    match fired_at {
        Some((step, plan)) => {
            println!(
                "  trigger fired after {step} batches ({:?}); rolled back v{} -> v{} (metric at target: {:.4})",
                corrupt_t.elapsed(),
                plan.from_version,
                plan.target_version,
                plan.target_metric
            );
            // Post-rollback: keep training, metric recovers.
            for _ in 0..60 {
                cluster.train_step()?;
                cluster.sync_tick()?;
            }
            let recovered = cluster.monitor.snapshot();
            println!("  window auc after recovery: {:.4}", recovered.window_auc);
        }
        None => println!("  !! trigger did not fire (unexpected)"),
    }
    println!("\ndrill complete.");
    Ok(())
}
