//! End-to-end online-learning driver (experiment E8 + the DESIGN.md
//! mandated full-system validation).
//!
//! Trains a DeepFM CTR model online against a drifting synthetic feed
//! through the complete WeiPS stack — exposure/feedback joining with
//! delayed clicks, sharded pull/push, server-side FTRL (AOT Pallas kernel
//! on the hot path), streaming synchronization to serving replicas,
//! periodic checkpoints — and compares **fused online serving** (WeiPS)
//! against a **frozen snapshot** baseline (the traditional offline-export
//! deployment) on the same future request stream while the online model
//! keeps learning. Logs the loss curve; results go in EXPERIMENTS.md.
//!
//!     cargo run --release --example online_ctr_e2e [steps] [ids_per_field]

use std::collections::VecDeque;
use std::sync::Arc;

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::joiner::{Exposure, Feedback, Joiner};
use weips::monitor::StreamingAuc;
use weips::sample::{Sample, Workload, WorkloadConfig};

const DRIFT: f64 = 0.02; // rad/s of ground-truth rotation
const CLICK_DELAY_MS: u64 = 300;
const JOIN_WINDOW_MS: u64 = 2_000;
const MS_PER_EXPOSURE: u64 = 4;

/// Streams exposures through the joiner with realistically delayed clicks,
/// producing labeled samples in event-time order.
struct JoinedFeed {
    feed: Workload,
    joiner: Joiner,
    pending_clicks: VecDeque<(u64, u64)>, // (deliver_at_ms, exposure_id)
    ready: VecDeque<Sample>,
    sim_ms: u64,
    next_exposure: u64,
}

impl JoinedFeed {
    fn new(cfg: WorkloadConfig) -> JoinedFeed {
        JoinedFeed {
            feed: Workload::new(cfg),
            joiner: Joiner::new(JOIN_WINDOW_MS),
            pending_clicks: VecDeque::new(),
            ready: VecDeque::new(),
            sim_ms: 0,
            next_exposure: 0,
        }
    }

    fn next_batch(&mut self, n: usize) -> Vec<Sample> {
        while self.ready.len() < n {
            self.sim_ms += MS_PER_EXPOSURE;
            let s = self.feed.sample(self.sim_ms);
            self.next_exposure += 1;
            self.joiner.on_exposure(Exposure {
                exposure_id: self.next_exposure,
                ts_ms: self.sim_ms,
                ids: s.ids.clone(),
            });
            if s.label > 0.5 {
                self.pending_clicks
                    .push_back((self.sim_ms + CLICK_DELAY_MS, self.next_exposure));
            }
            while let Some(&(at, exp)) = self.pending_clicks.front() {
                if at > self.sim_ms {
                    break;
                }
                self.pending_clicks.pop_front();
                if let Some(joined) =
                    self.joiner.on_feedback(Feedback { exposure_id: exp, ts_ms: at })
                {
                    self.ready.push_back(joined);
                }
            }
            self.ready.extend(self.joiner.advance(self.sim_ms));
        }
        self.ready.drain(..n).collect()
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Exercise the AOT Pallas FTRL path end-to-end (the TPU-representative
    // architecture). On CPU-interpret PJRT the scalar loop is faster below
    // a full kernel block, so the default crossover would bypass it — see
    // EXPERIMENTS.md §Perf for the measured tradeoff.
    if std::env::var("WEIPS_BATCHED_MIN_ROWS").is_err() {
        // Post-dedup a 256-sample batch leaves ~400-600 unique rows per
        // shard; 256 keeps them on the kernel path.
        std::env::set_var("WEIPS_BATCHED_MIN_ROWS", "256");
    }
    let args: Vec<String> = std::env::args().collect();
    // Defaults chosen so the freshness comparison is meaningful: the
    // training epoch stays under half a drift period (longer runs wrap the
    // ground-truth phase back toward the frozen snapshot), and the id
    // universe is small enough that per-id weights actually train.
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let ids_per_field: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(5_000);

    let workload_cfg = WorkloadConfig {
        ids_per_field,
        drift_per_sec: DRIFT,
        seed: 2026,
        ..Default::default()
    };
    let cluster = Arc::new(LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::DeepFm,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 2,
            queue_partitions: 4,
            gather_mode: GatherMode::Threshold(4096),
            ckpt_interval_ms: 15_000,
            ..Default::default()
        },
        workload: workload_cfg.clone(),
        ..Default::default()
    })?);
    let spec = cluster.spec.clone();
    println!(
        "model: DeepFM F={} K={} H={} — id universe {} (≈{:.1}M sparse params at saturation) + {} dense",
        spec.fields,
        spec.dim,
        spec.hidden,
        ids_per_field * spec.fields as u64,
        (ids_per_field * spec.fields as u64 * (1 + spec.dim as u64)) as f64 / 1e6,
        spec.dense.iter().map(|d| d.len).sum::<usize>(),
    );

    let mut feed = JoinedFeed::new(WorkloadConfig { fields: spec.fields, ..workload_cfg.clone() });

    println!("\n== phase 1: online training ({steps} steps) ==");
    println!(
        "{:>6} {:>9} {:>9} {:>9} {:>9} {:>7} {:>10}",
        "step", "loss", "auc", "win_auc", "logloss", "ctr", "rows"
    );
    let mut losses = Vec::new();
    let mut frozen_version = None;
    for step in 0..steps {
        let batch = feed.next_batch(spec.batch_train);
        let out = cluster.trainer.train_batch(&batch)?;
        losses.push(out.loss);
        cluster.sync_tick()?;
        if step % 10 == 0 {
            cluster.control_tick()?;
        }
        // Freeze a snapshot 25% in: the offline-deployment baseline.
        if step == steps / 4 && frozen_version.is_none() {
            cluster.flush_sync()?;
            frozen_version = Some(cluster.checkpoint()?);
            println!("  [frozen-baseline snapshot taken at step {step}]");
        }
        if step % (steps / 10).max(1) == 0 {
            let snap = cluster.monitor.snapshot();
            let rows: usize = cluster.masters.iter().map(|m| m.total_rows()).sum();
            let ctr: f32 =
                batch.iter().map(|s| s.label).sum::<f32>() / batch.len() as f32;
            println!(
                "{:>6} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>7.3} {:>10}",
                step, out.loss, snap.auc, snap.window_auc, snap.logloss, ctr, rows
            );
        }
    }
    cluster.flush_sync()?;
    let final_version = cluster.checkpoint()?;
    let k = losses.len().min(20);
    let first_avg: f32 = losses[..k].iter().sum::<f32>() / k as f32;
    let last_avg: f32 = losses[losses.len() - k..].iter().sum::<f32>() / k as f32;
    println!(
        "loss curve: first-{k} avg {first_avg:.4} -> last-{k} avg {last_avg:.4} (frozen v{}, final v{final_version})",
        frozen_version.unwrap()
    );

    // == phase 2: freshness comparison (E8) ==================================
    // The frozen baseline serves the 25%-mark snapshot and never updates;
    // the fused cluster keeps training online. Both are evaluated on the
    // same future traffic as the ground truth keeps drifting.
    println!("\n== phase 2: fused-online vs frozen-snapshot serving (drift {DRIFT} rad/s) ==");
    let frozen = LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::DeepFm,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 2,
            queue_partitions: 4,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        workload: workload_cfg.clone(),
        ..Default::default()
    })?;
    for (i, m) in frozen.masters.iter().enumerate() {
        // Chain-aware restore: the frozen version may be an incremental
        // delta tip (base + delta chunks), not a monolithic snapshot.
        m.restore_chain(&cluster.store, frozen_version.unwrap(), i)?;
        for shard in &frozen.slaves {
            for replica in shard {
                replica.full_sync_from_snapshot(&m.snapshot())?;
            }
        }
    }

    let mut fused_auc = StreamingAuc::new();
    let mut frozen_auc = StreamingAuc::new();
    println!("{:>6} {:>12} {:>12}", "chunk", "fused_auc", "frozen_auc");
    for chunk in 0..40u64 {
        // Evaluate both on the next slice of (future) traffic.
        let eval: Vec<Sample> = feed.next_batch(64);
        let reqs: Vec<Vec<u64>> = eval.iter().map(|s| s.ids.clone()).collect();
        let fused_preds = cluster.predict(&reqs)?;
        let frozen_preds = frozen.predict(&reqs)?;
        for ((s, fp), zp) in eval.iter().zip(&fused_preds).zip(&frozen_preds) {
            fused_auc.add(*fp, s.label);
            frozen_auc.add(*zp, s.label);
        }
        // The fused system keeps learning on the stream it just served
        // (including those very samples, via progressive validation).
        for _ in 0..2 {
            let batch = feed.next_batch(spec.batch_train);
            cluster.trainer.train_batch(&batch)?;
            cluster.sync_tick()?;
        }
        if chunk % 10 == 9 {
            println!("{:>6} {:>12.4} {:>12.4}", chunk + 1, fused_auc.auc(), frozen_auc.auc());
        }
    }
    println!(
        "\n  fused online serving : auc = {:.4}\n  frozen snapshot      : auc = {:.4}\n  freshness gain       : {:+.4} auc over {} eval samples",
        fused_auc.auc(),
        frozen_auc.auc(),
        fused_auc.auc() - frozen_auc.auc(),
        fused_auc.count()
    );

    println!(
        "\njoiner: {} exposures, {} positives joined, {} expired negative, {} orphans",
        feed.joiner.stats.exposures,
        feed.joiner.stats.joined_positive,
        feed.joiner.stats.expired_negative,
        feed.joiner.stats.orphan_feedback
    );
    let kernel_rows: u64 = cluster
        .masters
        .iter()
        .map(|m| m.metrics.batched_kernel_rows.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    let scalar_rows: u64 = cluster
        .masters
        .iter()
        .map(|m| m.metrics.scalar_rows.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    println!("ftrl path: {kernel_rows} rows via AOT Pallas kernel, {scalar_rows} scalar");
    Ok(())
}
