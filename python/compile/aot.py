"""AOT pipeline: lower every L2/L1 module to HLO text + write the manifest.

Usage (from the ``python/`` directory, as the Makefile does)::

    python -m compile.aot --out-dir ../artifacts

Emits one ``<module>.hlo.txt`` per (model, stage) variant plus
``manifest.json`` describing input/output shapes, dtypes and the model
hyper-parameters — the Rust runtime (``rust/src/runtime``) loads executables
and validates its buffers against this manifest.

Interchange format is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ftrl
from .kernels.ref import ftrl_weight_ref

# FTRL hyper-parameters baked into the AOT kernels. The Rust side reads
# these from the manifest so both paths agree in structure (and to float
# tolerance in value). Tuned for the synthetic CTR workload scale: a large
# l1 would keep most of the small id universe in the dead zone for the
# few-hundred-step experiment horizons.
FTRL_HYPERS = {"alpha": 0.1, "beta": 1.0, "l1": 0.01, "l2": 1.0}

_DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "s32", jnp.uint32.dtype: "u32"}


def to_hlo_text(lowered) -> str:
    """Convert a jax-lowered computation to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_entry(s) -> dict:
    return {"shape": list(s.shape), "dtype": _DTYPE_NAMES.get(s.dtype, str(s.dtype))}


def lower_module(fn, arg_specs):
    """Lower ``fn(*arg_specs)``; return (hlo_text, input_meta, output_meta)."""
    lowered = jax.jit(fn).lower(*arg_specs)
    out_shapes = jax.eval_shape(fn, *arg_specs)
    # Normalize: functions return tuples; eval_shape mirrors that.
    if not isinstance(out_shapes, (tuple, list)):
        out_shapes = (out_shapes,)
    inputs = [_shape_entry(s) for s in arg_specs]
    outputs = [_shape_entry(s) for s in jax.tree_util.tree_leaves(out_shapes)]
    return to_hlo_text(lowered), inputs, outputs


def ftrl_modules(block_rows, dims):
    """Standalone optimizer/transform modules applied by the master/slave.

    ``ftrl_update_d{D}``: (g, z, n) -> (z', n', w')   [master push path]
    ``ftrl_weight_d{D}``: (z, n) -> (w,)              [slave transform path]
    """
    f32 = jnp.float32
    mods = {}
    for d in dims:
        spec = jax.ShapeDtypeStruct((block_rows, d), f32)

        def upd(g, z, n, _d=d):
            return ftrl.ftrl_update(g, z, n, **FTRL_HYPERS)

        def wgt(z, n, _d=d):
            return (ftrl_weight_ref(z, n, **FTRL_HYPERS),)

        mods[f"ftrl_update_d{d}"] = (upd, [spec, spec, spec])
        mods[f"ftrl_weight_d{d}"] = (wgt, [spec, spec])
    return mods


def build(out_dir, batch_train, batch_predict, fields, dim, hidden, block_rows):
    os.makedirs(out_dir, exist_ok=True)
    modules = {}
    modules.update(M.model_specs(batch_train, batch_predict, fields, dim, hidden))
    modules.update(ftrl_modules(block_rows, dims=sorted({1, dim})))

    manifest = {
        "version": 1,
        "config": {
            "batch_train": batch_train,
            "batch_predict": batch_predict,
            "fields": fields,
            "dim": dim,
            "hidden": hidden,
            "ftrl_block_rows": block_rows,
            "ftrl": FTRL_HYPERS,
        },
        "modules": {},
    }

    for name, (fn, specs) in sorted(modules.items()):
        hlo, inputs, outputs = lower_module(fn, specs)
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(hlo)
        manifest["modules"][name] = {"path": path, "inputs": inputs, "outputs": outputs}
        print(f"  lowered {name}: {len(hlo)} chars, {len(inputs)} in / {len(outputs)} out")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(modules)} modules + manifest to {out_dir}")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--batch-train", type=int, default=int(os.environ.get("WEIPS_BATCH_TRAIN", 256)))
    p.add_argument("--batch-predict", type=int, default=int(os.environ.get("WEIPS_BATCH_PREDICT", 16)))
    p.add_argument("--fields", type=int, default=int(os.environ.get("WEIPS_FIELDS", 16)))
    p.add_argument("--dim", type=int, default=int(os.environ.get("WEIPS_DIM", 8)))
    p.add_argument("--hidden", type=int, default=int(os.environ.get("WEIPS_HIDDEN", 64)))
    p.add_argument("--ftrl-block-rows", type=int, default=int(os.environ.get("WEIPS_FTRL_BLOCK", 8192)))
    args = p.parse_args()
    build(
        args.out_dir,
        args.batch_train,
        args.batch_predict,
        args.fields,
        args.dim,
        args.hidden,
        args.ftrl_block_rows,
    )


if __name__ == "__main__":
    main()
