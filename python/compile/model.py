"""L2: JAX model definitions (forward / loss / grads) for the WeiPS workers.

The models are the CTR family the paper names (§4.1.2): LR-FTRL, FM-FTRL
and a DeepFM-style DNN. Crucially for a parameter server, the *embedding
lookup is not part of the graph*: the Rust trainer pulls the rows for the
ids in the batch from the master shards and feeds the already-gathered
per-field matrices as graph inputs; the graph returns gradients w.r.t.
those gathered inputs and Rust scatter-adds them back into push requests.
This keeps every AOT module shape-static.

``train_step`` outputs follow the paper's progressive-validation design
(§4.3.1): the returned predictions are computed from the *pre-update*
parameters — they are the model-metrics monitoring signal — and the same
samples then produce the gradients, so no sample is lost to evaluation.

All public functions are pure and jit-lowerable; ``aot.py`` lowers each
(model, batch) variant once to HLO text.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import fm_interaction


def _sigmoid(x):
    return jax.nn.sigmoid(x)


def _bce_loss(logit, label):
    """Mean binary cross-entropy from logits (numerically stable).

    ``softplus(x) - x*y``: softplus carries a smooth custom JVP, so the
    gradient is exactly ``sigmoid(x) - y`` everywhere (a hand-rolled
    ``max(x,0)+log1p(exp(-|x|))`` form has degenerate subgradients at
    ``x == 0``, which a zero-initialized sparse model hits on every new id).
    """
    return jnp.mean(jax.nn.softplus(logit) - logit * label)


# ---------------------------------------------------------------------------
# LR: logit = sum_f w_f + b
# ---------------------------------------------------------------------------

def lr_forward(w, b):
    """LR logit from gathered per-field weights.

    Args:
      w: (B, F) gathered weights for the batch's ids.
      b: (1,) dense bias.
    Returns:
      (B,) logits.
    """
    return jnp.sum(w, axis=1) + b[0]


def lr_predict(w, b):
    """Serving graph: (B,) CTR probabilities."""
    return (_sigmoid(lr_forward(w, b)),)


def lr_train_step(w, b, label):
    """Training graph.

    Returns:
      pred:   (B,) pre-update probabilities (progressive validation).
      loss:   () mean BCE.
      grad_w: (B, F) gradient w.r.t. gathered weights.
      grad_b: (1,) gradient w.r.t. bias.
    """
    def loss_fn(w_, b_):
        return _bce_loss(lr_forward(w_, b_), label)

    pred = _sigmoid(lr_forward(w, b))
    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(w, b)
    return pred, loss, grads[0], grads[1]


# ---------------------------------------------------------------------------
# FM: logit = sum_f w_f + b + 0.5 sum_k((sum_f v)^2 - sum_f v^2)
# ---------------------------------------------------------------------------

def fm_forward(w, v, b):
    """FM logit from gathered first-order weights and factors.

    Args:
      w: (B, F) first-order weights.
      v: (B, F, K) factors.
      b: (1,) bias.
    """
    return jnp.sum(w, axis=1) + b[0] + fm_interaction(v)


def fm_predict(w, v, b):
    """Serving graph: (B,) CTR probabilities."""
    return (_sigmoid(fm_forward(w, v, b)),)


def fm_train_step(w, v, b, label):
    """Training graph. Returns (pred, loss, grad_w, grad_v, grad_b)."""

    def loss_fn(w_, v_, b_):
        return _bce_loss(fm_forward(w_, v_, b_), label)

    pred = _sigmoid(fm_forward(w, v, b))
    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(w, v, b)
    return pred, loss, grads[0], grads[1], grads[2]


# ---------------------------------------------------------------------------
# DeepFM: FM + two-layer MLP tower over the flattened factors.
# Dense tower parameters live in the PS dense table and are graph inputs.
# ---------------------------------------------------------------------------

def deepfm_forward(w, v, b, w1, b1, w2, b2):
    """DeepFM logit.

    Args:
      w:  (B, F) first-order weights.
      v:  (B, F, K) factors (shared between FM term and deep tower).
      b:  (1,) bias.
      w1: (F*K, H) tower layer-1 weights.   b1: (H,)
      w2: (H, 1)  tower layer-2 weights.    b2: (1,)
    """
    bsz, f, k = v.shape
    fm_term = jnp.sum(w, axis=1) + b[0] + fm_interaction(v)
    h = jnp.maximum(v.reshape(bsz, f * k) @ w1 + b1, 0.0)  # ReLU
    deep_term = (h @ w2)[:, 0] + b2[0]
    return fm_term + deep_term


def deepfm_predict(w, v, b, w1, b1, w2, b2):
    """Serving graph: (B,) CTR probabilities."""
    return (_sigmoid(deepfm_forward(w, v, b, w1, b1, w2, b2)),)


def deepfm_train_step(w, v, b, w1, b1, w2, b2, label):
    """Training graph.

    Returns (pred, loss, grad_w, grad_v, grad_b, grad_w1, grad_b1,
    grad_w2, grad_b2).
    """

    def loss_fn(*params):
        return _bce_loss(deepfm_forward(*params), label)

    pred = _sigmoid(deepfm_forward(w, v, b, w1, b1, w2, b2))
    loss, grads = jax.value_and_grad(loss_fn, argnums=tuple(range(7)))(
        w, v, b, w1, b1, w2, b2
    )
    return (pred, loss) + tuple(grads)


# ---------------------------------------------------------------------------
# Registry used by aot.py and the tests.
# ---------------------------------------------------------------------------

def model_specs(batch_train, batch_predict, fields, dim, hidden):
    """Describe every AOT module variant: name -> (fn, input shapes).

    Shapes use f32 unless noted. The Rust runtime reads the same manifest
    (artifacts/manifest.json) to know what to feed each executable.
    """
    f32 = jnp.float32
    bt, bp, f, k, h = batch_train, batch_predict, fields, dim, hidden

    def s(*shape):
        return jax.ShapeDtypeStruct(shape, f32)

    return {
        "lr_train": (lr_train_step, [s(bt, f), s(1), s(bt)]),
        "lr_predict": (lr_predict, [s(bp, f), s(1)]),
        "fm_train": (fm_train_step, [s(bt, f), s(bt, f, k), s(1), s(bt)]),
        "fm_predict": (fm_predict, [s(bp, f), s(bp, f, k), s(1)]),
        "deepfm_train": (
            deepfm_train_step,
            [s(bt, f), s(bt, f, k), s(1), s(f * k, h), s(h), s(h, 1), s(1), s(bt)],
        ),
        "deepfm_predict": (
            deepfm_predict,
            [s(bp, f), s(bp, f, k), s(1), s(f * k, h), s(h), s(h, 1), s(1)],
        ),
    }
