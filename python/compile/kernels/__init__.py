"""L1: Pallas kernels for the WeiPS compute hot-spots + pure-jnp oracles."""

from .fm import fm_interaction
from .ftrl import ftrl_update

__all__ = ["fm_interaction", "ftrl_update"]
