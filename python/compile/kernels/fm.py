"""Pallas kernel: FM second-order interaction (forward hot-spot).

The factorization-machine term 0.5 * sum_k((sum_f v)^2 - sum_f v^2) is the
dominant non-matmul op in the FM / DeepFM forward pass WeiPS serves. The
kernel reduces over the field axis F entirely in VMEM, one (BLOCK_B, F, K)
tile of the batch per grid step, emitting a (BLOCK_B,) partial of logits.

The op carries an analytic ``custom_vjp`` so the training graphs can
differentiate through it: d/dv [0.5((sum_f v)^2 - sum_f v^2)] = sum_f v - v,
scaled by the incoming cotangent — the backward pass is a second Pallas
kernel over the same tiling.

TPU shaping: K (the factor dim) sits on the 128-lane minor axis, the F
reduction is a VPU tree-add in registers, no MXU involvement; arithmetic
intensity is ~2F flops per 4F bytes read, i.e. bandwidth-bound like the
FTRL kernel. Lowered ``interpret=True`` for CPU PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch rows per VMEM tile: 256 x F=64 x K=32 fp32 = 2 MiB worst case.
BLOCK_B = 256


def _fm_fwd_kernel(v_ref, o_ref):
    v = v_ref[...]  # (bb, F, K)
    s = jnp.sum(v, axis=1)  # (bb, K)
    sum_sq = s * s
    sq_sum = jnp.sum(v * v, axis=1)  # (bb, K)
    o_ref[...] = 0.5 * jnp.sum(sum_sq - sq_sum, axis=-1)


def _fm_bwd_kernel(v_ref, ct_ref, dv_ref):
    v = v_ref[...]  # (bb, F, K)
    ct = ct_ref[...]  # (bb,)
    s = jnp.sum(v, axis=1, keepdims=True)  # (bb, 1, K)
    dv_ref[...] = ct[:, None, None] * (s - v)


def _pad_batch(v, bb):
    b = v.shape[0]
    pad = (-b) % bb if bb else 0
    if pad:
        v = jnp.pad(v, ((0, pad),) + ((0, 0),) * (v.ndim - 1))
    return v, pad


def _fm_forward_pallas(v, block_b):
    b, f, k = v.shape
    bb = min(block_b, max(b, 1))
    v_p, pad = _pad_batch(v, bb)
    padded_b = b + pad
    out = pl.pallas_call(
        _fm_fwd_kernel,
        grid=(padded_b // bb,),
        in_specs=[pl.BlockSpec((bb, f, k), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded_b,), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(v_p)
    return out[:b] if pad else out


def _fm_backward_pallas(v, ct, block_b):
    b, f, k = v.shape
    bb = min(block_b, max(b, 1))
    v_p, pad = _pad_batch(v, bb)
    ct_p, _ = _pad_batch(ct, bb)
    padded_b = b + pad
    dv = pl.pallas_call(
        _fm_bwd_kernel,
        grid=(padded_b // bb,),
        in_specs=[
            pl.BlockSpec((bb, f, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, f, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_b, f, k), jnp.float32),
        interpret=True,
    )(v_p, ct_p)
    return dv[:b] if pad else dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _fm_op(v, block_b):
    return _fm_forward_pallas(v, block_b)


def _fm_op_fwd(v, block_b):
    return _fm_forward_pallas(v, block_b), v


def _fm_op_bwd(block_b, v, ct):
    return (_fm_backward_pallas(v, ct, block_b),)


_fm_op.defvjp(_fm_op_fwd, _fm_op_bwd)


def fm_interaction(v, block_b=BLOCK_B):
    """FM second-order logits via Pallas (differentiable).

    Args:
      v: (B, F, K) float32 factor tensor.
      block_b: batch rows per VMEM tile.

    Returns:
      (B,) float32 second-order logits.
    """
    v = jnp.asarray(v, jnp.float32)
    assert v.ndim == 3, v.shape
    return _fm_op(v, block_b)


def vmem_bytes(block_b=BLOCK_B, fields=16, dim=8, dtype_bytes=4):
    """Static VMEM footprint estimate for one forward grid step."""
    return block_b * fields * dim * dtype_bytes + block_b * dtype_bytes
