"""Pallas kernel: batched FTRL-proximal update (the master-server hot spot).

WeiPS applies the optimizer on the server (§2.1, §4.1.2): every trainer
push lands a gradient block on a master shard which must update the FTRL
accumulators (z, n) and derive the serving weight w for the block of
touched ids. At production push rates this elementwise 10-op update over
(ids x dim) blocks dominates master CPU, so it is implemented as the L1
Pallas kernel and AOT-lowered into the HLO module the Rust master executes.

TPU shaping (DESIGN.md §Hardware-Adaptation): the (N, D) block is tiled by
``BlockSpec`` into VMEM-resident (BLOCK_N, D) tiles — D is padded Rust-side
to a lane multiple for the wide tables — and the update is pure VPU
elementwise work (no MXU), so the roofline is HBM bandwidth: 4 streams in
(g, z, n) + 3 out (z, n, w) of 4 bytes each. On CPU we lower with
``interpret=True`` (a real TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM tile. At D=8 fp32 this is 3 x 2048 x 8 x 4 B = 192 KiB of
# input tiles + 3 output tiles => ~384 KiB << 16 MiB VMEM, leaving room for
# double-buffering the HBM->VMEM pipeline.
BLOCK_N = 2048


def _ftrl_kernel(g_ref, z_ref, n_ref, zo_ref, no_ref, wo_ref, *, alpha, beta, l1, l2):
    """Per-tile FTRL-proximal update (runs once per grid step)."""
    g = g_ref[...]
    z = z_ref[...]
    n = n_ref[...]

    sqrt_n = jnp.sqrt(n)
    denom_old = (beta + sqrt_n) / alpha + l2
    w_old = jnp.where(
        jnp.abs(z) <= l1, jnp.zeros_like(z), -(z - jnp.sign(z) * l1) / denom_old
    )

    g2 = g * g
    n_new = n + g2
    sqrt_n_new = jnp.sqrt(n_new)
    sigma = (sqrt_n_new - sqrt_n) / alpha
    z_new = z + g - sigma * w_old

    denom_new = (beta + sqrt_n_new) / alpha + l2
    w_new = jnp.where(
        jnp.abs(z_new) <= l1,
        jnp.zeros_like(z_new),
        -(z_new - jnp.sign(z_new) * l1) / denom_new,
    )

    zo_ref[...] = z_new
    no_ref[...] = n_new
    wo_ref[...] = w_new


def ftrl_update(g, z, n, alpha=0.05, beta=1.0, l1=1.0, l2=1.0, block_n=BLOCK_N):
    """Batched FTRL update via Pallas.

    Args:
      g, z, n: (N, D) float32 blocks (gradient, z-, n- accumulators).
      alpha, beta, l1, l2: FTRL hyper-parameters (static).
      block_n: rows per VMEM tile; N is padded to a multiple internally.

    Returns:
      (z_new, n_new, w_new), each (N, D) float32.
    """
    g = jnp.asarray(g, jnp.float32)
    z = jnp.asarray(z, jnp.float32)
    n = jnp.asarray(n, jnp.float32)
    assert g.shape == z.shape == n.shape and g.ndim == 2, (g.shape, z.shape, n.shape)
    n_rows, dim = g.shape

    bn = min(block_n, max(n_rows, 1))
    pad = (-n_rows) % bn if bn else 0
    if pad:
        g = jnp.pad(g, ((0, pad), (0, 0)))
        z = jnp.pad(z, ((0, pad), (0, 0)))
        # Pad n with 1.0 so padded lanes have a well-defined sqrt/denominator.
        n = jnp.pad(n, ((0, pad), (0, 0)), constant_values=1.0)
    padded_rows = n_rows + pad

    kernel = functools.partial(_ftrl_kernel, alpha=alpha, beta=beta, l1=l1, l2=l2)
    spec = pl.BlockSpec((bn, dim), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((padded_rows, dim), jnp.float32)
    z_new, n_new, w_new = pl.pallas_call(
        kernel,
        grid=(padded_rows // bn,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[out_shape, out_shape, out_shape],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(g, z, n)
    if pad:
        z_new = z_new[:n_rows]
        n_new = n_new[:n_rows]
        w_new = w_new[:n_rows]
    return z_new, n_new, w_new


def vmem_bytes(block_n=BLOCK_N, dim=8, dtype_bytes=4):
    """Static VMEM footprint estimate for one grid step (6 tiles)."""
    return 6 * block_n * dim * dtype_bytes
