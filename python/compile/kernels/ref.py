"""Pure-jnp correctness oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here
written with plain ``jax.numpy`` ops only. pytest (incl. hypothesis shape /
dtype sweeps) asserts ``assert_allclose(kernel(...), ref(...))`` — this is
the core L1 correctness signal of the repo.

The math follows the WeiPS paper's optimizer inventory (§4.1.2): FTRL-
proximal (McMahan 2011) as used by LR-FTRL / FM-FTRL, and the FM second-
order interaction term (Rendle 2010) that is the compute hot-spot of the
FM / DeepFM forward pass.
"""

from __future__ import annotations

import jax.numpy as jnp


def _ftrl_weight(z, n, alpha, beta, l1, l2):
    """w(z, n) under FTRL-proximal with L1/L2 regularization."""
    shrink = -(z - jnp.sign(z) * l1) / ((beta + jnp.sqrt(n)) / alpha + l2)
    return jnp.where(jnp.abs(z) <= l1, jnp.zeros_like(z), shrink)


def ftrl_update_ref(g, z, n, alpha=0.05, beta=1.0, l1=1.0, l2=1.0):
    """One FTRL-proximal step over a block of parameters.

    Args:
      g: gradient block, shape (N, D).
      z: FTRL z accumulator, shape (N, D).
      n: FTRL squared-gradient accumulator, shape (N, D).
      alpha, beta, l1, l2: FTRL hyper-parameters (python floats).

    Returns:
      (z_new, n_new, w_new): updated accumulators and the serving weight
      derived from them. ``w_new`` is what the slave stores after the
      FTRL(z,n) -> w model transform (paper §4.1.4b).
    """
    g = jnp.asarray(g)
    z = jnp.asarray(z)
    n = jnp.asarray(n)
    # Current weight implied by (z, n) — needed for the sigma correction.
    w_old = _ftrl_weight(z, n, alpha, beta, l1, l2)
    sigma = (jnp.sqrt(n + g * g) - jnp.sqrt(n)) / alpha
    z_new = z + g - sigma * w_old
    n_new = n + g * g
    w_new = _ftrl_weight(z_new, n_new, alpha, beta, l1, l2)
    return z_new, n_new, w_new


def ftrl_weight_ref(z, n, alpha=0.05, beta=1.0, l1=1.0, l2=1.0):
    """Serving weight from FTRL accumulators (the slave-side transform)."""
    return _ftrl_weight(jnp.asarray(z), jnp.asarray(n), alpha, beta, l1, l2)


def fm_interaction_ref(v):
    """FM second-order term: 0.5 * sum_k ((sum_f v)^2 - sum_f v^2).

    Args:
      v: factor tensor, shape (B, F, K) — B samples, F fields, K factors.

    Returns:
      (B,) second-order logits.
    """
    v = jnp.asarray(v)
    sum_sq = jnp.sum(v, axis=1) ** 2  # (B, K)
    sq_sum = jnp.sum(v * v, axis=1)  # (B, K)
    return 0.5 * jnp.sum(sum_sq - sq_sum, axis=-1)


def adagrad_update_ref(g, acc, w, lr=0.01, eps=1e-8):
    """Adagrad step over a block: returns (acc_new, w_new)."""
    g = jnp.asarray(g)
    acc_new = jnp.asarray(acc) + g * g
    w_new = jnp.asarray(w) - lr * g / (jnp.sqrt(acc_new) + eps)
    return acc_new, w_new
