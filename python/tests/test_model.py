"""L2 correctness: model graphs — shapes, gradient checks, loss semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import model as M

SETTINGS = dict(max_examples=15, deadline=None)


def _batch(seed, b, f, k=None):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = jax.random.normal(keys[0], (b, f), jnp.float32) * 0.1
    label = (jax.random.uniform(keys[1], (b,)) < 0.5).astype(jnp.float32)
    if k is None:
        return w, label
    v = jax.random.normal(keys[2], (b, f, k), jnp.float32) * 0.1
    return w, v, label


def _numerical_grad(fn, x, eps=1e-3):
    """Central-difference gradient of scalar fn w.r.t. array x."""
    x = np.asarray(x, np.float64)
    g = np.zeros_like(x)
    it = np.nditer(x, flags=["multi_index"])
    while not it.finished:
        idx = it.multi_index
        xp = x.copy()
        xm = x.copy()
        xp[idx] += eps
        xm[idx] -= eps
        g[idx] = (float(fn(xp)) - float(fn(xm))) / (2 * eps)
        it.iternext()
    return g


# ---------------------------------------------------------------------------
# LR
# ---------------------------------------------------------------------------


def test_lr_shapes():
    w, label = _batch(0, 32, 16)
    b = jnp.zeros(1)
    pred, loss, gw, gb = M.lr_train_step(w, b, label)
    assert pred.shape == (32,) and loss.shape == () and gw.shape == (32, 16)
    assert gb.shape == (1,)
    (p,) = M.lr_predict(w, b)
    assert p.shape == (32,)


def test_lr_grad_matches_numerical():
    w, label = _batch(1, 4, 3)
    b = jnp.array([0.2])
    _, _, gw, gb = M.lr_train_step(w, b, label)

    def loss_of_w(wnp):
        logit = wnp.sum(axis=1) + 0.2
        lab = np.asarray(label, np.float64)
        return np.mean(np.clip(logit, 0, None) - logit * lab + np.log1p(np.exp(-np.abs(logit))))

    num = _numerical_grad(loss_of_w, w)
    np.testing.assert_allclose(gw, num, rtol=1e-3, atol=1e-4)


def test_lr_prediction_is_probability():
    w, label = _batch(2, 64, 8)
    pred, _, _, _ = M.lr_train_step(w, jnp.zeros(1), label)
    p = np.asarray(pred)
    assert np.all(p > 0) and np.all(p < 1)


def test_lr_pred_is_pre_update():
    # Progressive validation: prediction must be a pure function of the
    # inputs, not of the gradient step (paper §4.3.1).
    w, label = _batch(3, 8, 4)
    b = jnp.zeros(1)
    pred, _, _, _ = M.lr_train_step(w, b, label)
    (pred2,) = M.lr_predict(w, b)
    np.testing.assert_allclose(pred, pred2, rtol=1e-6)


# ---------------------------------------------------------------------------
# FM
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(b=st.integers(1, 64), f=st.integers(1, 12), k=st.integers(1, 8), seed=st.integers(0, 1000))
def test_fm_shapes_sweep(b, f, k, seed):
    w, v, label = _batch(seed, b, f, k)
    bias = jnp.zeros(1)
    pred, loss, gw, gv, gb = M.fm_train_step(w, v, bias, label)
    assert pred.shape == (b,) and gw.shape == (b, f) and gv.shape == (b, f, k)
    assert np.isfinite(float(loss))


def test_fm_grad_v_matches_numerical():
    w, v, label = _batch(5, 3, 4, 2)
    bias = jnp.array([0.0])
    _, _, _, gv, _ = M.fm_train_step(w, v, bias, label)

    def loss_of_v(vnp):
        sum_sq = vnp.sum(axis=1) ** 2
        sq_sum = (vnp**2).sum(axis=1)
        inter = 0.5 * (sum_sq - sq_sum).sum(axis=-1)
        logit = np.asarray(w, np.float64).sum(axis=1) + inter
        lab = np.asarray(label, np.float64)
        return np.mean(np.clip(logit, 0, None) - logit * lab + np.log1p(np.exp(-np.abs(logit))))

    num = _numerical_grad(loss_of_v, v)
    np.testing.assert_allclose(gv, num, rtol=2e-3, atol=1e-4)


def test_fm_reduces_to_lr_when_factors_zero():
    w, v, label = _batch(6, 16, 8, 4)
    bias = jnp.array([0.3])
    zero_v = jnp.zeros_like(v)
    (p_fm,) = M.fm_predict(w, zero_v, bias)
    (p_lr,) = M.lr_predict(w, bias)
    np.testing.assert_allclose(p_fm, p_lr, rtol=1e-6)


# ---------------------------------------------------------------------------
# DeepFM
# ---------------------------------------------------------------------------


def _deep_params(seed, f, k, h):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    w1 = jax.random.normal(keys[0], (f * k, h), jnp.float32) * 0.1
    b1 = jnp.zeros(h)
    w2 = jax.random.normal(keys[1], (h, 1), jnp.float32) * 0.1
    b2 = jnp.zeros(1)
    return w1, b1, w2, b2


def test_deepfm_shapes():
    b, f, k, h = 16, 8, 4, 32
    w, v, label = _batch(7, b, f, k)
    bias = jnp.zeros(1)
    w1, b1, w2, b2 = _deep_params(8, f, k, h)
    out = M.deepfm_train_step(w, v, bias, w1, b1, w2, b2, label)
    pred, loss, gw, gv, gb, gw1, gb1, gw2, gb2 = out
    assert pred.shape == (b,)
    assert gw1.shape == (f * k, h) and gb1.shape == (h,)
    assert gw2.shape == (h, 1) and gb2.shape == (1,)
    assert np.isfinite(float(loss))


def test_deepfm_reduces_to_fm_when_tower_zero():
    b, f, k, h = 8, 6, 3, 16
    w, v, label = _batch(9, b, f, k)
    bias = jnp.array([0.1])
    w1 = jnp.zeros((f * k, h))
    b1 = jnp.zeros(h)
    w2 = jnp.zeros((h, 1))
    b2 = jnp.zeros(1)
    (p_deep,) = M.deepfm_predict(w, v, bias, w1, b1, w2, b2)
    (p_fm,) = M.fm_predict(w, v, bias)
    np.testing.assert_allclose(p_deep, p_fm, rtol=1e-6)


def test_deepfm_dense_grad_matches_numerical():
    b, f, k, h = 4, 3, 2, 5
    w, v, label = _batch(10, b, f, k)
    bias = jnp.zeros(1)
    w1, b1, w2, b2 = _deep_params(11, f, k, h)
    out = M.deepfm_train_step(w, v, bias, w1, b1, w2, b2, label)
    gw2 = out[7]

    def loss_of_w2(w2np):
        vn = np.asarray(v, np.float64).reshape(b, f * k)
        hpre = vn @ np.asarray(w1, np.float64) + np.asarray(b1, np.float64)
        hact = np.maximum(hpre, 0)
        deep = (hact @ w2np)[:, 0]
        wn = np.asarray(w, np.float64)
        sum_sq = np.asarray(v, np.float64).sum(axis=1) ** 2
        sq_sum = (np.asarray(v, np.float64) ** 2).sum(axis=1)
        inter = 0.5 * (sum_sq - sq_sum).sum(axis=-1)
        logit = wn.sum(axis=1) + inter + deep
        lab = np.asarray(label, np.float64)
        return np.mean(np.clip(logit, 0, None) - logit * lab + np.log1p(np.exp(-np.abs(logit))))

    num = _numerical_grad(loss_of_w2, w2)
    np.testing.assert_allclose(gw2, num, rtol=2e-3, atol=1e-4)


def test_training_reduces_loss_full_batch_gd():
    # A few steps of plain GD on the gathered weights should reduce loss.
    b, f = 64, 8
    key = jax.random.PRNGKey(12)
    w = jnp.zeros((b, f))
    true_w = jax.random.normal(key, (f,))
    x_sign = jnp.sign(jax.random.normal(jax.random.PRNGKey(13), (b, f)))
    label = (jnp.sum(x_sign * true_w, axis=1) > 0).astype(jnp.float32)
    bias = jnp.zeros(1)
    # Fold feature signs into the gathered weights (w acts as w_f * x_f).
    losses = []
    for _ in range(30):
        pred, loss, gw, gb = M.lr_train_step(w, bias, label)
        losses.append(float(loss))
        w = w - 0.5 * gw
        bias = bias - 0.5 * gb
    assert losses[-1] < losses[0] * 0.8, losses[:3] + losses[-3:]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_model_specs_cover_all_variants():
    specs = M.model_specs(32, 4, 8, 4, 16)
    assert set(specs) == {
        "lr_train",
        "lr_predict",
        "fm_train",
        "fm_predict",
        "deepfm_train",
        "deepfm_predict",
    }
    for name, (fn, args) in specs.items():
        out = jax.eval_shape(fn, *args)
        leaves = jax.tree_util.tree_leaves(out)
        assert leaves, name
        first = leaves[0]
        expect_b = 32 if name.endswith("train") else 4
        assert first.shape == (expect_b,), (name, first.shape)
