"""AOT pipeline: lowering produces loadable HLO text + a consistent manifest."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_to_hlo_text_smoke():
    import jax

    def fn(x):
        return (x * 2.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4]" in text


def test_lower_module_reports_shapes():
    import jax

    specs = [jax.ShapeDtypeStruct((8, 3), jnp.float32), jax.ShapeDtypeStruct((8,), jnp.float32)]

    def fn(w, label):
        return (jnp.sum(w, axis=1) - label, jnp.mean(label))

    hlo, inputs, outputs = aot.lower_module(fn, specs)
    assert inputs == [
        {"shape": [8, 3], "dtype": "f32"},
        {"shape": [8], "dtype": "f32"},
    ]
    assert outputs[0] == {"shape": [8], "dtype": "f32"}
    assert outputs[1] == {"shape": [], "dtype": "f32"}
    assert "HloModule" in hlo


def test_build_writes_all_artifacts(tmp_path):
    out = str(tmp_path)
    aot.build(out, batch_train=8, batch_predict=2, fields=4, dim=2, hidden=8, block_rows=64)
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    mods = manifest["modules"]
    expected = {
        "lr_train",
        "lr_predict",
        "fm_train",
        "fm_predict",
        "deepfm_train",
        "deepfm_predict",
        "ftrl_update_d1",
        "ftrl_update_d2",
        "ftrl_weight_d1",
        "ftrl_weight_d2",
    }
    assert set(mods) == expected
    for name, meta in mods.items():
        path = os.path.join(out, meta["path"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, name
    cfg = manifest["config"]
    assert cfg["batch_train"] == 8 and cfg["dim"] == 2
    assert cfg["ftrl"]["alpha"] == pytest.approx(aot.FTRL_HYPERS["alpha"])
    assert cfg["ftrl"]["l1"] == pytest.approx(aot.FTRL_HYPERS["l1"])


def test_manifest_shapes_match_model_specs(tmp_path):
    out = str(tmp_path)
    aot.build(out, batch_train=8, batch_predict=2, fields=4, dim=2, hidden=8, block_rows=64)
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    specs = M.model_specs(8, 2, 4, 2, 8)
    for name, (fn, args) in specs.items():
        meta = manifest["modules"][name]
        got = [tuple(e["shape"]) for e in meta["inputs"]]
        want = [tuple(a.shape) for a in args]
        assert got == want, name


def test_lowered_fm_train_executes_in_jax(tmp_path):
    # The lowered module is also executable in-process: compile the jitted
    # fn and compare against the eager path (guards against lowering the
    # wrong function into the artifact).
    import jax

    specs = M.model_specs(4, 2, 3, 2, 8)
    fn, args = specs["fm_train"]
    rng = np.random.RandomState(0)
    concrete = [jnp.asarray(rng.randn(*a.shape), jnp.float32) for a in args]
    eager = fn(*concrete)
    jitted = jax.jit(fn)(*concrete)
    for a, b in zip(eager, jitted):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
