"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import fm, ftrl
from compile.kernels.ref import (
    adagrad_update_ref,
    fm_interaction_ref,
    ftrl_update_ref,
    ftrl_weight_ref,
)

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, lo=-3.0, hi=3.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


# ---------------------------------------------------------------------------
# FTRL update kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n_rows=st.integers(1, 700),
    dim=st.integers(1, 16),
    block=st.sampled_from([8, 64, 256, 2048]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ftrl_matches_ref_across_shapes(n_rows, dim, block, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    g = _rand(keys[0], (n_rows, dim))
    z = _rand(keys[1], (n_rows, dim), -5.0, 5.0)
    n = jax.random.uniform(keys[2], (n_rows, dim), jnp.float32, 0.0, 10.0)

    z1, n1, w1 = ftrl.ftrl_update(g, z, n, block_n=block)
    z2, n2, w2 = ftrl_update_ref(g, z, n)
    np.testing.assert_allclose(z1, z2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(n1, n2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(w1, w2, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    alpha=st.floats(0.01, 1.0),
    beta=st.floats(0.1, 2.0),
    l1=st.floats(0.0, 3.0),
    l2=st.floats(0.0, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_ftrl_matches_ref_across_hypers(alpha, beta, l1, l2, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    g = _rand(keys[0], (37, 4))
    z = _rand(keys[1], (37, 4), -5.0, 5.0)
    n = jax.random.uniform(keys[2], (37, 4), jnp.float32, 0.0, 10.0)

    got = ftrl.ftrl_update(g, z, n, alpha=alpha, beta=beta, l1=l1, l2=l2)
    want = ftrl_update_ref(g, z, n, alpha=alpha, beta=beta, l1=l1, l2=l2)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_ftrl_zero_gradient_is_noop_on_n():
    z = jnp.ones((16, 2))
    n = jnp.full((16, 2), 2.0)
    g = jnp.zeros((16, 2))
    z1, n1, _ = ftrl.ftrl_update(g, z, n)
    np.testing.assert_allclose(n1, n)
    np.testing.assert_allclose(z1, z)


def test_ftrl_l1_sparsifies():
    # Small |z| after update => weight exactly zero (the L1 dead zone).
    g = jnp.full((8, 1), 1e-4)
    z = jnp.zeros((8, 1))
    n = jnp.zeros((8, 1))
    _, _, w = ftrl.ftrl_update(g, z, n, l1=1.0)
    np.testing.assert_array_equal(np.asarray(w), np.zeros((8, 1)))


def test_ftrl_drives_weight_against_gradient():
    # Persistent positive gradient should drive w negative once past l1.
    z = jnp.zeros((4, 1))
    n = jnp.zeros((4, 1))
    w = None
    for _ in range(50):
        g = jnp.ones((4, 1))
        z, n, w = ftrl.ftrl_update(g, z, n)
    assert np.all(np.asarray(w) < 0.0)


def test_ftrl_sequential_equals_ref_trajectory():
    # Multi-step trajectories agree, not just single steps.
    key = jax.random.PRNGKey(7)
    zk, nk = jnp.zeros((32, 8)), jnp.zeros((32, 8))
    zr, nr = jnp.zeros((32, 8)), jnp.zeros((32, 8))
    for i in range(10):
        key, sub = jax.random.split(key)
        g = _rand(sub, (32, 8))
        zk, nk, wk = ftrl.ftrl_update(g, zk, nk, block_n=16)
        zr, nr, wr = ftrl_update_ref(g, zr, nr)
    np.testing.assert_allclose(wk, wr, rtol=1e-4, atol=1e-5)


def test_ftrl_rejects_mismatched_shapes():
    with pytest.raises(AssertionError):
        ftrl.ftrl_update(jnp.zeros((4, 2)), jnp.zeros((4, 3)), jnp.zeros((4, 2)))


# ---------------------------------------------------------------------------
# FM interaction kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    batch=st.integers(1, 600),
    fields=st.integers(1, 32),
    k=st.integers(1, 24),
    block=st.sampled_from([4, 32, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fm_matches_ref_across_shapes(batch, fields, k, block, seed):
    v = _rand(jax.random.PRNGKey(seed), (batch, fields, k))
    got = fm.fm_interaction(v, block_b=block)
    want = fm_interaction_ref(v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_fm_single_field_is_zero():
    # With one field there are no pairwise interactions.
    v = _rand(jax.random.PRNGKey(0), (16, 1, 8))
    np.testing.assert_allclose(fm.fm_interaction(v), np.zeros(16), atol=1e-6)


def test_fm_matches_explicit_pairwise_sum():
    # Brute-force sum_{i<j} <v_i, v_j> on a tiny case.
    v = _rand(jax.random.PRNGKey(3), (4, 5, 3))
    got = np.asarray(fm.fm_interaction(v))
    vn = np.asarray(v)
    want = np.zeros(4, np.float32)
    for bidx in range(4):
        for i in range(5):
            for j in range(i + 1, 5):
                want[bidx] += float(vn[bidx, i] @ vn[bidx, j])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fm_is_jittable_and_differentiable():
    v = _rand(jax.random.PRNGKey(4), (8, 6, 4))

    def loss(v_):
        return jnp.sum(fm.fm_interaction(v_))

    g = jax.jit(jax.grad(loss))(v)
    # d/dv of 0.5((sum v)^2 - sum v^2) = sum_f v - v
    want = jnp.sum(v, axis=1, keepdims=True) - v
    np.testing.assert_allclose(g, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Adagrad oracle sanity (used by the Rust scalar implementation tests)
# ---------------------------------------------------------------------------


def test_adagrad_ref_moves_against_gradient():
    g = jnp.ones((4, 2))
    acc = jnp.zeros((4, 2))
    w = jnp.zeros((4, 2))
    acc1, w1 = adagrad_update_ref(g, acc, w, lr=0.1)
    assert np.all(np.asarray(w1) < 0)
    np.testing.assert_allclose(acc1, np.ones((4, 2)))


def test_ftrl_weight_ref_dead_zone():
    z = jnp.array([[0.5], [-0.5], [2.0], [-2.0]])
    n = jnp.ones((4, 1))
    w = np.asarray(ftrl_weight_ref(z, n, l1=1.0))
    assert w[0, 0] == 0.0 and w[1, 0] == 0.0
    assert w[2, 0] < 0.0 and w[3, 0] > 0.0
