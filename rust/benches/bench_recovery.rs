//! E4 — multi-level fault tolerance (§4.2): unavailability windows for
//! hot-replica failover vs partial (single-shard) recovery vs full-cluster
//! cold restart, plus requests failed during each.

use std::time::Instant;

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::util::bench;

fn cluster() -> LocalCluster {
    LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 8,
            slave_shards: 2,
            slave_replicas: 3,
            queue_partitions: 8,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        workload: weips::sample::WorkloadConfig {
            ids_per_field: 5_000,
            seed: 17,
            ..Default::default()
        },
        ..Default::default()
    })
    .expect("cluster (run `make artifacts` first)")
}

fn main() {
    let mut c = cluster();
    for _ in 0..40 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    c.checkpoint().unwrap();
    for _ in 0..20 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    let rows: usize = c.masters.iter().map(|m| m.total_rows()).sum();
    bench::metric("model rows at failure time", rows);

    // -- hot failover -----------------------------------------------------------
    bench::header("E4a: hot-replica failover (serving unavailability)");
    let reqs = c.serving_requests(4);
    bench::run("serving while healthy", 3, 100, || {
        c.predict(&reqs).unwrap();
    });
    c.kill_slave(0, 0);
    c.kill_slave(1, 0);
    let mut failed = 0u64;
    bench::run("serving immediately after 2 replica deaths", 0, 100, || {
        if c.predict(&reqs).is_err() {
            failed += 1;
        }
    });
    bench::metric("requests failed during failover", failed);

    // -- slave recovery -----------------------------------------------------------
    bench::header("E4b: slave replica recovery (full sync + replay)");
    bench::run("recover_slave (checkpoint + offset replay)", 0, 5, || {
        c.kill_slave(0, 0);
        c.recover_slave(0, 0).unwrap();
    });

    // -- master partial recovery ----------------------------------------------------
    bench::header("E4c: master shard partial recovery vs full restart");
    let t0 = Instant::now();
    c.crash_master(3).unwrap();
    c.recover_master(3).unwrap();
    let partial = t0.elapsed();
    bench::metric("partial recovery (1 of 8 shards)", format!("{partial:?}"));

    // Full cold restart: every shard reloads from checkpoint.
    let t0 = Instant::now();
    let version = c.store.latest_version("ctr").unwrap();
    for m in &c.masters {
        m.load_checkpoint(&c.store, version).unwrap();
    }
    // ... and every replica full-syncs (the cold-path slave bootstrap).
    let snaps: Vec<Vec<u8>> = c
        .masters
        .iter()
        .map(|m| c.store.load_shard("ctr", version, m.shard_id).unwrap())
        .collect();
    for shard in &c.slaves {
        for replica in shard {
            replica.clear();
            for s in &snaps {
                replica.full_sync_from_snapshot(s).unwrap();
            }
        }
    }
    let full = t0.elapsed();
    bench::metric("full cold restart (8 shards + 6 replicas)", format!("{full:?}"));
    bench::metric(
        "partial / full ratio",
        format!("{:.2}x faster", full.as_secs_f64() / partial.as_secs_f64().max(1e-9)),
    );

    // -- checkpoint save cost (the cold-backup write path) ---------------------------
    bench::header("E4d: checkpoint save (async, all shards)");
    bench::run("checkpoint_now (8 shards)", 1, 10, || {
        c.checkpoint().unwrap();
    });
    println!(
        "\nshape check: hot failover adds microseconds and fails zero requests;\npartial recovery is a fraction of a full restart and touches one shard only."
    );
}
