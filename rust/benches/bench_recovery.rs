//! E4 — multi-level fault tolerance (§4.2) + incremental durability.
//!
//! The incremental section (artifact-free, runs everywhere) measures
//! what the Monolith-style chain buys: checkpoint pause and recovery
//! time that scale with the **dirty set**, not total table size. It
//! asserts the shape (a 1%-dirty delta seals far faster than a full
//! base) and that crash recovery — base + delta chain + WAL tail —
//! round-trips **byte-identical** shard state, then writes
//! `BENCH_recovery.json` (CI uploads it per commit and gates the smoke
//! invariants).
//!
//! The legacy cluster drill (hot failover vs partial vs full-cluster
//! recovery) still runs when AOT artifacts are present and `--smoke` is
//! not set.
//!
//! `--smoke` or `WEIPS_BENCH_SMOKE=1` shrinks sizes and skips the
//! cluster drill.

use std::sync::Arc;
use std::time::Instant;

use weips::config::{ModelKind, ModelSpec};
use weips::meta::MetaStore;
use weips::proto::SparsePush;
use weips::queue::WalLog;
use weips::runtime::ModelConfig;
use weips::scheduler::{CkptPolicy, Scheduler};
use weips::server::master::MasterShard;
use weips::storage::incremental::{self, IncrPolicy, WalJournal};
use weips::storage::{CheckpointStore, CkptKind};
use weips::util::bench;
use weips::util::clock::ManualClock;

fn smoke() -> bool {
    std::env::var("WEIPS_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
        || std::env::args().any(|a| a == "--smoke")
}

fn artifacts_ready() -> bool {
    weips::runtime::default_artifacts_dir().join("manifest.json").exists()
}

fn mini_spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 8,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn push_range(m: &MasterShard, ids: std::ops::Range<u64>) {
    let all: Vec<u64> = ids.collect();
    for chunk in all.chunks(4096) {
        let grads: Vec<f32> = chunk.iter().map(|id| (*id % 13) as f32 * 0.1 + 0.2).collect();
        m.sparse_push(&SparsePush {
            model: "ctr".into(),
            table: "w".into(),
            ids: chunk.to_vec(),
            grads,
        })
        .unwrap();
    }
}

fn tmp_dir() -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "weips-bench-recovery-{}-{:x}",
        std::process::id(),
        weips::util::mono_ns()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Incremental checkpoint pause + recovery scaling vs the dirty set.
fn incremental_scaling(rows: u64, results: &mut Vec<String>) {
    bench::header("E4i: incremental checkpoint pause vs dirty set");
    let dir = tmp_dir();
    let store = Arc::new(CheckpointStore::new(dir.join("ckpt"), None));
    let clock = ManualClock::new(0);
    let master =
        Arc::new(MasterShard::new(0, mini_spec(), None, 1, Arc::new(clock.clone())).unwrap());
    let mut scheduler = Scheduler::new(
        MetaStore::new(Arc::new(clock.clone())),
        store.clone(),
        "ctr",
        CkptPolicy { interval_ms: u64::MAX / 4, jitter: 0.0, keep_local: 64, remote_every: 0 },
        Arc::new(clock.clone()),
    );
    scheduler.set_incr_policy(IncrPolicy { base_every: 64, keep_chains: 8 });
    let wal = WalLog::open(dir.join("wal"), 1).unwrap();
    let mut journal = WalJournal::new(0);
    let masters = [master.clone()];

    push_range(&master, 0..rows);
    journal.poll(&master, &wal, 1).unwrap();

    // Base: full snapshot of every row. The snapshot *encode* is the
    // pause the training path feels; the seal adds manifest + fs work.
    let t0 = Instant::now();
    let snap_len = master.snapshot().len();
    let base_encode_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    let (base_version, kind, cuts) = scheduler
        .checkpoint_incremental(&masters, vec![], wal.latest_offsets(), 0.5)
        .unwrap();
    let base_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(kind, CkptKind::Base);
    let mut prev_cut = cuts[0];
    journal.reset(cuts[0], master.dense_versions());
    bench::metric(
        "base checkpoint (all rows)",
        format!("encode {base_encode_ms:.2} ms, seal {base_ms:.2} ms, {rows} rows, {snap_len} B"),
    );
    results.push(format!(
        r#"{{"bench":"recovery","stage":"ckpt_pause","kind":"base","rows":{rows},"dirty_rows":{rows},"encode_ms":{base_encode_ms:.3},"seal_ms":{base_ms:.3}}}"#
    ));

    // Deltas at increasing dirty fractions. Assertions compare *encode*
    // times (pure collection cost, no fs noise); seal times are reported.
    let mut delta_encode_ms = Vec::new();
    let mut last_version = base_version;
    for fraction in [0.01f64, 0.1, 1.0] {
        let dirty = ((rows as f64) * fraction).max(1.0) as u64;
        push_range(&master, 0..dirty);
        journal.poll(&master, &wal, 2).unwrap();
        let t0 = Instant::now();
        let probe = master.encode_delta(prev_cut);
        let encode_ms = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(probe.upserts as u64, dirty, "delta collected the wrong dirty set");
        let t0 = Instant::now();
        let (v, kind, cuts) = scheduler
            .checkpoint_incremental(&masters, vec![], wal.latest_offsets(), 0.5)
            .unwrap();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(kind, CkptKind::Delta);
        prev_cut = cuts[0];
        journal.reset(cuts[0], master.dense_versions());
        last_version = v;
        bench::metric(
            &format!("delta checkpoint ({:.0}% dirty)", fraction * 100.0),
            format!("encode {encode_ms:.2} ms, seal {ms:.2} ms, {dirty} rows"),
        );
        results.push(format!(
            r#"{{"bench":"recovery","stage":"ckpt_pause","kind":"delta","rows":{rows},"dirty_rows":{dirty},"encode_ms":{encode_ms:.3},"seal_ms":{ms:.3}}}"#
        ));
        delta_encode_ms.push(encode_ms);
    }
    // The acceptance shape: pause scales with the dirty set, not table
    // size — a 1%-dirty delta is far cheaper than the full base encode,
    // and delta cost grows with the dirty fraction.
    assert!(
        delta_encode_ms[0] < base_encode_ms,
        "1%-dirty delta encode ({:.3} ms) not cheaper than full base encode ({base_encode_ms:.3} ms)",
        delta_encode_ms[0]
    );
    assert!(
        delta_encode_ms[0] < delta_encode_ms[2],
        "delta encode does not scale with dirty set: 1% {:.3} ms vs 100% {:.3} ms",
        delta_encode_ms[0],
        delta_encode_ms[2]
    );

    // -- recovery ---------------------------------------------------------------
    bench::header("E4ii: recovery time (chain + WAL) and byte identity");
    // WAL-only tail on top of the last sealed delta.
    push_range(&master, 0..rows / 100);
    journal.poll(&master, &wal, 3).unwrap();
    let reference = master.snapshot();

    let fresh =
        Arc::new(MasterShard::new(0, mini_spec(), None, 1, Arc::new(clock.clone())).unwrap());
    let t0 = Instant::now();
    let tip = fresh.restore_chain(&store, last_version, 0).unwrap();
    let chain_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    let from = tip.wal_offsets.first().copied().unwrap_or(0);
    let replayed = incremental::replay_wal(&fresh, &wal, 0, from).unwrap();
    let wal_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(replayed > 0);
    assert_eq!(
        fresh.snapshot(),
        reference,
        "crash recovery did not round-trip byte-identical state"
    );
    bench::metric("chain restore (base + 3 deltas)", format!("{chain_ms:.2} ms"));
    bench::metric("WAL tail replay", format!("{wal_ms:.2} ms, {replayed} records"));
    bench::metric("recovered state", "byte-identical to uninterrupted run");
    results.push(format!(
        r#"{{"bench":"recovery","stage":"recover","rows":{rows},"chain_ms":{chain_ms:.3},"wal_ms":{wal_ms:.3},"wal_records":{replayed},"byte_identical":true}}"#
    ));

    // Dirty-set-proportional recovery: replaying one delta on a warm
    // shard touches only its dirty rows.
    let dirty = ((rows as f64) * 0.01).max(1.0) as u64;
    let chunk = store.load_chunk("ctr", base_version + 1, 0, CkptKind::Delta).unwrap();
    let t0 = Instant::now();
    fresh.apply_delta(&chunk, false).unwrap();
    let delta_apply_ms = t0.elapsed().as_secs_f64() * 1e3;
    bench::metric(
        &format!("single delta re-apply ({dirty} rows)"),
        format!("{delta_apply_ms:.2} ms"),
    );
    results.push(format!(
        r#"{{"bench":"recovery","stage":"delta_apply","rows":{rows},"dirty_rows":{dirty},"ms":{delta_apply_ms:.3}}}"#
    ));

    std::fs::remove_dir_all(dir).ok();
}

/// The legacy cluster drill: hot failover, slave recovery, master
/// partial recovery vs full cold restart (needs AOT artifacts).
fn cluster_drill() {
    use weips::config::{ClusterConfig, GatherMode};
    use weips::coordinator::{ClusterOpts, LocalCluster};

    let mut c = LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 8,
            slave_shards: 2,
            slave_replicas: 3,
            queue_partitions: 8,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        workload: weips::sample::WorkloadConfig {
            ids_per_field: 5_000,
            seed: 17,
            ..Default::default()
        },
        ..Default::default()
    })
    .expect("cluster (run `make artifacts` first)");
    for _ in 0..40 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    c.checkpoint().unwrap();
    for _ in 0..20 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    let rows: usize = c.masters.iter().map(|m| m.total_rows()).sum();
    bench::metric("model rows at failure time", rows);

    // -- hot failover ---------------------------------------------------------
    bench::header("E4a: hot-replica failover (serving unavailability)");
    let reqs = c.serving_requests(4);
    bench::run("serving while healthy", 3, 100, || {
        c.predict(&reqs).unwrap();
    });
    c.kill_slave(0, 0);
    c.kill_slave(1, 0);
    let mut failed = 0u64;
    bench::run("serving immediately after 2 replica deaths", 0, 100, || {
        if c.predict(&reqs).is_err() {
            failed += 1;
        }
    });
    bench::metric("requests failed during failover", failed);

    // -- slave recovery -------------------------------------------------------
    bench::header("E4b: slave replica recovery (chain sync + replay)");
    bench::run("recover_slave (chain + offset replay)", 0, 5, || {
        c.kill_slave(0, 0);
        c.recover_slave(0, 0).unwrap();
    });

    // -- master partial recovery ----------------------------------------------
    bench::header("E4c: master shard partial recovery vs full restart");
    let t0 = Instant::now();
    c.crash_master(3).unwrap();
    c.recover_master(3).unwrap();
    let partial = t0.elapsed();
    bench::metric("partial recovery (1 of 8 shards)", format!("{partial:?}"));

    // Full cold restart: every shard reloads, every replica re-syncs.
    let t0 = Instant::now();
    let version = c.store.latest_version("ctr").unwrap();
    for m in &c.masters {
        m.restore_chain(&c.store, version, m.shard_id as usize).unwrap();
    }
    let chains: Vec<_> =
        c.masters.iter().map(|m| c.shard_chain(version, m.shard_id).unwrap()).collect();
    for shard in &c.slaves {
        for replica in shard {
            replica.clear();
            for chain in &chains {
                LocalCluster::apply_chain_chunks(replica, chain, None).unwrap();
            }
        }
    }
    let full = t0.elapsed();
    bench::metric("full cold restart (8 shards + 6 replicas)", format!("{full:?}"));
    bench::metric(
        "partial / full ratio",
        format!("{:.2}x faster", full.as_secs_f64() / partial.as_secs_f64().max(1e-9)),
    );

    // -- checkpoint save cost -------------------------------------------------
    bench::header("E4d: checkpoint save (async, all shards)");
    bench::run("checkpoint_now (8 shards)", 1, 10, || {
        c.checkpoint().unwrap();
    });
    println!(
        "\nshape check: hot failover adds microseconds and fails zero requests;\npartial recovery is a fraction of a full restart and touches one shard only."
    );
}

fn main() {
    let rows = if smoke() { 20_000u64 } else { 200_000u64 };
    let mut results = Vec::new();
    incremental_scaling(rows, &mut results);
    let json = format!("[\n  {}\n]\n", results.join(",\n  "));
    // Anchor to the workspace root (cargo runs benches with cwd = the
    // package root, rust/), so CI finds the artifact at a fixed path.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package has a parent dir")
        .join("BENCH_recovery.json");
    std::fs::write(&out, &json).expect("write BENCH_recovery.json");
    println!("\nwrote {} ({} records)", out.display(), results.len());
    if !smoke() && artifacts_ready() {
        cluster_drill();
    }
}
