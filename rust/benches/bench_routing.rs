//! E6 — model routing + heterogeneous migration (§4.1.4a, §4.2.1d):
//! routing overhead per batch across cluster sizes, partition-subset
//! bandwidth reduction, and whole-model migration cost 10 -> 20 shards.

use std::sync::Arc;

use weips::config::{ModelKind, ModelSpec};
use weips::proto::SparsePush;
use weips::runtime::ModelConfig;
use weips::server::master::MasterShard;
use weips::sync::router::{partition_subset_applies, partitions_for_slave, Router};
use weips::util::bench;
use weips::util::clock::ManualClock;

fn model_cfg() -> ModelConfig {
    ModelConfig {
        batch_train: 256,
        batch_predict: 16,
        fields: 16,
        dim: 8,
        hidden: 64,
        ftrl_block_rows: 8192,
        ftrl_alpha: 0.1,
        ftrl_beta: 1.0,
        ftrl_l1: 0.01,
        ftrl_l2: 1.0,
    }
}

fn main() {
    bench::header("E6a: id routing throughput (split_ids per batch of 4096)");
    let ids: Vec<u64> = (0..4096u64).map(|i| i * 2_654_435_761).collect();
    for shards in [1u32, 4, 16, 32] {
        let router = Router::new(shards);
        bench::run_batched(&format!("split_ids into {shards} shards (ids/s)"), 5, 200, 4096, || {
            std::hint::black_box(router.split_ids(&ids));
        });
    }

    println!("\n=== E6b: partition-subset bandwidth (slave reads P/S of the queue) ===");
    println!(
        "{:<12} {:<12} {:<12} {:>18} {:>12}",
        "masters", "partitions", "slaves", "parts/slave", "reduction"
    );
    for (m, p, s) in [(8u32, 8u32, 4u32), (8, 8, 2), (12, 12, 4), (8, 8, 3), (16, 16, 8)] {
        let per_slave = partitions_for_slave(m, p, s, 0).len();
        let reduction = if partition_subset_applies(m, p, s) {
            format!("{:.0}%", (1.0 - per_slave as f64 / p as f64) * 100.0)
        } else {
            "0% (fallback)".into()
        };
        println!("{:<12} {:<12} {:<12} {:>18} {:>12}", m, p, s, per_slave, reduction);
    }

    bench::header("E6c: heterogeneous migration (trained model, full remap)");
    let spec = ModelSpec::derive("ctr", ModelKind::Fm, &model_cfg());
    let clock = Arc::new(ManualClock::new(0));
    let build = |shards: u32| -> Vec<Arc<MasterShard>> {
        (0..shards)
            .map(|i| Arc::new(MasterShard::new(i, spec.clone(), None, 1, clock.clone()).unwrap()))
            .collect()
    };
    let src = build(10);
    let src_router = Router::new(10);
    let n = 100_000u64;
    for base in (0..n).step_by(2048) {
        let mut per_shard: Vec<Vec<u64>> = vec![Vec::new(); 10];
        for id in base..(base + 2048).min(n) {
            per_shard[src_router.shard_of(id) as usize].push(id);
        }
        for (sidx, ids) in per_shard.into_iter().enumerate() {
            if ids.is_empty() {
                continue;
            }
            let grads = vec![0.5f32; ids.len()];
            src[sidx]
                .sparse_push(&SparsePush {
                    model: "ctr".into(),
                    table: "w".into(),
                    ids,
                    grads,
                })
                .unwrap();
        }
    }
    bench::metric("rows to migrate", n);
    for dst_shards in [20u32, 4] {
        let label = format!("migrate 10 -> {dst_shards} shards (rows/s)");
        bench::run_batched(&label, 0, 3, n, || {
            let dst = build(dst_shards);
            let router = Router::new(dst_shards);
            let mut moved = 0;
            for s in &src {
                let snap = s.snapshot();
                for (di, d) in dst.iter().enumerate() {
                    moved += d.absorb(&snap, &router, di as u32).unwrap();
                }
            }
            assert_eq!(moved, n as usize);
        });
    }
    println!(
        "\nshape check: routing adds nanoseconds per id; compatible topologies cut\nslave queue reads by (1 - S/P); full migration is snapshot-bandwidth-bound."
    );
}
