//! E8 — model freshness (§1.1): "if the interests model cannot be updated
//! in time, the performance of the model will slowly decrease". Sweeps
//! serving-model staleness (how long ago updates stopped) against AUC on
//! current traffic, under ground-truth drift — the series version of the
//! `online_ctr_e2e` headline comparison.

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::monitor::StreamingAuc;
use weips::sample::{Workload, WorkloadConfig};

const DRIFT: f64 = 0.02;

fn main() {
    let workload_cfg = WorkloadConfig {
        ids_per_field: 2_000,
        zipf_s: 1.2,
        drift_per_sec: DRIFT,
        seed: 88,
        ..Default::default()
    };
    let c = LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Fm,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 1,
            queue_partitions: 4,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        workload: workload_cfg.clone(),
        ..Default::default()
    })
    .expect("cluster (run `make artifacts` first)");
    let fields = c.spec.fields;

    // Online-train while snapshotting at increasing staleness points.
    println!("=== E8: serving AUC vs model staleness (drift {DRIFT} rad/s) ===");
    println!("training 360 steps, checkpointing every 60...");
    let mut versions = Vec::new();
    for step in 0..360u64 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
        if step % 60 == 59 {
            c.flush_sync().unwrap();
            versions.push((step, c.checkpoint().unwrap()));
        }
    }
    c.flush_sync().unwrap();
    let now_ms = c.sim_time_ms.load(std::sync::atomic::Ordering::Relaxed);

    // Evaluate every snapshot + the live model on *current* traffic.
    let mut eval_feed = Workload::new(WorkloadConfig { fields, ..workload_cfg.clone() });
    let eval: Vec<weips::sample::Sample> = eval_feed.batch(now_ms, 2_048);
    let reqs: Vec<Vec<u64>> = eval.iter().map(|s| s.ids.clone()).collect();

    println!(
        "\n{:<28} {:>14} {:>10}",
        "serving model", "staleness", "auc"
    );
    // Live (freshly synced) model.
    let mut live_auc = StreamingAuc::new();
    for (s, p) in eval.iter().zip(c.predict(&reqs).unwrap()) {
        live_auc.add(p, s.label);
    }
    println!("{:<28} {:>14} {:>10.4}", "fused online (live)", "0 steps", live_auc.auc());

    // Each checkpoint replayed into the serving side = a stale deployment.
    // (Old versions may have been GC'd by the retention policy — skip those.)
    let retained = c.store.list_versions("ctr");
    for (step, version) in versions.iter().rev() {
        if !retained.contains(version) {
            continue;
        }
        c.switch_version(*version).unwrap();
        let mut auc = StreamingAuc::new();
        for (s, p) in eval.iter().zip(c.predict(&reqs).unwrap()) {
            auc.add(p, s.label);
        }
        println!(
            "{:<28} {:>14} {:>10.4}",
            format!("checkpoint v{version}"),
            format!("{} steps", 359 - step),
            auc.auc()
        );
    }
    println!(
        "\nshape check: AUC decays monotonically (modulo noise) with staleness —\nthe freshness motivation for second-level deployment."
    );
}
