//! E9 — substrate micro-benchmarks supporting the E1/E3 analysis: queue
//! append/fetch, sparse-table pull/push scaling, codec + compression, RPC
//! round-trip (local and TCP).
//!
//! E13 — zero-copy substrate stages (the CI gate; `--smoke` /
//! `WEIPS_BENCH_SMOKE=1` shrinks sizes and skips the E9 sweeps):
//! - `framing`: vectored (`writev`-style) header+body emission vs the
//!   scratch-buffer copy path, over a drained loopback socket;
//! - `mmap_load`: mmap-backed checkpoint chunk loads vs streamed
//!   `fs::read`, pages touched so the fault cost is paid;
//! - `arena_pull`: full-row gathers against the per-stripe bump arena vs
//!   the historical boxed row store;
//! - `uring_identity`: RPC responses under `rpc_poll_mode=uring` vs the
//!   epoll backend (byte identity + availability flag).
//!
//! Every stage asserts byte identity between its zero-copy path and the
//! portable fallback — CI fails if they ever diverge. Writes
//! `BENCH_substrate.json` (CI uploads it per commit; the committed
//! baseline self-arms via tools/promote_bench_baseline.py --kind
//! substrate).

use std::io::{IoSlice, Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use weips::codec::{self, maybe_compress, Decode, Encode, Writer};
use weips::net::{Channel, PollMode, RpcOptions, RpcServer, Service};
use weips::optim::{Ftrl, Optimizer};
use weips::proto::{SparsePush, SyncBatch, SyncEntry, SyncOp};
use weips::queue::Queue;
use weips::storage::{CheckpointStore, CkptKind};
use weips::table::{RowStore, SparseTable, StripedSparseTable};
use weips::util::bench;
use weips::Result;

fn smoke() -> bool {
    std::env::var("WEIPS_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
        || std::env::args().any(|a| a == "--smoke")
}

/// E9 sweeps, unchanged: context numbers for the E13 gate, full mode only.
fn classic() {
    // -- queue ---------------------------------------------------------------
    bench::header("E9a: partitioned queue");
    let q = Queue::new(1 << 30);
    let topic = q.create_topic("bench", 4).unwrap();
    let payload = vec![7u8; 4_096];
    bench::run("append 4KiB", 100, 20_000, || {
        topic.partition(0).unwrap().append(0, payload.clone());
    });
    let p = topic.partition(0).unwrap();
    let mut offset = 0u64;
    bench::run_batched("fetch 256 records", 10, 50, 256, || {
        let recs = p.fetch(offset, 256, Duration::ZERO).unwrap();
        offset = if recs.len() < 256 { 0 } else { offset + 256 };
        std::hint::black_box(recs);
    });

    // -- sparse table -----------------------------------------------------------
    bench::header("E9b: sparse table (FTRL rows, dim 8)");
    let ftrl = Arc::new(weips::optim::Ftrl::new(Default::default()));
    for n_rows in [10_000u64, 100_000, 1_000_000] {
        let mut table = SparseTable::new("v", 8, ftrl.clone(), 1);
        let ids: Vec<u64> = (0..n_rows).collect();
        let grads = vec![0.1f32; 4096 * 8];
        let chunk: Vec<u64> = (0..4096u64).map(|i| i * (n_rows / 4096).max(1)).collect();
        // Populate.
        for big in ids.chunks(4096) {
            let g = vec![0.1f32; big.len() * 8];
            table.apply_grads(big, &g, 0);
        }
        bench::run_batched(
            &format!("apply_grads 4096 ids over {n_rows} rows (ids/s)"),
            2,
            20,
            4096,
            || {
                table.apply_grads(&chunk, &grads, 1);
            },
        );
        let mut out = vec![0.0f32; 4096 * 8];
        bench::run_batched(
            &format!("pull_slot 4096 ids over {n_rows} rows (ids/s)"),
            2,
            20,
            4096,
            || {
                table.pull_slot(&chunk, "w", 2, &mut out).unwrap();
            },
        );
    }

    // -- codec -------------------------------------------------------------------
    bench::header("E9c: codec + compression (sync batch of 4096 FTRL rows)");
    let batch = SyncBatch {
        model: "ctr".into(),
        table: "v".into(),
        shard: 0,
        seq: 1,
        created_ms: 0,
        entries: (0..4096u64)
            .map(|id| SyncEntry {
                id: id * 37,
                op: SyncOp::Upsert((0..24).map(|j| (id + j) as f32 * 0.01).collect()),
            })
            .collect(),
        dense: vec![],
    };
    let mut encoded = Vec::new();
    bench::run("encode", 5, 200, || {
        encoded = batch.to_bytes();
    });
    bench::metric("encoded size", format!("{} bytes", encoded.len()));
    bench::run("decode", 5, 200, || {
        std::hint::black_box(SyncBatch::from_bytes(&encoded).unwrap());
    });
    let mut wire = Vec::new();
    bench::run("compress (lz-fast)", 2, 50, || {
        wire = maybe_compress(&encoded);
    });
    bench::metric(
        "wire size",
        format!("{} bytes ({:.1}% of raw)", wire.len(), wire.len() as f64 / encoded.len() as f64 * 100.0),
    );

    // -- rpc ------------------------------------------------------------------------
    bench::header("E9d: RPC round-trip (SparsePush of 1024 ids)");
    struct Sink;
    impl Service for Sink {
        fn call(&self, _m: u16, payload: &[u8]) -> Result<Vec<u8>> {
            let req = SparsePush::from_bytes(payload)?;
            std::hint::black_box(&req);
            Ok(vec![1])
        }
    }
    let push = SparsePush {
        model: "ctr".into(),
        table: "w".into(),
        ids: (0..1024).collect(),
        grads: vec![0.01; 1024],
    }
    .to_bytes();
    let local = Channel::local(Arc::new(Sink));
    bench::run("local channel", 10, 2_000, || {
        local.call(2, &push).unwrap();
    });
    let server = RpcServer::serve("127.0.0.1:0", Arc::new(Sink)).unwrap();
    let remote = Channel::remote(&server.addr().to_string(), Duration::from_secs(5));
    bench::run("tcp channel (loopback)", 10, 1_000, || {
        remote.call(2, &push).unwrap();
    });
    server.shutdown();
}

/// Write `[head][body]` as one logical frame without assembling it: a
/// vectored write first, plain writes for any partial-progress tail.
fn write_frame_vectored(s: &mut TcpStream, head: &[u8], body: &[u8]) -> std::io::Result<()> {
    let mut off = 0usize;
    let total = head.len() + body.len();
    while off < total {
        let n = if off < head.len() {
            s.write_vectored(&[IoSlice::new(&head[off..]), IoSlice::new(body)])?
        } else {
            s.write(&body[off - head.len()..])?
        };
        if n == 0 {
            return Err(std::io::ErrorKind::WriteZero.into());
        }
        off += n;
    }
    Ok(())
}

/// E13a: scratch-copy framing vs vectored header+body emission over a
/// drained loopback socket. The reader verifies the first on-wire frame
/// byte-for-byte against `codec::frame` of the same payload.
fn framing(results: &mut Vec<String>) {
    bench::header("E13a: vectored vs scratch response framing");
    let payload_bytes: usize = if smoke() { 64 << 10 } else { 256 << 10 };
    let frames: usize = if smoke() { 400 } else { 2_000 };
    let payload: Vec<u8> = (0..payload_bytes).map(|i| (i.wrapping_mul(31) >> 3) as u8).collect();

    // The vectored path's header, computed once (both loops below reuse
    // it, isolating the copy cost — the CRC is identical work either way).
    let mut head = [0u8; 8];
    head[..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    head[4..].copy_from_slice(&codec::crc32(&payload).to_le_bytes());
    let scratch_frame = codec::frame(&payload);
    assert_eq!(&scratch_frame[..8], &head[..], "vectored header must match scratch framing");
    assert_eq!(&scratch_frame[8..], &payload[..], "frame body must be the payload verbatim");

    let frame_len = 8 + payload.len();
    let total = 2 * frames * frame_len;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let reader = std::thread::spawn(move || {
        let (mut conn, _) = listener.accept().unwrap();
        let mut first = vec![0u8; frame_len];
        conn.read_exact(&mut first).unwrap();
        let mut seen = frame_len;
        let mut buf = vec![0u8; 1 << 20];
        while seen < total {
            let n = conn.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            seen += n;
        }
        (first, seen)
    });
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();

    // Scratch path: assemble header+body in a reused buffer, one write —
    // exactly what the portable `finish_frame` server path does.
    let mut buf: Vec<u8> = Vec::with_capacity(frame_len);
    let t = Instant::now();
    for _ in 0..frames {
        buf.clear();
        buf.extend_from_slice(&head);
        buf.extend_from_slice(&payload);
        stream.write_all(&buf).unwrap();
    }
    let scratch_s = t.elapsed().as_secs_f64();

    // Vectored path: the same bytes, no assembly.
    let t = Instant::now();
    for _ in 0..frames {
        write_frame_vectored(&mut stream, &head, &payload).unwrap();
    }
    let vectored_s = t.elapsed().as_secs_f64();
    drop(stream);

    let (first, seen) = reader.join().unwrap();
    assert_eq!(seen, total, "reader must drain every framed byte");
    assert_eq!(first, scratch_frame, "on-wire frame must be byte-identical to scratch framing");

    let mb = (frames * frame_len) as f64 / 1e6;
    let (scratch_mb_s, vectored_mb_s) = (mb / scratch_s, mb / vectored_s);
    let win = vectored_mb_s / scratch_mb_s;
    bench::metric("scratch framing", format!("{scratch_mb_s:.0} MB/s"));
    bench::metric("vectored framing", format!("{vectored_mb_s:.0} MB/s ({win:.2}x)"));
    results.push(format!(
        r#"{{"bench":"substrate","stage":"framing","payload_bytes":{payload_bytes},"frames":{frames},"scratch_mb_s":{scratch_mb_s:.1},"vectored_mb_s":{vectored_mb_s:.1},"win":{win:.3},"byte_identical":true}}"#
    ));
}

/// E13b: mmap-backed chunk loads vs streamed `fs::read`, every page
/// touched (recovery decodes front-to-back, so the fault cost is real).
fn mmap_load(results: &mut Vec<String>) {
    bench::header("E13b: mmap vs streamed checkpoint chunk load");
    let chunk_bytes: usize = if smoke() { 4 << 20 } else { 64 << 20 };
    let iters: usize = if smoke() { 20 } else { 50 };
    let dir = std::env::temp_dir().join(format!("weips-bench-substrate-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut store = CheckpointStore::new(&dir, None);
    let payload: Vec<u8> =
        (0..chunk_bytes).map(|i| (i.wrapping_mul(2_654_435_761) >> 16) as u8).collect();
    store.save_chunk("bench", 1, 0, CkptKind::Base, &payload).unwrap();

    // Touch one byte per half-page: pays every fault without turning the
    // measurement into a pure memory-bandwidth race.
    fn touch(bytes: &[u8]) -> u64 {
        bytes.iter().step_by(2048).fold(0u64, |a, &b| a.wrapping_add(b as u64))
    }

    store.set_mmap_load(false);
    let mut streamed_sum = 0u64;
    let t = Instant::now();
    for _ in 0..iters {
        let chunk = store.load_chunk("bench", 1, 0, CkptKind::Base).unwrap();
        streamed_sum = streamed_sum.wrapping_add(touch(&chunk));
    }
    let streamed_s = t.elapsed().as_secs_f64();

    store.set_mmap_load(true);
    let mut mmap_sum = 0u64;
    let t = Instant::now();
    for _ in 0..iters {
        let chunk = store.load_chunk("bench", 1, 0, CkptKind::Base).unwrap();
        mmap_sum = mmap_sum.wrapping_add(touch(&chunk));
    }
    let mmap_s = t.elapsed().as_secs_f64();
    assert_eq!(streamed_sum, mmap_sum, "page-touch sums must agree across load paths");

    store.set_mmap_load(false);
    let a = store.load_chunk("bench", 1, 0, CkptKind::Base).unwrap();
    store.set_mmap_load(true);
    let b = store.load_chunk("bench", 1, 0, CkptKind::Base).unwrap();
    assert_eq!(&a[..], &b[..], "mmap'd chunk must be byte-identical to the streamed read");
    assert_eq!(&a[..], &payload[..], "loaded chunk must round-trip the saved payload");
    let _ = std::fs::remove_dir_all(&dir);

    let mb = (iters * chunk_bytes) as f64 / 1e6;
    let (streamed_mb_s, mmap_mb_s) = (mb / streamed_s, mb / mmap_s);
    let win = mmap_mb_s / streamed_mb_s;
    let mmap_supported = weips::util::sys::supported();
    bench::metric("streamed load", format!("{streamed_mb_s:.0} MB/s"));
    bench::metric(
        "mmap load",
        format!("{mmap_mb_s:.0} MB/s ({win:.2}x, supported={mmap_supported})"),
    );
    results.push(format!(
        r#"{{"bench":"substrate","stage":"mmap_load","chunk_bytes":{chunk_bytes},"iters":{iters},"streamed_mb_s":{streamed_mb_s:.1},"mmap_mb_s":{mmap_mb_s:.1},"win":{win:.3},"mmap_supported":{mmap_supported},"byte_identical":true}}"#
    ));
}

/// E13c: full-row gathers against the per-stripe bump arena vs the boxed
/// row store, after asserting both encode byte-identical checkpoints.
fn arena_pull(results: &mut Vec<String>) {
    bench::header("E13c: arena vs boxed row store (full-row gather)");
    let rows: u64 = if smoke() { 50_000 } else { 400_000 };
    let iters: usize = if smoke() { 100 } else { 400 };
    const BATCH: usize = 4096;
    let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(Default::default()));
    let build = |rs: RowStore| {
        let t = StripedSparseTable::with_row_store("w", 8, ftrl.clone(), 1, 8, rs);
        let ids: Vec<u64> = (0..rows).collect();
        for chunk in ids.chunks(BATCH) {
            let g = vec![0.05f32; chunk.len() * 8];
            t.apply_batch(chunk, &g, 0);
        }
        t
    };
    let arena = build(RowStore::Arena);
    let boxed = build(RowStore::Boxed);

    let mut wa = Writer::new();
    arena.encode_rows(&mut wa);
    let mut wb = Writer::new();
    boxed.encode_rows(&mut wb);
    assert_eq!(wa.as_bytes(), wb.as_bytes(), "arena and boxed checkpoints must be byte-identical");

    let width = arena.get_row(0).expect("row 0 seeded").values.len();
    let batches: Vec<Vec<u64>> =
        (0..16u64).map(|k| (0..BATCH as u64).map(|j| (k * 2_503 + j * 3) % rows).collect()).collect();

    let mut oa = vec![0.0f32; BATCH * 8];
    let mut ob = vec![0.0f32; BATCH * 8];
    arena.pull_slot(&batches[0], "w", 1, &mut oa).unwrap();
    boxed.pull_slot(&batches[0], "w", 1, &mut ob).unwrap();
    assert_eq!(oa, ob, "slot pulls must agree across row stores");

    let mut out = vec![0.0f32; BATCH * width];
    let mut time = |t: &StripedSparseTable| {
        for ids in &batches {
            t.pull_rows(ids, &mut out);
        }
        let t0 = Instant::now();
        for i in 0..iters {
            t.pull_rows(&batches[i % batches.len()], &mut out);
            std::hint::black_box(&out);
        }
        t0.elapsed().as_secs_f64()
    };
    let boxed_s = time(&boxed);
    let arena_s = time(&arena);
    let ids_per_s = |secs: f64| (iters * BATCH) as f64 / secs;
    let (boxed_ids_s, arena_ids_s) = (ids_per_s(boxed_s), ids_per_s(arena_s));
    let win = arena_ids_s / boxed_ids_s;
    let waste = arena.arena_waste_floats();
    bench::metric("boxed gather", format!("{:.2} M ids/s", boxed_ids_s / 1e6));
    bench::metric("arena gather", format!("{:.2} M ids/s ({win:.2}x, waste {waste} floats)", arena_ids_s / 1e6));
    results.push(format!(
        r#"{{"bench":"substrate","stage":"arena_pull","rows":{rows},"batch":{BATCH},"boxed_ids_s":{boxed_ids_s:.0},"arena_ids_s":{arena_ids_s:.0},"win":{win:.3},"arena_waste_floats":{waste},"byte_identical":true}}"#
    ));
}

/// E13d: the io_uring RPC backend answers byte-for-byte what the epoll
/// backend answers; records whether the kernel actually granted a ring.
fn uring_identity(results: &mut Vec<String>) {
    bench::header("E13d: io_uring vs epoll response identity");
    struct Echo;
    impl Service for Echo {
        fn call(&self, m: u16, payload: &[u8]) -> Result<Vec<u8>> {
            let mut v = Vec::with_capacity(payload.len() + 2);
            v.extend_from_slice(&m.to_le_bytes());
            v.extend_from_slice(payload);
            Ok(v)
        }
    }
    let payloads: Vec<Vec<u8>> = (0..8u8).map(|k| vec![k ^ 0x5a; 1 << (k as usize + 4)]).collect();
    let timed_calls: usize = if smoke() { 200 } else { 1_000 };
    let probe = vec![0x11u8; 4 << 10];
    let run_mode = |mode: PollMode| {
        let server = RpcServer::serve_with(
            "127.0.0.1:0",
            Arc::new(Echo),
            RpcOptions { mode, ..RpcOptions::default() },
        )
        .unwrap();
        let ch = Channel::remote(&server.addr().to_string(), Duration::from_secs(5));
        let replies: Vec<Vec<u8>> = payloads.iter().map(|p| ch.call(7, p).unwrap()).collect();
        let t = Instant::now();
        for _ in 0..timed_calls {
            std::hint::black_box(ch.call(9, &probe).unwrap());
        }
        let calls_s = timed_calls as f64 / t.elapsed().as_secs_f64();
        let resolved = server.poll_mode();
        server.shutdown();
        (resolved, replies, calls_s)
    };
    let (_, epoll_replies, epoll_calls_s) = run_mode(PollMode::Event);
    let (uring_mode, uring_replies, uring_calls_s) = run_mode(PollMode::Uring);
    assert_eq!(epoll_replies, uring_replies, "uring and epoll responses must be byte-identical");
    let uring_available = uring_mode == PollMode::Uring;
    bench::metric("epoll", format!("{epoll_calls_s:.0} calls/s"));
    bench::metric(
        "uring",
        format!("{uring_calls_s:.0} calls/s (ring granted: {uring_available})"),
    );
    results.push(format!(
        r#"{{"bench":"substrate","stage":"uring_identity","uring_available":{uring_available},"epoll_calls_s":{epoll_calls_s:.1},"uring_calls_s":{uring_calls_s:.1},"byte_identical":true}}"#
    ));
}

fn main() {
    if !smoke() {
        classic();
    }
    let mut results = Vec::new();
    framing(&mut results);
    mmap_load(&mut results);
    arena_pull(&mut results);
    uring_identity(&mut results);
    let json = format!("[\n  {}\n]\n", results.join(",\n  "));
    // Anchor to the workspace root (cargo runs benches with cwd = the
    // package root, rust/), so CI finds the artifact at a fixed path.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package has a parent dir")
        .join("BENCH_substrate.json");
    std::fs::write(&out, &json).expect("write BENCH_substrate.json");
    println!("\nwrote {} ({} records)", out.display(), results.len());
}
