//! E9 — substrate micro-benchmarks supporting the E1/E3 analysis: queue
//! append/fetch, sparse-table pull/push scaling, codec + compression, RPC
//! round-trip (local and TCP).

use std::sync::Arc;
use std::time::Duration;

use weips::codec::{maybe_compress, Decode, Encode};
use weips::net::{Channel, RpcServer, Service};
use weips::proto::{SparsePush, SyncBatch, SyncEntry, SyncOp};
use weips::queue::Queue;
use weips::table::SparseTable;
use weips::util::bench;
use weips::Result;

fn main() {
    // -- queue ---------------------------------------------------------------
    bench::header("E9a: partitioned queue");
    let q = Queue::new(1 << 30);
    let topic = q.create_topic("bench", 4).unwrap();
    let payload = vec![7u8; 4_096];
    bench::run("append 4KiB", 100, 20_000, || {
        topic.partition(0).unwrap().append(0, payload.clone());
    });
    let p = topic.partition(0).unwrap();
    let mut offset = 0u64;
    bench::run_batched("fetch 256 records", 10, 50, 256, || {
        let recs = p.fetch(offset, 256, Duration::ZERO).unwrap();
        offset = if recs.len() < 256 { 0 } else { offset + 256 };
        std::hint::black_box(recs);
    });

    // -- sparse table -----------------------------------------------------------
    bench::header("E9b: sparse table (FTRL rows, dim 8)");
    let ftrl = Arc::new(weips::optim::Ftrl::new(Default::default()));
    for n_rows in [10_000u64, 100_000, 1_000_000] {
        let mut table = SparseTable::new("v", 8, ftrl.clone(), 1);
        let ids: Vec<u64> = (0..n_rows).collect();
        let grads = vec![0.1f32; 4096 * 8];
        let chunk: Vec<u64> = (0..4096u64).map(|i| i * (n_rows / 4096).max(1)).collect();
        // Populate.
        for big in ids.chunks(4096) {
            let g = vec![0.1f32; big.len() * 8];
            table.apply_grads(big, &g, 0);
        }
        bench::run_batched(
            &format!("apply_grads 4096 ids over {n_rows} rows (ids/s)"),
            2,
            20,
            4096,
            || {
                table.apply_grads(&chunk, &grads, 1);
            },
        );
        let mut out = vec![0.0f32; 4096 * 8];
        bench::run_batched(
            &format!("pull_slot 4096 ids over {n_rows} rows (ids/s)"),
            2,
            20,
            4096,
            || {
                table.pull_slot(&chunk, "w", 2, &mut out).unwrap();
            },
        );
    }

    // -- codec -------------------------------------------------------------------
    bench::header("E9c: codec + compression (sync batch of 4096 FTRL rows)");
    let batch = SyncBatch {
        model: "ctr".into(),
        table: "v".into(),
        shard: 0,
        seq: 1,
        created_ms: 0,
        entries: (0..4096u64)
            .map(|id| SyncEntry {
                id: id * 37,
                op: SyncOp::Upsert((0..24).map(|j| (id + j) as f32 * 0.01).collect()),
            })
            .collect(),
        dense: vec![],
    };
    let mut encoded = Vec::new();
    bench::run("encode", 5, 200, || {
        encoded = batch.to_bytes();
    });
    bench::metric("encoded size", format!("{} bytes", encoded.len()));
    bench::run("decode", 5, 200, || {
        std::hint::black_box(SyncBatch::from_bytes(&encoded).unwrap());
    });
    let mut wire = Vec::new();
    bench::run("compress (lz-fast)", 2, 50, || {
        wire = maybe_compress(&encoded);
    });
    bench::metric(
        "wire size",
        format!("{} bytes ({:.1}% of raw)", wire.len(), wire.len() as f64 / encoded.len() as f64 * 100.0),
    );

    // -- rpc ------------------------------------------------------------------------
    bench::header("E9d: RPC round-trip (SparsePush of 1024 ids)");
    struct Sink;
    impl Service for Sink {
        fn call(&self, _m: u16, payload: &[u8]) -> Result<Vec<u8>> {
            let req = SparsePush::from_bytes(payload)?;
            std::hint::black_box(&req);
            Ok(vec![1])
        }
    }
    let push = SparsePush {
        model: "ctr".into(),
        table: "w".into(),
        ids: (0..1024).collect(),
        grads: vec![0.01; 1024],
    }
    .to_bytes();
    let local = Channel::local(Arc::new(Sink));
    bench::run("local channel", 10, 2_000, || {
        local.call(2, &push).unwrap();
    });
    let server = RpcServer::serve("127.0.0.1:0", Arc::new(Sink)).unwrap();
    let remote = Channel::remote(&server.addr().to_string(), Duration::from_secs(5));
    bench::run("tcp channel (loopback)", 10, 1_000, || {
        remote.call(2, &push).unwrap();
    });
}
