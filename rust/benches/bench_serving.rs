//! E12 — serving read path: hot-id cache latency and throughput, and
//! the coherence guarantee that makes the cache safe to run in
//! production. Artifact-free (runs everywhere); `--smoke` /
//! `WEIPS_BENCH_SMOKE=1` shrinks sizes for the CI stage.
//!
//! Asserted invariants (CI fails if they break):
//! - cached pulls are **byte-identical** to uncached pulls over the same
//!   request stream;
//! - at a cumulative hit rate >= 50%, the cached p99 pull latency is at
//!   least 2x better than the uncached path on the same hot batches;
//! - one-tick freshness: an update applied to the serving tables and
//!   announced through the scatter tap is visible to the very next
//!   cached pull — no TTL window, ever.
//!
//! Writes `BENCH_serving.json` (CI uploads it per commit; the committed
//! baseline self-arms via tools/promote_bench_baseline.py --kind serving).

use std::sync::Arc;
use std::time::Instant;

use weips::net::Channel;
use weips::optim::{Ftrl, FtrlHyper, Optimizer};
use weips::proto::{SyncBatch, SyncEntry, SyncOp};
use weips::replica::{BalancePolicy, ReplicaGroup};
use weips::server::slave::{SlaveService, SlaveShard};
use weips::sync::{Router, ScatterTap, ServingWeights};
use weips::util::bench;
use weips::worker::{HotIdCache, SlaveClient, SlaveEndpoint};

const SHARDS: u32 = 2;
const REPLICAS: u32 = 2;
const BATCH: usize = 64;
const HOT_SET: u64 = 512;

fn smoke() -> bool {
    std::env::var("WEIPS_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
        || std::env::args().any(|a| a == "--smoke")
}

fn fleet() -> (SlaveClient, Vec<Vec<Arc<SlaveShard>>>) {
    let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
    let mut groups = Vec::new();
    let mut all = Vec::new();
    for s in 0..SHARDS {
        let mut eps = Vec::new();
        let mut reps = Vec::new();
        for r in 0..REPLICAS {
            let shard = Arc::new(SlaveShard::new(
                s,
                r,
                "ctr",
                vec![("w".into(), 1)],
                vec![("bias".into(), 1)],
                Arc::new(ServingWeights::new(vec![("w".into(), ftrl.clone(), 1)])),
                Router::new(SHARDS),
            ));
            let ch = Channel::local(Arc::new(SlaveService { shard: shard.clone() }));
            eps.push(Arc::new(SlaveEndpoint::local(ch, shard.clone())));
            reps.push(shard);
        }
        groups.push(Arc::new(ReplicaGroup::new(eps, BalancePolicy::RoundRobin)));
        all.push(reps);
    }
    (SlaveClient::new("ctr", groups), all)
}

/// Seed `rows` serving rows (value = id as f32) into every replica.
fn seed(slaves: &[Vec<Arc<SlaveShard>>], rows: u64) {
    let router = Router::new(slaves.len() as u32);
    let mut buckets: Vec<Vec<SyncEntry>> = vec![Vec::new(); slaves.len()];
    for id in 0..rows {
        buckets[router.shard_of(id) as usize]
            .push(SyncEntry { id, op: SyncOp::Upsert(vec![2.0, 1.0, id as f32]) });
    }
    for (s, entries) in buckets.into_iter().enumerate() {
        for chunk in entries.chunks(4096) {
            let batch = SyncBatch {
                model: "ctr".into(),
                table: "w".into(),
                shard: 0,
                seq: 0,
                created_ms: 0,
                entries: chunk.to_vec(),
                dense: vec![],
            };
            for replica in &slaves[s] {
                replica.apply_batch(&batch).unwrap();
            }
        }
    }
}

/// Rotating window over the hot set: request `i` pulls `BATCH` hot ids.
fn hot_batch(i: usize) -> Vec<u64> {
    (0..BATCH as u64).map(|j| (i as u64 * 7 + j) % HOT_SET).collect()
}

fn pctl(sorted: &[u64], q: f64) -> u64 {
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

/// Per-pull latencies in ns, sorted ascending.
fn measure(client: &SlaveClient, reqs: usize) -> Vec<u64> {
    let mut samples = Vec::with_capacity(reqs);
    for i in 0..reqs {
        let ids = hot_batch(i);
        let t = Instant::now();
        std::hint::black_box(client.sparse_pull("w", &ids).unwrap());
        samples.push(t.elapsed().as_nanos() as u64);
    }
    samples.sort_unstable();
    samples
}

/// E12a: cached vs uncached p50/p99 on identical hot-batch streams, with
/// the byte-identity and the 2x-p99 acceptance gates.
fn pull_latency(rows: u64, reqs: usize, results: &mut Vec<String>) {
    bench::header("E12a: cached vs uncached pull latency");
    let (uncached, slaves_u) = fleet();
    seed(&slaves_u, rows);
    let (mut cached, slaves_c) = fleet();
    seed(&slaves_c, rows);
    let cache = HotIdCache::new(1 << 20);
    cached.set_cache(cache.clone());

    // Byte identity over a mixed probe (hot + tail ids).
    let probe: Vec<u64> = (0..BATCH as u64).map(|j| j * (rows / BATCH as u64).max(1)).collect();
    let a = uncached.sparse_pull("w", &probe).unwrap();
    let b = cached.sparse_pull("w", &probe).unwrap(); // fill
    let c = cached.sparse_pull("w", &probe).unwrap(); // hits
    assert_eq!(a, b, "cached fill path must be byte-identical");
    assert_eq!(a, c, "cached hit path must be byte-identical");

    let base = measure(&uncached, reqs);
    // Warm the hot set, then measure the steady state.
    for i in 0..(HOT_SET as usize / BATCH + 1) {
        cached.sparse_pull("w", &hot_batch(i)).unwrap();
    }
    let hot = measure(&cached, reqs);

    let (u50, u99) = (pctl(&base, 0.50), pctl(&base, 0.99));
    let (c50, c99) = (pctl(&hot, 0.50), pctl(&hot, 0.99));
    let hit_rate = cache.hit_rate();
    assert!(hit_rate >= 0.5, "hot-set hit rate only {hit_rate:.3}");
    assert!(
        c99 * 2 <= u99,
        "cached p99 {c99} ns not 2x better than uncached {u99} ns at hit rate {hit_rate:.3}"
    );
    bench::metric(
        &format!("uncached ({rows} rows)"),
        format!("p50 {:.1} us, p99 {:.1} us", u50 as f64 / 1e3, u99 as f64 / 1e3),
    );
    bench::metric(
        &format!("cached (hit rate {hit_rate:.3})"),
        format!(
            "p50 {:.1} us, p99 {:.1} us ({:.1}x at p99)",
            c50 as f64 / 1e3,
            c99 as f64 / 1e3,
            u99 as f64 / c99.max(1) as f64
        ),
    );
    results.push(format!(
        r#"{{"bench":"serving","stage":"pull_latency","rows":{rows},"requests":{reqs},"batch":{BATCH},"uncached_p50_us":{:.3},"uncached_p99_us":{:.3},"cached_p50_us":{:.3},"cached_p99_us":{:.3},"hit_rate":{hit_rate:.4},"p99_speedup":{:.3},"byte_identical":true}}"#,
        u50 as f64 / 1e3,
        u99 as f64 / 1e3,
        c50 as f64 / 1e3,
        c99 as f64 / 1e3,
        u99 as f64 / c99.max(1) as f64
    ));
}

/// E12b: pull throughput vs concurrent predictor threads, cached off/on.
fn throughput(rows: u64, per_thread: usize, results: &mut Vec<String>) {
    bench::header("E12b: throughput vs concurrent predictors");
    for cached_on in [false, true] {
        let (mut client, slaves) = fleet();
        seed(&slaves, rows);
        let cache = HotIdCache::new(1 << 20);
        if cached_on {
            client.set_cache(cache.clone());
            for i in 0..(HOT_SET as usize / BATCH + 1) {
                client.sparse_pull("w", &hot_batch(i)).unwrap();
            }
        }
        let client = Arc::new(client);
        for threads in [1usize, 2, 4] {
            let t0 = Instant::now();
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let client = client.clone();
                    std::thread::spawn(move || {
                        for i in 0..per_thread {
                            std::hint::black_box(
                                client.sparse_pull("w", &hot_batch(t * per_thread + i)).unwrap(),
                            );
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            let secs = t0.elapsed().as_secs_f64();
            let pulls_per_sec = (threads * per_thread) as f64 / secs;
            bench::metric(
                &format!("{threads} thread(s), cache {}", if cached_on { "on" } else { "off" }),
                format!("{:.0} pulls/s ({:.0} ids/s)", pulls_per_sec, pulls_per_sec * BATCH as f64),
            );
            results.push(format!(
                r#"{{"bench":"serving","stage":"throughput","threads":{threads},"cached":{cached_on},"pulls_per_sec":{pulls_per_sec:.1},"hit_rate":{:.4}}}"#,
                cache.hit_rate()
            ));
        }
    }
}

/// E12c: one-tick freshness — an update applied to the replicas and
/// announced through the scatter tap is visible to the next cached pull.
fn freshness(results: &mut Vec<String>) {
    bench::header("E12c: one-tick freshness under the cache");
    let (mut client, slaves) = fleet();
    seed(&slaves, HOT_SET);
    let cache = HotIdCache::new(1 << 16);
    client.set_cache(cache.clone());
    let ids = hot_batch(0);
    client.sparse_pull("w", &ids).unwrap(); // fill
    let hot = ids[0];
    let shard = Router::new(SHARDS).shard_of(hot) as usize;
    let update = SyncBatch {
        model: "ctr".into(),
        table: "w".into(),
        shard: 0,
        seq: 1,
        created_ms: 0,
        entries: vec![SyncEntry { id: hot, op: SyncOp::Upsert(vec![2.0, 1.0, 1e6]) }],
        dense: vec![],
    };
    for replica in &slaves[shard] {
        replica.apply_batch(&update).unwrap();
    }
    cache.on_applied(std::slice::from_ref(&update));
    let (_, vals) = client.sparse_pull("w", &ids).unwrap();
    assert_eq!(vals[0], 1e6, "update not visible within one tick");
    assert!(cache.stats.invalidations.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    bench::metric("freshness", "streamed update visible on the next cached pull");
    results.push(
        r#"{"bench":"serving","stage":"freshness","one_tick":true}"#.to_string(),
    );
}

fn main() {
    let (rows, reqs, per_thread) =
        if smoke() { (20_000u64, 2_000usize, 500usize) } else { (200_000u64, 10_000usize, 2_500usize) };
    let mut results = Vec::new();
    pull_latency(rows, reqs, &mut results);
    throughput(rows, per_thread, &mut results);
    freshness(&mut results);
    let json = format!("[\n  {}\n]\n", results.join(",\n  "));
    // Anchor to the workspace root (cargo runs benches with cwd = the
    // package root, rust/), so CI finds the artifact at a fixed path.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package has a parent dir")
        .join("BENCH_serving.json");
    std::fs::write(&out, &json).expect("write BENCH_serving.json");
    println!("\nwrote {} ({} records)", out.display(), results.len());
}
