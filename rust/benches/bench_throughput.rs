//! E3 — heterogeneous requests (§1.2.2): "the training stage is sensitive
//! to the throughput with a large batch size ... the prediction serving
//! stage is more sensitive to delay time, carry high QPS, set small batch
//! size". One fused system must sustain both profiles.

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::util::bench;

fn cluster() -> LocalCluster {
    LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Fm,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 2,
            queue_partitions: 4,
            gather_mode: GatherMode::Threshold(8192),
            ..Default::default()
        },
        workload: weips::sample::WorkloadConfig {
            ids_per_field: 10_000,
            seed: 33,
            ..Default::default()
        },
        ..Default::default()
    })
    .expect("cluster (run `make artifacts` first)")
}

fn main() {
    let c = cluster();
    let b_train = c.spec.batch_train;
    let b_pred = c.spec.batch_predict;
    // Warm every module + populate tables.
    for _ in 0..10 {
        c.train_step().unwrap();
    }
    c.flush_sync().unwrap();

    bench::header("E3a: training profile (throughput, large batches)");
    bench::run_batched(
        &format!("train_step end-to-end (batch={b_train}, samples/s)"),
        3,
        60,
        b_train as u64,
        || {
            c.train_step().unwrap();
        },
    );
    // Isolate the PS interaction: pull + push without the compute graph.
    let reqs = c.serving_requests(b_train);
    let flat: Vec<u64> = reqs.iter().flatten().copied().collect();
    let master_client = {
        use weips::net::Channel;
        use weips::server::master::MasterService;
        let chans: Vec<Channel> = c
            .masters
            .iter()
            .map(|m| Channel::local(std::sync::Arc::new(MasterService { shard: m.clone(), store: None })))
            .collect();
        weips::worker::ShardedClient::new("ctr", chans)
    };
    bench::run_batched(
        &format!("sparse pull w+v ({} ids, ids/s)", flat.len()),
        3,
        100,
        flat.len() as u64,
        || {
            master_client.sparse_pull("w", &flat, "w").unwrap();
            master_client.sparse_pull("v", &flat, "w").unwrap();
        },
    );
    let grads1 = vec![0.01f32; flat.len()];
    let grads8 = vec![0.01f32; flat.len() * c.spec.dim];
    bench::run_batched(
        &format!("sparse push w+v ({} ids, ids/s)", flat.len()),
        3,
        100,
        flat.len() as u64,
        || {
            master_client.sparse_push("w", &flat, &grads1).unwrap();
            master_client.sparse_push("v", &flat, &grads8).unwrap();
        },
    );

    bench::header("E3b: serving profile (latency, small batches, failover on)");
    c.flush_sync().unwrap();
    for probe_batch in [1usize, 4, 16] {
        let reqs = c.serving_requests(probe_batch);
        bench::run(
            &format!("predict batch={probe_batch} (request latency)"),
            5,
            200,
            || {
                c.predict(&reqs).unwrap();
            },
        );
    }
    let _ = b_pred;

    bench::header("E3c: mixed traffic (trainer + predictor interleaved)");
    let reqs = c.serving_requests(4);
    bench::run("1 train_step + 8 predict(4) interleaved", 2, 30, || {
        c.train_step().unwrap();
        for _ in 0..8 {
            c.predict(&reqs).unwrap();
        }
        c.sync_tick().unwrap();
    });
    println!(
        "\nshape check: serving p99 stays in the low-millisecond band even while\ntraining batches stream through the same fused cluster — the paper's\nhybrid-profile requirement."
    );
}
