//! E3 — heterogeneous requests (§1.2.2): "the training stage is sensitive
//! to the throughput with a large batch size ... the prediction serving
//! stage is more sensitive to delay time, carry high QPS, set small batch
//! size". One fused system must sustain both profiles.
//!
//! Also E3d: the lock-striping scaling curve — multi-threaded contended
//! push/pull against one `StripedSparseTable` at 1 vs N stripes. This
//! scenario needs no AOT artifacts and runs first; the cluster scenarios
//! below are skipped when artifacts are absent.

use std::sync::Arc;

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::table::StripedSparseTable;
use weips::util::bench;

/// E3d: N writer + N reader threads hammer one table; every thread works
/// a disjoint id range but all ranges hash across all stripes, so a
/// single-lock table serializes everything while a striped one scales.
/// Emits both the human table row and the one-line JSON shape.
fn contended_push_pull() {
    bench::header("E3d: contended push/pull vs lock stripes (dim 8, FTRL)");
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8).min(8);
    let ids_per_thread = 2_048u64;
    let rounds = 30u64;
    let mut baseline_ops = 0.0f64;
    for stripes in [1usize, 2, 8, 32] {
        let ftrl = Arc::new(weips::optim::Ftrl::new(Default::default()));
        let table = Arc::new(StripedSparseTable::new("v", 8, ftrl, 1, stripes));
        // Pre-populate so the measurement is steady-state updates.
        for t in 0..threads as u64 {
            let ids: Vec<u64> = (t * ids_per_thread..(t + 1) * ids_per_thread).collect();
            table.apply_batch(&ids, &vec![0.1f32; ids.len() * 8], 0);
        }
        let start = std::time::Instant::now();
        let mut handles = Vec::new();
        for t in 0..threads as u64 {
            let table = table.clone();
            handles.push(std::thread::spawn(move || {
                let ids: Vec<u64> = (t * ids_per_thread..(t + 1) * ids_per_thread).collect();
                let grads = vec![0.1f32; ids.len() * 8];
                let mut out = vec![0.0f32; ids.len() * 8];
                for round in 0..rounds {
                    if (t + round) % 2 == 0 {
                        table.apply_batch(&ids, &grads, round);
                    } else {
                        table.pull_slot(&ids, "w", round, &mut out).unwrap();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let elapsed = start.elapsed();
        let total_ops = threads as u64 * rounds * ids_per_thread;
        let ops_per_sec = total_ops as f64 / elapsed.as_secs_f64();
        if stripes == 1 {
            baseline_ops = ops_per_sec;
        }
        let speedup = if baseline_ops > 0.0 { ops_per_sec / baseline_ops } else { 1.0 };
        bench::metric(
            &format!("{threads} threads, {stripes:>2} stripes (row-ops/s)"),
            format!("{ops_per_sec:>14.0}   ({speedup:.2}x vs 1 stripe)"),
        );
        bench::json_metric(
            "contended_push_pull",
            &[
                ("threads", threads.to_string()),
                ("stripes", stripes.to_string()),
                ("ids_per_thread", ids_per_thread.to_string()),
                ("rounds", rounds.to_string()),
                ("ops_per_sec", format!("{ops_per_sec:.0}")),
                ("speedup_vs_1_stripe", format!("{speedup:.3}")),
            ],
        );
    }
}

fn cluster() -> LocalCluster {
    LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Fm,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 2,
            queue_partitions: 4,
            gather_mode: GatherMode::Threshold(8192),
            ..Default::default()
        },
        workload: weips::sample::WorkloadConfig {
            ids_per_field: 10_000,
            seed: 33,
            ..Default::default()
        },
        ..Default::default()
    })
    .expect("cluster (run `make artifacts` first)")
}

fn main() {
    contended_push_pull();

    if !weips::runtime::default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping cluster scenarios: run `make artifacts` first");
        return;
    }
    let c = cluster();
    let b_train = c.spec.batch_train;
    let b_pred = c.spec.batch_predict;
    // Warm every module + populate tables.
    for _ in 0..10 {
        c.train_step().unwrap();
    }
    c.flush_sync().unwrap();

    bench::header("E3a: training profile (throughput, large batches)");
    bench::run_batched(
        &format!("train_step end-to-end (batch={b_train}, samples/s)"),
        3,
        60,
        b_train as u64,
        || {
            c.train_step().unwrap();
        },
    );
    // Isolate the PS interaction: pull + push without the compute graph.
    let reqs = c.serving_requests(b_train);
    let flat: Vec<u64> = reqs.iter().flatten().copied().collect();
    let master_client = {
        use weips::net::Channel;
        use weips::server::master::MasterService;
        let chans: Vec<Channel> = c
            .masters
            .iter()
            .map(|m| Channel::local(std::sync::Arc::new(MasterService { shard: m.clone(), store: None })))
            .collect();
        weips::worker::ShardedClient::new("ctr", chans)
    };
    bench::run_batched(
        &format!("sparse pull w+v ({} ids, ids/s)", flat.len()),
        3,
        100,
        flat.len() as u64,
        || {
            master_client.sparse_pull("w", &flat, "w").unwrap();
            master_client.sparse_pull("v", &flat, "w").unwrap();
        },
    );
    let grads1 = vec![0.01f32; flat.len()];
    let grads8 = vec![0.01f32; flat.len() * c.spec.dim];
    bench::run_batched(
        &format!("sparse push w+v ({} ids, ids/s)", flat.len()),
        3,
        100,
        flat.len() as u64,
        || {
            master_client.sparse_push("w", &flat, &grads1).unwrap();
            master_client.sparse_push("v", &flat, &grads8).unwrap();
        },
    );

    bench::header("E3b: serving profile (latency, small batches, failover on)");
    c.flush_sync().unwrap();
    for probe_batch in [1usize, 4, 16] {
        let reqs = c.serving_requests(probe_batch);
        bench::run(
            &format!("predict batch={probe_batch} (request latency)"),
            5,
            200,
            || {
                c.predict(&reqs).unwrap();
            },
        );
    }
    let _ = b_pred;

    bench::header("E3c: mixed traffic (trainer + predictor interleaved)");
    let reqs = c.serving_requests(4);
    bench::run("1 train_step + 8 predict(4) interleaved", 2, 30, || {
        c.train_step().unwrap();
        for _ in 0..8 {
            c.predict(&reqs).unwrap();
        }
        c.sync_tick().unwrap();
    });
    println!(
        "\nshape check: serving p99 stays in the low-millisecond band even while\ntraining batches stream through the same fused cluster — the paper's\nhybrid-profile requirement."
    );
}
