//! E11 — elastic resharding: live-migration pause vs slot count,
//! catch-up convergence under concurrent pushes, and routing-epoch
//! determinism. Artifact-free (runs everywhere); `--smoke` /
//! `WEIPS_BENCH_SMOKE=1` shrinks sizes for the CI stage.
//!
//! Asserted invariants (CI fails if they break):
//! - a migrated cluster's logical state is **byte-identical** to a
//!   no-migration control run fed the same event stream;
//! - catch-up converges: the last dirty round is no larger than the base
//!   pass even with a pusher hammering the donor throughout;
//! - rebalance plans are deterministic, minimal-disruption, and survive
//!   an encode/decode round trip bit-for-bit.
//!
//! Writes `BENCH_reshard.json` (CI uploads it per commit; the committed
//! baseline self-arms via tools/promote_bench_baseline.py --kind reshard).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use weips::config::{ModelKind, ModelSpec};
use weips::net::Channel;
use weips::reshard::{
    balance_moves, pick_donor_slots, MigrationOpts, SlotMap, SlotSet, SlotTransfer,
};
use weips::runtime::ModelConfig;
use weips::server::master::{MasterService, MasterShard};
use weips::sync::Router;
use weips::table::DeltaRow;
use weips::util::bench;
use weips::util::clock::ManualClock;
use weips::worker::ShardedClient;

const UNIVERSE: usize = 256;
const MASTERS: u32 = 4;

fn smoke() -> bool {
    std::env::var("WEIPS_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
        || std::env::args().any(|a| a == "--smoke")
}

fn mini_spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 4,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Lr, &cfg)
}

struct Fleet {
    router: Router,
    masters: Vec<Arc<MasterShard>>,
    client: Arc<ShardedClient>,
}

fn fleet() -> Fleet {
    let clock = Arc::new(ManualClock::new(0));
    let router = Router::with_slots(MASTERS, UNIVERSE);
    let masters: Vec<Arc<MasterShard>> = (0..MASTERS)
        .map(|i| {
            let m = Arc::new(
                MasterShard::with_stripes(i, mini_spec(), None, 1, 8, clock.clone()).unwrap(),
            );
            m.set_route_guard(router.clone());
            m
        })
        .collect();
    let channels: Vec<Channel> = masters
        .iter()
        .map(|m| Channel::local(Arc::new(MasterService { shard: m.clone(), store: None })))
        .collect();
    let client = Arc::new(ShardedClient::with_router("ctr", channels, router.clone()));
    Fleet { router, masters, client }
}

fn load(f: &Fleet, rows: u64) {
    let ids: Vec<u64> = (0..rows).collect();
    for chunk in ids.chunks(4096) {
        let grads: Vec<f32> = chunk.iter().map(|&id| (id % 13) as f32 * 0.1 + 0.2).collect();
        f.client.sparse_push("w", chunk, &grads).unwrap();
    }
}

fn spawn_pusher(
    f: &Fleet,
    rows: u64,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    let client = f.client.clone();
    std::thread::spawn(move || {
        let mut round = 0u64;
        while !stop.load(Ordering::Acquire) {
            let base = (round * 997) % rows;
            let n = 1024.min(rows);
            let ids: Vec<u64> = (0..n).map(|i| (base + i) % rows).collect();
            let grads = vec![0.3f32; ids.len()];
            client.sparse_push("w", &ids, &grads).unwrap();
            round += 1;
        }
    })
}

/// Union of every shard's rows, sorted by id per table — the logical
/// model (values + update counts).
fn logical_state(f: &Fleet) -> Vec<Vec<DeltaRow>> {
    let full = SlotSet::full(UNIVERSE);
    let n_tables = f.masters[0].collect_slot_delta(None, &full).len();
    let mut per_table: Vec<Vec<DeltaRow>> = vec![Vec::new(); n_tables];
    for m in &f.masters {
        for (ti, (_, rows, _)) in m.collect_slot_delta(None, &full).into_iter().enumerate() {
            per_table[ti].extend(rows);
        }
    }
    for rows in &mut per_table {
        rows.sort_by_key(|r| r.id);
    }
    per_table
}

fn cutover(f: &Fleet, slots: &[u16], recipient: u32) {
    let map = f.router.snapshot();
    let moves: Vec<(u16, u32)> = slots.iter().map(|&s| (s, recipient)).collect();
    f.router.install(map.rebalanced(&moves).unwrap()).unwrap();
}

/// E11a: sealed-window pause and total migration time vs slots moved,
/// with a pusher hammering the fleet throughout.
fn migration_pause(rows: u64, results: &mut Vec<String>) {
    bench::header("E11a: live migration pause vs slot count");
    for k in [8usize, 32, 64] {
        let f = fleet();
        load(&f, rows);
        let stop = Arc::new(AtomicBool::new(false));
        let pusher = spawn_pusher(&f, rows, stop.clone());
        let map = f.router.snapshot();
        let slots = pick_donor_slots(&map, 3, k).unwrap();
        let t_total = Instant::now();
        let mut t = SlotTransfer::new(&f.masters[3], &f.masters[1], &slots, UNIVERSE).unwrap();
        t.run_catchup(&MigrationOpts::default()).unwrap();
        let t_seal = Instant::now();
        t.seal().unwrap();
        t.final_sync().unwrap();
        cutover(&f, &slots, 1);
        let report = t.finish().unwrap();
        let sealed_ms = t_seal.elapsed().as_secs_f64() * 1e3;
        let total_ms = t_total.elapsed().as_secs_f64() * 1e3;
        stop.store(true, Ordering::Release);
        pusher.join().unwrap();
        assert!(report.purged_rows > 0, "migration moved nothing");
        bench::metric(
            &format!("move {k} slots ({} rows)", report.purged_rows),
            format!(
                "sealed window {sealed_ms:.2} ms, total {total_ms:.2} ms, \
                 base {} rows, {} catch-up rounds, {} rows in the sealed window",
                report.base_rows, report.catchup_rounds, report.final_rows
            ),
        );
        results.push(format!(
            r#"{{"bench":"reshard","stage":"migration_pause","slots_moved":{k},"rows":{rows},"sealed_ms":{sealed_ms:.3},"total_ms":{total_ms:.3},"base_rows":{},"catchup_rounds":{},"final_rows":{},"purged_rows":{}}}"#,
            report.base_rows, report.catchup_rounds, report.final_rows, report.purged_rows
        ));
    }
}

/// E11b: catch-up convergence under a continuous pusher — the last dirty
/// round must not exceed the base pass.
fn catchup_convergence(rows: u64, results: &mut Vec<String>) {
    bench::header("E11b: catch-up convergence under live pushes");
    let f = fleet();
    load(&f, rows);
    let stop = Arc::new(AtomicBool::new(false));
    let pusher = spawn_pusher(&f, rows, stop.clone());
    let map = f.router.snapshot();
    let slots = map.slots_of(3);
    let mut t = SlotTransfer::new(&f.masters[3], &f.masters[1], &slots, UNIVERSE).unwrap();
    t.run_catchup(&MigrationOpts { max_catchup_rounds: 8, catchup_threshold: 64 }).unwrap();
    t.seal().unwrap();
    t.final_sync().unwrap();
    cutover(&f, &slots, 1);
    let report = t.finish().unwrap();
    stop.store(true, Ordering::Release);
    pusher.join().unwrap();
    assert!(report.base_rows > 0);
    assert!(
        report.last_round_rows <= report.base_rows,
        "catch-up diverged: last round {} > base {}",
        report.last_round_rows,
        report.base_rows
    );
    bench::metric(
        "catch-up",
        format!(
            "base {} rows -> {} rounds ({} rows total), last round {} rows, sealed window {} rows",
            report.base_rows,
            report.catchup_rounds,
            report.catchup_rows,
            report.last_round_rows,
            report.final_rows
        ),
    );
    results.push(format!(
        r#"{{"bench":"reshard","stage":"catchup","rows":{rows},"base_rows":{},"rounds":{},"catchup_rows":{},"last_round_rows":{},"final_rows":{}}}"#,
        report.base_rows,
        report.catchup_rounds,
        report.catchup_rows,
        report.last_round_rows,
        report.final_rows
    ));
}

/// E11c: a full live migration produces a logical state byte-identical
/// to a control run fed the same event stream with no migration.
fn migrate_identity(results: &mut Vec<String>) {
    bench::header("E11c: migrated state == control state (byte-identical)");
    let control = fleet();
    let live = fleet();
    let ids: Vec<u64> = (0..2_000).collect();
    let push = |f: &Fleet, scale: f32| {
        for chunk in ids.chunks(512) {
            let grads: Vec<f32> =
                chunk.iter().map(|&id| (id % 7) as f32 * 0.1 + scale).collect();
            f.client.sparse_push("w", chunk, &grads).unwrap();
        }
    };
    push(&control, 0.5);
    push(&live, 0.5);
    let map = live.router.snapshot();
    let slots = map.slots_of(3);
    let mut t = SlotTransfer::new(&live.masters[3], &live.masters[1], &slots, UNIVERSE).unwrap();
    t.run_catchup(&MigrationOpts::default()).unwrap();
    // Dirty window between catch-up and seal: drained by the final delta.
    push(&control, 0.25);
    push(&live, 0.25);
    t.seal().unwrap();
    t.final_sync().unwrap();
    cutover(&live, &slots, 1);
    t.finish().unwrap();
    // Post-cutover traffic routes to the recipient.
    push(&control, 0.125);
    push(&live, 0.125);
    let identical = logical_state(&control) == logical_state(&live);
    assert!(identical, "migrated cluster state != control state");
    assert_eq!(live.masters[3].total_rows(), 0, "donor not drained");
    bench::metric("byte identity", "migrated state == control state (values + metadata)");
    results.push(format!(
        r#"{{"bench":"reshard","stage":"migrate_identity","ids":{},"byte_identical":true,"donor_drained":true}}"#,
        ids.len()
    ));
}

/// E11d: routing-epoch determinism — plans are deterministic and
/// minimal, maps round-trip bit-for-bit.
fn routing_determinism(results: &mut Vec<String>) {
    bench::header("E11d: routing-epoch determinism");
    let map = SlotMap::uniform(UNIVERSE, MASTERS);
    let moves = balance_moves(&map, MASTERS + 2);
    let a = map.rebalanced(&moves).unwrap();
    let b = map.rebalanced(&moves).unwrap();
    let identical = a == b
        && balance_moves(&map, MASTERS + 2) == moves
        && SlotMap::from_bytes(&a.to_bytes()).unwrap() == a
        && a.to_bytes() == b.to_bytes();
    let changed =
        (0..UNIVERSE as u16).filter(|&s| a.shard_of_slot(s) != map.shard_of_slot(s)).count();
    let minimal = changed == moves.len();
    assert!(identical, "rebalance not deterministic / round-trip unstable");
    assert!(minimal, "rebalance disrupted unmoved slots");
    let stats = bench::run("split 100k ids through the two-level map", 2, 20, || {
        let r = Router::with_slots(MASTERS, UNIVERSE);
        let ids: Vec<u64> = (0..100_000).collect();
        std::hint::black_box(r.split_ids(&ids));
    });
    results.push(format!(
        r#"{{"bench":"reshard","stage":"determinism","identical":true,"minimal_disruption":true,"moves":{},"split_100k_ms":{:.3}}}"#,
        moves.len(),
        stats.mean_ns / 1e6
    ));
}

fn main() {
    let rows = if smoke() { 20_000u64 } else { 100_000u64 };
    let mut results = Vec::new();
    migration_pause(rows, &mut results);
    catchup_convergence(rows, &mut results);
    migrate_identity(&mut results);
    routing_determinism(&mut results);
    let json = format!("[\n  {}\n]\n", results.join(",\n  "));
    // Anchor to the workspace root (cargo runs benches with cwd = the
    // package root, rust/), so CI finds the artifact at a fixed path.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package has a parent dir")
        .join("BENCH_reshard.json");
    std::fs::write(&out, &json).expect("write BENCH_reshard.json");
    println!("\nwrote {} ({} records)", out.display(), results.len());
}
