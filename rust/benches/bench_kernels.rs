//! E7 — L1/L2 hot path: the AOT Pallas FTRL kernel through PJRT vs the
//! scalar Rust implementation, and the compiled model graphs' execution
//! cost (the compute half of every train/predict step).

use std::sync::Arc;

use weips::optim::{BatchedFtrl, Ftrl, FtrlHyper, Optimizer};
use weips::runtime::{default_artifacts_dir, Engine, Tensor};
use weips::util::bench;
use weips::util::Rng;

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let engine = Arc::new(Engine::load(dir).unwrap());
    let cfg = engine.config().clone();
    let scalar = Ftrl::new(FtrlHyper {
        alpha: cfg.ftrl_alpha,
        beta: cfg.ftrl_beta,
        l1: cfg.ftrl_l1,
        l2: cfg.ftrl_l2,
    });

    bench::header("E7a: FTRL update — scalar Rust vs AOT Pallas kernel (per-row cost)");
    for dim in [1usize, cfg.dim] {
        let batched = BatchedFtrl::new(engine.clone(), dim).unwrap();
        for rows in [1_024usize, 8_192, 32_768] {
            let mut rng = Rng::new(1);
            let g: Vec<f32> = (0..rows * dim).map(|_| rng.gen_f32() - 0.5).collect();
            // Scalar path.
            let mut scalar_rows: Vec<Vec<f32>> =
                (0..rows).map(|_| vec![0.0f32; 3 * dim]).collect();
            bench::run_batched(
                &format!("scalar  d={dim} rows={rows} (rows/s)"),
                1,
                8,
                rows as u64,
                || {
                    for (i, row) in scalar_rows.iter_mut().enumerate() {
                        scalar.apply(row, &g[i * dim..(i + 1) * dim], dim, 1);
                    }
                },
            );
            // Batched AOT kernel path.
            let mut z = vec![0.0f32; rows * dim];
            let mut n = vec![0.0f32; rows * dim];
            let mut w = vec![0.0f32; rows * dim];
            bench::run_batched(
                &format!("pallas  d={dim} rows={rows} (rows/s)"),
                1,
                8,
                rows as u64,
                || {
                    batched.update(&g, &mut z, &mut n, &mut w).unwrap();
                },
            );
        }
    }

    bench::header("E7b: model graph execution (PJRT, per sample)");
    let (bt, bp, f, k, h) = (cfg.batch_train, cfg.batch_predict, cfg.fields, cfg.dim, cfg.hidden);
    let mut rng = Rng::new(2);
    let mut t = |shape: &[usize]| {
        let len = shape.iter().product();
        Tensor::new(shape.to_vec(), (0..len).map(|_| rng.gen_f32() * 0.2 - 0.1).collect())
    };
    let label = Tensor::vec1((0..bt).map(|i| (i % 2) as f32).collect());

    let lr_in = vec![t(&[bt, f]), t(&[1]), label.clone()];
    bench::run_batched(&format!("lr_train      (B={bt}, samples/s)"), 2, 20, bt as u64, || {
        engine.execute("lr_train", &lr_in).unwrap();
    });
    let fm_in = vec![t(&[bt, f]), t(&[bt, f, k]), t(&[1]), label.clone()];
    bench::run_batched(&format!("fm_train      (B={bt}, samples/s)"), 2, 20, bt as u64, || {
        engine.execute("fm_train", &fm_in).unwrap();
    });
    let deep_in = vec![
        t(&[bt, f]),
        t(&[bt, f, k]),
        t(&[1]),
        t(&[f * k, h]),
        t(&[h]),
        t(&[h, 1]),
        t(&[1]),
        label,
    ];
    bench::run_batched(&format!("deepfm_train  (B={bt}, samples/s)"), 2, 20, bt as u64, || {
        engine.execute("deepfm_train", &deep_in).unwrap();
    });
    let fm_pred = vec![t(&[bp, f]), t(&[bp, f, k]), t(&[1])];
    bench::run(&format!("fm_predict    (B={bp}, graph latency)"), 5, 100, || {
        engine.execute("fm_predict", &fm_pred).unwrap();
    });
    let deep_pred = vec![
        t(&[bp, f]),
        t(&[bp, f, k]),
        t(&[1]),
        t(&[f * k, h]),
        t(&[h]),
        t(&[h, 1]),
        t(&[1]),
    ];
    bench::run(&format!("deepfm_predict(B={bp}, graph latency)"), 5, 100, || {
        engine.execute("deepfm_predict", &deep_pred).unwrap();
    });

    println!(
        "\nnote: the Pallas kernel runs interpret=True on CPU PJRT (no TPU here), so\nabsolute numbers measure the CPU lowering; the structural target — one fused\nelementwise pass over (rows x dim) with VMEM-sized tiles — is what transfers\nto TPU (see DESIGN.md §Hardware-Adaptation and EXPERIMENTS.md §Perf)."
    );
}
