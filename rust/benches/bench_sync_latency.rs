//! E1 — "second-level model deployment by streaming update" (abstract,
//! §4.1): master-write → slave-visible latency under each gather mode,
//! against the traditional full checkpoint-export-and-load baseline.
//!
//! Threshold/period modes are measured *at a traffic rate*: the latency a
//! sentinel update experiences while regular training traffic fills the
//! gather window (that traffic is what triggers the flush).

use std::time::{Duration, Instant};

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::proto::{SparsePull, SparsePush};
use weips::sample::WorkloadConfig;
use weips::sync::Router;
use weips::util::bench;
use weips::util::histogram::{fmt_ns, Histogram};

fn cluster(gather: GatherMode) -> LocalCluster {
    LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Fm,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 2,
            queue_partitions: 4,
            gather_mode: gather,
            ..Default::default()
        },
        workload: WorkloadConfig { ids_per_field: 5_000, seed: 61, ..Default::default() },
        ..Default::default()
    })
    .expect("cluster (run `make artifacts` first)")
}

/// Push one sentinel update, then tick the pipeline (feeding background
/// traffic so threshold windows fill) until the slave serves the master's
/// current weight. Returns write→visible latency.
fn probe_latency(c: &LocalCluster, sentinel: u64, feed_traffic: bool) -> Duration {
    let master_router = Router::new(c.cfg.master_shards);
    let slave_router = Router::new(c.cfg.slave_shards);
    let m = &c.masters[master_router.shard_of(sentinel) as usize];
    let shard = slave_router.shard_of(sentinel) as usize;
    let t0 = Instant::now();
    m.sparse_push(&SparsePush {
        model: "ctr".into(),
        table: "w".into(),
        ids: vec![sentinel],
        grads: vec![1.0],
    })
    .unwrap();
    loop {
        c.sync_tick().unwrap();
        let served = c.slaves[shard][0]
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![sentinel],
                slot: "w".into(),
            })
            .unwrap()
            .values[0];
        let master_w = m
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![sentinel],
                slot: "w".into(),
            })
            .unwrap()
            .values[0];
        if (served - master_w).abs() < 1e-9 {
            return t0.elapsed();
        }
        if feed_traffic {
            // Regular traffic is what fills threshold windows; it is part
            // of the latency a threshold-mode deployment experiences.
            c.train_step().unwrap();
        }
        if t0.elapsed() > Duration::from_secs(30) {
            panic!("sync never converged");
        }
    }
}

fn main() {
    bench::header("E1: streaming sync latency (master write -> slave visible)");
    for (label, gather, feed) in [
        ("gather=realtime", GatherMode::Realtime, false),
        ("gather=threshold:1024 (w/ traffic)", GatherMode::Threshold(1024), true),
        ("gather=threshold:8192 (w/ traffic)", GatherMode::Threshold(8192), true),
        ("gather=period:100ms", GatherMode::Period(100), false),
        ("gather=period:1000ms", GatherMode::Period(1000), false),
    ] {
        let c = cluster(gather);
        for _ in 0..6 {
            c.train_step().unwrap(); // warm tables + modules (unmeasured)
        }
        c.flush_sync().unwrap();
        let sentinel = 0xDEAD_BEEFu64;
        let hist = Histogram::new();
        for _ in 0..25 {
            // Background churn between probes (unmeasured).
            c.train_step().unwrap();
            let d = probe_latency(&c, sentinel, feed);
            hist.record(d.as_nanos() as u64);
        }
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12} {:>14}",
            label,
            hist.count(),
            fmt_ns(hist.mean() as u64),
            fmt_ns(hist.quantile(0.5)),
            fmt_ns(hist.quantile(0.99)),
            "-"
        );
    }

    // Baseline: the traditional deployment — full checkpoint export from
    // masters + full load into every slave replica.
    bench::header("E1 baseline: full checkpoint export + slave reload");
    let c = cluster(GatherMode::Realtime);
    for _ in 0..50 {
        c.train_step().unwrap();
    }
    c.flush_sync().unwrap();
    let rows: usize = c.masters.iter().map(|m| m.total_rows()).sum();
    bench::metric("model rows at export time", rows);
    bench::run("checkpoint-export-reload (baseline)", 1, 10, || {
        let v = c.checkpoint().unwrap();
        // Chain-aware load (a version may be a base or a delta tip):
        // chunks load once per master and are shared across replicas.
        let chains: Vec<_> =
            c.masters.iter().map(|m| c.shard_chain(v, m.shard_id).unwrap()).collect();
        for shard in &c.slaves {
            for replica in shard {
                replica.clear();
                for chain in &chains {
                    LocalCluster::apply_chain_chunks(replica, chain, None).unwrap();
                }
            }
        }
    });
    println!(
        "\nshape check: realtime/period streaming stays far under the one-second\nbound; threshold modes are traffic-rate-bound; the export baseline scales\nwith model size (hours at production's 1e11 parameters)."
    );
}
