//! E5 — domino downgrade (§4.3): detection latency and rollback cost
//! after injected model corruption; false-alarm comparison of the plain
//! vs smoothed trigger on noisy-but-healthy metrics.

use std::time::Instant;

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::downgrade::SwitchStrategy;
use weips::monitor::{PlainThreshold, SmoothedThreshold, Trigger};
use weips::sample::WorkloadConfig;
use weips::util::bench;
use weips::util::Rng;

fn cluster() -> LocalCluster {
    LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 2,
            slave_shards: 1,
            slave_replicas: 2,
            queue_partitions: 2,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        workload: WorkloadConfig {
            ids_per_field: 300,
            zipf_s: 1.3,
            seed: 5,
            ..Default::default()
        },
        trigger_threshold: 0.55,
        trigger_smooth: 3,
        switch_strategy: SwitchStrategy::LatestStable,
        ..Default::default()
    })
    .expect("cluster (run `make artifacts` first)")
}

fn main() {
    println!("=== E5: domino downgrade — detection + rollback + recovery ===");
    let c = cluster();
    for _ in 0..140 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    let healthy = c.monitor.snapshot();
    c.checkpoint().unwrap();
    bench::metric("healthy window AUC", format!("{:.4}", healthy.window_auc));

    // Corrupt, then measure batches-to-detection and rollback wall time.
    c.corrupt_model().unwrap();
    c.flush_sync().unwrap();
    let corrupt_at = Instant::now();
    let mut detection_batches = None;
    let mut rollback_time = None;
    for step in 0..100 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
        let t0 = Instant::now();
        if let Some(plan) = c.control_tick().unwrap() {
            detection_batches = Some(step + 1);
            rollback_time = Some(t0.elapsed());
            bench::metric("rolled back", format!("v{} -> v{}", plan.from_version, plan.target_version));
            break;
        }
    }
    bench::metric(
        "detection latency (batches of 256 samples)",
        detection_batches.map(|b| b.to_string()).unwrap_or("NEVER".into()),
    );
    bench::metric(
        "detection wall time since corruption",
        format!("{:?}", corrupt_at.elapsed()),
    );
    bench::metric(
        "rollback execution time (masters + slaves + seek)",
        rollback_time.map(|t| format!("{t:?}")).unwrap_or("-".into()),
    );
    // Metric recovery after rollback.
    for _ in 0..80 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    let recovered = c.monitor.snapshot();
    bench::metric("window AUC 80 batches after rollback", format!("{:.4}", recovered.window_auc));

    // -- trigger comparison on synthetic metric streams -------------------------------
    println!("\n=== E5b: false alarms — plain vs smoothed threshold (§4.3.2a) ===");
    println!(
        "{:<26} {:>14} {:>14} {:>20}",
        "trigger", "false alarms", "(healthy noise)", "detection delay (bad)"
    );
    let mut rng = Rng::new(404);
    // Healthy stream: AUC ~ N(0.72, 0.025), threshold 0.70.
    let healthy_stream: Vec<f64> =
        (0..2_000).map(|_| 0.72 + rng.gen_normal() * 0.025).collect();
    // Degraded stream: drops to 0.60 at t=0.
    let degraded_stream: Vec<f64> =
        (0..200).map(|_| 0.60 + rng.gen_normal() * 0.025).collect();
    for (name, mk) in [
        ("plain threshold 0.70", Box::new(|| Box::new(PlainThreshold { threshold: 0.70 }) as Box<dyn Trigger>)
            as Box<dyn Fn() -> Box<dyn Trigger>>),
        ("smoothed k=3 @0.70", Box::new(|| Box::new(SmoothedThreshold::new(0.70, 3)) as Box<dyn Trigger>)),
        ("smoothed k=5 @0.70", Box::new(|| Box::new(SmoothedThreshold::new(0.70, 5)) as Box<dyn Trigger>)),
    ] {
        let mut t = mk();
        let false_alarms = healthy_stream.iter().filter(|v| t.observe(**v)).count();
        let mut t = mk();
        let delay = degraded_stream
            .iter()
            .position(|v| t.observe(*v))
            .map(|p| (p + 1).to_string())
            .unwrap_or("never".into());
        println!("{:<26} {:>14} {:>14} {:>20}", name, false_alarms, "", delay);
    }
    println!(
        "\nshape check: the smoothed trigger eliminates the plain threshold's false\nalarms at the cost of k-1 extra observation points of detection delay —\nthe paper's §4.3.2a trade-off."
    );
}
