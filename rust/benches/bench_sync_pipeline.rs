//! E10 — the event-driven parallel streaming-sync pipeline (§4.1):
//! striped collector → pooled gather absorb + snapshot → queue → pooled
//! coalesced scatter apply, fronted by the event-driven RPC substrate.
//!
//! Measures, at 1 vs N table stripes × sequential vs pooled sync stages:
//!   - gather-snapshot throughput (per-stripe value reads, the flush hot
//!     path) — rows/s;
//!   - gather-absorb throughput (the dedup-window merge, fanned per
//!     stripe over the sync pool) — events/s;
//!   - scatter-apply throughput (per-stripe transform + upsert into the
//!     serving table) — rows/s;
//!   - scatter coalescing: rows/s and stripe-lock acquisitions per row
//!     for batch-by-batch vs coalesced application of a queue backlog
//!     (asserts acquisitions/row strictly decrease at depth > 1);
//!   - push → serving-visible latency through the full pipeline
//!     (push, gather flush, queue, scatter poll) — ms per round;
//!   - idle-fleet CPU: process CPU burned while a fleet of parked RPC
//!     connections sits idle, epoll vs peek poll mode;
//! and verifies the determinism contract: sync-batch bytes and checkpoint
//! bytes are identical for every stripe count and pool size, and survive
//! an RPC round trip unchanged in both poll modes.
//!
//! Needs no AOT artifacts. Emits the human table plus one-line JSON
//! records, and writes the full result set to `BENCH_sync_pipeline.json`
//! (uploaded as a CI artifact and gated against the committed baseline by
//! `tools/check_bench_regression.py`). `WEIPS_BENCH_SMOKE=1` shrinks
//! sizes for CI smoke runs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use weips::codec::Encode;
use weips::config::{GatherMode, ModelKind, ModelSpec};
use weips::net::{Channel, PollMode, RpcOptions, RpcServer, Service};
use weips::optim::{Ftrl, FtrlHyper, Optimizer};
use weips::proto::{SparsePush, SyncBatch, SyncEntry, SyncOp};
use weips::queue::Queue;
use weips::runtime::ModelConfig;
use weips::server::master::MasterShard;
use weips::server::slave::SlaveShard;
use weips::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use weips::table::stripe_of_id;
use weips::util::bench;
use weips::util::clock::ManualClock;
use weips::util::{sys, ThreadPool};

const DIM: usize = 8;

fn smoke() -> bool {
    std::env::var("WEIPS_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: DIM,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn master(stripes: usize) -> Arc<MasterShard> {
    let clock = Arc::new(ManualClock::new(0));
    Arc::new(MasterShard::with_stripes(0, spec(), None, 1, stripes, clock).unwrap())
}

/// Populate `n` rows of table `v` and clear the collector backlog.
fn populate(m: &MasterShard, n: u64) {
    for chunk in (0..n).collect::<Vec<_>>().chunks(8_192) {
        let grads = vec![0.1f32; chunk.len() * DIM];
        m.sparse_push(&SparsePush {
            model: "ctr".into(),
            table: "v".into(),
            ids: chunk.to_vec(),
            grads,
        })
        .unwrap();
    }
    let mut sink = Vec::new();
    m.collector().drain(&mut sink);
}

fn serving(stripes: usize) -> Arc<SlaveShard> {
    let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
    Arc::new(SlaveShard::with_stripes(
        0,
        0,
        "ctr",
        vec![("w".into(), 1), ("v".into(), DIM)],
        vec![("bias".into(), 1)],
        Arc::new(ServingWeights::new(vec![
            ("w".into(), ftrl.clone(), 1),
            ("v".into(), ftrl, DIM),
        ])),
        Router::new(1),
        stripes,
    ))
}

struct Case {
    stripes: usize,
    threads: usize,
}

impl Case {
    fn label(&self) -> String {
        format!("{} stripes, {} pool threads", self.stripes, self.threads)
    }

    fn pool(&self) -> Option<Arc<ThreadPool>> {
        (self.threads > 0).then(|| Arc::new(ThreadPool::new(self.threads, "sync-bench")))
    }
}

fn cases() -> Vec<Case> {
    vec![
        Case { stripes: 1, threads: 0 }, // the sequential single-thread path
        Case { stripes: 8, threads: 0 }, // striping alone
        Case { stripes: 8, threads: 4 }, // the acceptance configuration
        Case { stripes: 32, threads: 4 },
    ]
}

fn gather_snapshot(rows: u64, iters: u64, results: &mut Vec<String>) {
    bench::header(&format!("E10a: gather snapshot throughput ({rows} rows, dim {DIM})"));
    let mut baseline = 0.0f64;
    for case in cases() {
        let m = master(case.stripes);
        populate(&m, rows);
        let pool = case.pool();
        let mut groups: Vec<Vec<u64>> = vec![Vec::new(); case.stripes];
        for id in 0..rows {
            groups[stripe_of_id(id, case.stripes)].push(id);
        }
        let table = m.table_index("v").unwrap();
        let stats = bench::run_batched(
            &format!("snapshot ({})", case.label()),
            1,
            iters,
            rows,
            || {
                let snap = m.read_rows_for_sync_grouped(table, &groups, pool.as_deref());
                std::hint::black_box(&snap);
            },
        );
        let rows_per_sec = stats.ops_per_sec();
        if case.stripes == 1 && case.threads == 0 {
            baseline = rows_per_sec;
        }
        let speedup = if baseline > 0.0 { rows_per_sec / baseline } else { 1.0 };
        bench::metric(
            &format!("  speedup vs sequential ({})", case.label()),
            format!("{speedup:.2}x"),
        );
        let json = format!(
            r#"{{"bench":"sync_pipeline","stage":"gather_snapshot","stripes":{},"threads":{},"rows":{},"rows_per_sec":{:.0},"speedup_vs_seq":{:.3}}}"#,
            case.stripes, case.threads, rows, rows_per_sec, speedup
        );
        println!("{json}");
        results.push(json);
    }
}

fn scatter_apply(rows: u64, iters: u64, results: &mut Vec<String>) {
    bench::header(&format!("E10b: scatter apply throughput ({rows} rows, dim {DIM})"));
    let batch = SyncBatch {
        model: "ctr".into(),
        table: "v".into(),
        shard: 0,
        seq: 1,
        created_ms: 0,
        entries: (0..rows)
            .map(|id| SyncEntry {
                id,
                op: SyncOp::Upsert(vec![0.25f32; 3 * DIM]),
            })
            .collect(),
        dense: vec![],
    };
    let mut baseline = 0.0f64;
    for case in cases() {
        let s = serving(case.stripes);
        let pool = case.pool();
        let stats = bench::run_batched(
            &format!("apply ({})", case.label()),
            1,
            iters,
            rows,
            || {
                s.apply_batch_pooled(&batch, pool.as_deref()).unwrap();
            },
        );
        let rows_per_sec = stats.ops_per_sec();
        if case.stripes == 1 && case.threads == 0 {
            baseline = rows_per_sec;
        }
        let speedup = if baseline > 0.0 { rows_per_sec / baseline } else { 1.0 };
        bench::metric(
            &format!("  speedup vs sequential ({})", case.label()),
            format!("{speedup:.2}x"),
        );
        let json = format!(
            r#"{{"bench":"sync_pipeline","stage":"scatter_apply","stripes":{},"threads":{},"rows":{},"rows_per_sec":{:.0},"speedup_vs_seq":{:.3}}}"#,
            case.stripes, case.threads, rows, rows_per_sec, speedup
        );
        println!("{json}");
        results.push(json);
    }
}

fn gather_absorb(events: u64, iters: u64, results: &mut Vec<String>) {
    bench::header(&format!("E10e: gather absorb throughput ({events} events/drain)"));
    let ids: Vec<u64> = (0..events).collect();
    let mut baseline = 0.0f64;
    for case in cases() {
        let m = master(case.stripes);
        let pool = case.pool();
        let clock = Arc::new(ManualClock::new(0));
        let mut g = Gather::with_pool(m.clone(), GatherMode::Threshold(1 << 30), clock, pool);
        let mut total = Duration::ZERO;
        for _ in 0..iters {
            // Enqueue dirty events straight into the striped collector
            // (table 1 = "v"), then time the absorb-only poll.
            for chunk in ids.chunks(8_192) {
                m.collector().record_updates(1, chunk);
            }
            let t0 = Instant::now();
            let out = g.poll();
            total += t0.elapsed();
            assert!(out.is_empty(), "threshold flush fired during absorb bench");
        }
        let events_per_sec = (events * iters) as f64 / total.as_secs_f64();
        if case.stripes == 1 && case.threads == 0 {
            baseline = events_per_sec;
        }
        let speedup = if baseline > 0.0 { events_per_sec / baseline } else { 1.0 };
        bench::metric(
            &format!("absorb ({})", case.label()),
            format!("{:.2} M events/s ({speedup:.2}x)", events_per_sec / 1e6),
        );
        let json = format!(
            r#"{{"bench":"sync_pipeline","stage":"gather_absorb","stripes":{},"threads":{},"rows":{},"rows_per_sec":{:.0},"speedup_vs_seq":{:.3}}}"#,
            case.stripes, case.threads, events, events_per_sec, speedup
        );
        println!("{json}");
        results.push(json);
    }
}

fn scatter_coalesce(rows: u64, depth: u64, results: &mut Vec<String>) {
    bench::header(&format!(
        "E10f: scatter coalescing ({depth} batches x {rows} rows backlog)"
    ));
    let batches: Vec<SyncBatch> = (0..depth)
        .map(|d| SyncBatch {
            model: "ctr".into(),
            table: "v".into(),
            shard: 0,
            seq: d + 1,
            created_ms: 0,
            entries: (0..rows)
                .map(|id| SyncEntry {
                    id,
                    op: SyncOp::Upsert(vec![0.25 + d as f32 * 0.01; 3 * DIM]),
                })
                .collect(),
            dense: vec![],
        })
        .collect();
    for case in cases() {
        let pool = case.pool();
        // Batch-by-batch: the pre-coalescing path.
        let one = serving(case.stripes);
        let t0 = Instant::now();
        for b in &batches {
            one.apply_batch_pooled(b, pool.as_deref()).unwrap();
        }
        let one_secs = t0.elapsed().as_secs_f64();
        // Coalesced: the whole backlog as one grouped run.
        let co = serving(case.stripes);
        let t1 = Instant::now();
        co.apply_batches_pooled(&batches, pool.as_deref()).unwrap();
        let co_secs = t1.elapsed().as_secs_f64();
        let applied = rows * depth;
        let one_locks = one
            .metrics
            .stripe_lock_acquisitions
            .load(std::sync::atomic::Ordering::Relaxed);
        let co_locks = co
            .metrics
            .stripe_lock_acquisitions
            .load(std::sync::atomic::Ordering::Relaxed);
        // The acceptance criterion: stripe-lock acquisitions per applied
        // row strictly decrease at batch depth > 1.
        assert!(
            co_locks < one_locks,
            "coalescing did not amortize locks ({}): {co_locks} vs {one_locks}",
            case.label()
        );
        let one_rate = applied as f64 / one_secs;
        let co_rate = applied as f64 / co_secs;
        bench::metric(
            &format!("coalesced apply ({})", case.label()),
            format!(
                "{:.2} M rows/s vs {:.2} M rows/s; locks/row {:.4} vs {:.4}",
                co_rate / 1e6,
                one_rate / 1e6,
                co_locks as f64 / applied as f64,
                one_locks as f64 / applied as f64
            ),
        );
        let json = format!(
            r#"{{"bench":"sync_pipeline","stage":"scatter_coalesce","stripes":{},"threads":{},"rows":{},"depth":{},"rows_per_sec":{:.0},"rows_per_sec_batchwise":{:.0},"locks_per_row":{:.5},"locks_per_row_batchwise":{:.5}}}"#,
            case.stripes,
            case.threads,
            applied,
            depth,
            co_rate,
            one_rate,
            co_locks as f64 / applied as f64,
            one_locks as f64 / applied as f64
        );
        println!("{json}");
        results.push(json);
    }
}

struct EchoService;

impl Service for EchoService {
    fn call(&self, _method: u16, payload: &[u8]) -> weips::Result<Vec<u8>> {
        Ok(payload.to_vec())
    }
}

/// Poll modes available on this host (Event only where the epoll binding
/// works — the bench verifies by asking the server what it resolved to).
fn available_poll_modes() -> Vec<PollMode> {
    if sys::supported() {
        vec![PollMode::Event, PollMode::Peek]
    } else {
        vec![PollMode::Peek]
    }
}

fn idle_fleet_cpu(conns: usize, window_ms: u64, results: &mut Vec<String>) {
    bench::header(&format!("E10g: idle-fleet CPU ({conns} parked connections)"));
    if sys::process_cpu_ns().is_none() {
        println!("  (process CPU clock unavailable on this target — skipped)");
        return;
    }
    for mode in available_poll_modes() {
        let server = RpcServer::serve_with(
            "127.0.0.1:0",
            Arc::new(EchoService),
            RpcOptions { threads: 2, mode, ..RpcOptions::default() },
        )
        .unwrap();
        assert_eq!(server.poll_mode(), mode, "requested poll mode unavailable");
        let fleet: Vec<std::net::TcpStream> = (0..conns)
            .map(|_| std::net::TcpStream::connect(server.addr()).unwrap())
            .collect();
        // Wait until the whole fleet is parked, then measure an idle window.
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.parked_connections() < conns {
            assert!(Instant::now() < deadline, "fleet never parked ({mode:?})");
            std::thread::sleep(Duration::from_millis(5));
        }
        let cpu0 = sys::process_cpu_ns().unwrap();
        let w0 = Instant::now();
        std::thread::sleep(Duration::from_millis(window_ms));
        let cpu_ms = (sys::process_cpu_ns().unwrap() - cpu0) as f64 / 1e6;
        let wall_ms = w0.elapsed().as_secs_f64() * 1e3;
        bench::metric(
            &format!("idle cpu ({mode:?}, {conns} conns)"),
            format!("{cpu_ms:.2} ms CPU / {wall_ms:.0} ms wall"),
        );
        let json = format!(
            r#"{{"bench":"sync_pipeline","stage":"idle_fleet_cpu","mode":"{mode:?}","conns":{conns},"cpu_ms":{cpu_ms:.3},"wall_ms":{wall_ms:.1}}}"#,
        );
        println!("{json}");
        results.push(json);
        drop(fleet);
    }
}

fn push_to_visible_latency(rounds: u64, ids_per_round: u64, results: &mut Vec<String>) {
    bench::header(&format!(
        "E10c: push -> serving-visible latency ({ids_per_round} ids/round)"
    ));
    for case in cases() {
        let clock = Arc::new(ManualClock::new(0));
        let m = Arc::new(
            MasterShard::with_stripes(0, spec(), None, 1, case.stripes, clock.clone()).unwrap(),
        );
        let pool = case.pool();
        let queue = Queue::new(1 << 30);
        let topic = queue.create_topic("sync.ctr", 1).unwrap();
        let pusher = Pusher::new(topic.clone(), 0);
        let mut gather =
            Gather::with_pool(m.clone(), GatherMode::Realtime, clock.clone(), pool.clone());
        let s = serving(case.stripes);
        let mut scatter = Scatter::with_pool(topic, s.clone(), 1, 1, clock, pool);
        let mut total = Duration::ZERO;
        for round in 0..rounds {
            let ids: Vec<u64> =
                (round * ids_per_round..(round + 1) * ids_per_round).collect();
            let grads = vec![0.1f32; ids.len() * DIM];
            let t0 = std::time::Instant::now();
            m.sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "v".into(),
                ids,
                grads,
            })
            .unwrap();
            pusher.push_all(&gather.flush_now()).unwrap();
            scatter.poll(Duration::ZERO).unwrap();
            total += t0.elapsed();
        }
        assert_eq!(s.total_rows(), (rounds * ids_per_round) as usize);
        let ms_per_round = total.as_secs_f64() * 1e3 / rounds as f64;
        bench::metric(
            &format!("push->visible ({})", case.label()),
            format!("{ms_per_round:.3} ms/round"),
        );
        let json = format!(
            r#"{{"bench":"sync_pipeline","stage":"push_to_visible","stripes":{},"threads":{},"ids_per_round":{},"ms_per_round":{:.4}}}"#,
            case.stripes, case.threads, ids_per_round, ms_per_round
        );
        println!("{json}");
        results.push(json);
    }
}

/// Determinism contract: the same logical workload must produce
/// byte-identical sync batches and checkpoints at every stripe count and
/// pool size (the gather sorts batch entries by id; the checkpoint
/// encoder emits ascending ids).
fn determinism_check(results: &mut Vec<String>) {
    bench::header("E10d: sync-batch + checkpoint determinism across stripes x pools");
    let mut blobs: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
    for case in cases() {
        let clock = Arc::new(ManualClock::new(0));
        let m = Arc::new(
            MasterShard::with_stripes(0, spec(), None, 1, case.stripes, clock.clone()).unwrap(),
        );
        let pool = case.pool();
        let mut gather =
            Gather::with_pool(m.clone(), GatherMode::Threshold(1 << 30), clock, pool);
        for round in 0..10u64 {
            let ids: Vec<u64> = (0..512).map(|i| (i * 13 + round) % 1_999).collect();
            let grads = vec![0.5f32; ids.len() * DIM];
            m.sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "v".into(),
                ids,
                grads,
            })
            .unwrap();
        }
        let batch_bytes: Vec<u8> =
            gather.flush_now().iter().flat_map(|b| b.to_bytes()).collect();
        blobs.push((batch_bytes, m.snapshot()));
    }
    for (i, (batches, snap)) in blobs.iter().enumerate().skip(1) {
        assert_eq!(
            batches, &blobs[0].0,
            "sync-batch bytes diverged between case 0 and case {i}"
        );
        assert_eq!(
            snap, &blobs[0].1,
            "checkpoint bytes diverged between case 0 and case {i}"
        );
    }
    // The wire leg: the same bytes must survive an RPC round trip
    // unchanged under both readiness mechanisms (exercises the
    // zero-allocation frame assemble/parse paths end to end).
    let mut modes_checked = 0;
    for mode in available_poll_modes() {
        let server = RpcServer::serve_with(
            "127.0.0.1:0",
            Arc::new(EchoService),
            RpcOptions { threads: 2, mode, ..RpcOptions::default() },
        )
        .unwrap();
        let ch = Channel::remote(&server.addr().to_string(), Duration::from_secs(10));
        for payload in [&blobs[0].0, &blobs[0].1] {
            let echoed = ch.call(0, payload).unwrap();
            assert_eq!(&echoed, payload, "bytes corrupted over RPC in {mode:?} mode");
        }
        modes_checked += 1;
    }
    bench::metric("sync-batch + checkpoint bytes identical across all cases", "ok");
    let json = format!(
        r#"{{"bench":"sync_pipeline","stage":"determinism","cases":{},"poll_modes":{modes_checked},"identical":true}}"#,
        blobs.len()
    );
    println!("{json}");
    results.push(json);
}

fn main() {
    let (rows, iters, rounds, ids_per_round) = if smoke() {
        (20_000u64, 2u64, 5u64, 512u64)
    } else {
        (200_000u64, 5u64, 20u64, 2_048u64)
    };
    let (absorb_events, coalesce_rows, coalesce_depth, idle_conns, idle_window_ms) = if smoke() {
        (20_000u64, 4_000u64, 4u64, 16usize, 300u64)
    } else {
        (200_000u64, 40_000u64, 8u64, 64usize, 1_000u64)
    };
    let mut results = Vec::new();
    gather_snapshot(rows, iters, &mut results);
    gather_absorb(absorb_events, iters, &mut results);
    scatter_apply(rows, iters, &mut results);
    scatter_coalesce(coalesce_rows, coalesce_depth, &mut results);
    push_to_visible_latency(rounds, ids_per_round, &mut results);
    idle_fleet_cpu(idle_conns, idle_window_ms, &mut results);
    determinism_check(&mut results);
    let json = format!("[\n  {}\n]\n", results.join(",\n  "));
    // Anchor to the workspace root (cargo runs benches with cwd = the
    // package root, rust/), so CI finds the artifact at a fixed path.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package has a parent dir")
        .join("BENCH_sync_pipeline.json");
    std::fs::write(&out, &json).expect("write BENCH_sync_pipeline.json");
    println!("\nwrote {} ({} records)", out.display(), results.len());
}
