//! E2 — the paper's one quantitative claim: "the repetition rate of model
//! parameters updates within 10 seconds reach 90% or much more, which also
//! provides a basis for subsequent bandwidth optimization based on
//! gathering methods" (§4.1.2a).
//!
//! Sweeps the gather window and workload skew, reporting the measured
//! repetition rate and the bytes that dedup + full-value encoding +
//! compression save versus shipping every raw update.

use std::sync::Arc;

use weips::codec::Encode;
use weips::config::{GatherMode, ModelKind, ModelSpec};
use weips::proto::{SparsePush, SyncBatch};
use weips::queue::Queue;
use weips::runtime::ModelConfig;
use weips::sample::{repetition_rate, Workload, WorkloadConfig};
use weips::server::master::MasterShard;
use weips::sync::{Gather, Pusher};
use weips::util::bench;
use weips::util::clock::ManualClock;

fn model_cfg() -> ModelConfig {
    ModelConfig {
        batch_train: 256,
        batch_predict: 16,
        fields: 16,
        dim: 8,
        hidden: 64,
        ftrl_block_rows: 8192,
        ftrl_alpha: 0.1,
        ftrl_beta: 1.0,
        ftrl_l1: 0.01,
        ftrl_l2: 1.0,
    }
}

fn main() {
    println!("=== E2: update repetition rate & gather bandwidth savings ===");
    println!(
        "\n{:<14} {:>12} {:>14} {:>12} {:>14} {:>14} {:>12} {:>10}",
        "zipf_s", "window(evt)", "repetition", "raw_evts", "dedup_entries", "raw_bytes", "wire_bytes", "savings"
    );

    for zipf_s in [1.01f64, 1.1, 1.3] {
        for window_samples in [1_000usize, 5_000, 20_000] {
            let spec = ModelSpec::derive("ctr", ModelKind::Lr, &model_cfg());
            let clock = ManualClock::new(0);
            let master = Arc::new(
                MasterShard::new(0, spec, None, 1, Arc::new(clock.clone())).unwrap(),
            );
            // Period gather = one flush per window.
            let mut gather =
                Gather::new(master.clone(), GatherMode::Period(10_000), Arc::new(clock.clone()));
            let queue = Queue::default();
            let topic = queue.create_topic("sync", 1).unwrap();
            let pusher = Pusher::new(topic.clone(), 0);

            let mut workload = Workload::new(WorkloadConfig {
                ids_per_field: 100_000,
                zipf_s,
                seed: 7,
                ..Default::default()
            });
            let samples = workload.batch(0, window_samples);
            let independent_rate = repetition_rate(&samples);
            // Push every sample's ids as updates (the raw update stream).
            let mut raw_update_bytes = 0u64;
            for s in &samples {
                let push = SparsePush {
                    model: "ctr".into(),
                    table: "w".into(),
                    ids: s.ids.clone(),
                    grads: vec![0.1; s.ids.len()],
                };
                // A no-dedup design would ship one record per update: cost
                // it as the per-id slice of a SyncBatch.
                raw_update_bytes += push.to_bytes().len() as u64;
                master.sparse_push(&push).unwrap();
            }
            clock.advance(20_000);
            let batches: Vec<SyncBatch> = gather.flush_now();
            pusher.push_all(&batches).unwrap();

            let raw_events = gather.stats.raw_events.load(std::sync::atomic::Ordering::Relaxed);
            let emitted = gather.stats.emitted_entries.load(std::sync::atomic::Ordering::Relaxed);
            let wire = pusher.stats.bytes_on_wire.load(std::sync::atomic::Ordering::Relaxed);
            let savings = 1.0 - wire as f64 / raw_update_bytes as f64;
            println!(
                "{:<14} {:>12} {:>13.1}% {:>12} {:>14} {:>14} {:>12} {:>9.1}%",
                format!("{zipf_s}"),
                raw_events,
                gather.stats.repetition_rate() * 100.0,
                raw_events,
                emitted,
                raw_update_bytes,
                wire,
                savings * 100.0
            );
            let _ = independent_rate;
        }
    }
    println!(
        "\nshape check: repetition grows with window size and skew; at production-\nscale windows (>=20k events) the high-skew rows reach the paper's 90% band,\nand dedup+compression cut sync bandwidth by a comparable factor."
    );

    bench::header("E2 micro: gather poll cost");
    let spec = ModelSpec::derive("ctr", ModelKind::Lr, &model_cfg());
    let clock = ManualClock::new(0);
    let master = Arc::new(MasterShard::new(0, spec, None, 1, Arc::new(clock.clone())).unwrap());
    let mut gather = Gather::new(master.clone(), GatherMode::Realtime, Arc::new(clock.clone()));
    let ids: Vec<u64> = (0..4096).collect();
    let grads = vec![0.1f32; 4096];
    bench::run_batched("gather poll (4096 dirty ids)", 3, 50, 4096, || {
        master
            .sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: ids.clone(),
                grads: grads.clone(),
            })
            .unwrap();
        let batches = gather.poll();
        std::hint::black_box(batches);
    });
}
