//! E15 — alert-evaluator overhead on the streaming sync path.
//!
//! The cluster health engine evaluates every declared rule against the
//! process-local metrics registry; it only ever *reads* registry state,
//! so the sync pipeline must not notice it exists. This bench holds
//! that to numbers:
//!   - gather → queue → scatter pipeline throughput with the evaluator
//!     off vs ticking at an aggressive 5 ms cadence (200× the default),
//!     interleaved best-of-trials so host noise cancels;
//!   - raw evaluation cost: full rule-set sweeps per second, measured
//!     inline;
//!   - the pending → firing lifecycle must engage against a real
//!     breaching source and land in the event journal (asserted
//!     in-run);
//!   - sync-batch bytes must be identical with the evaluator off and
//!     ticking (asserted in-run — the engine never touches the wire).
//!
//! Needs no AOT artifacts. Emits one-line JSON records and writes the
//! result set to `BENCH_alerts.json`; CI uploads the artifact and gates
//! `overhead_frac <= 0.01` (≤1% evaluator overhead) via
//! `tools/check_bench_regression.py --kind alerts`.
//! `WEIPS_BENCH_SMOKE=1` shrinks sizes for CI smoke runs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use weips::alerts;
use weips::codec::Encode;
use weips::config::{GatherMode, ModelKind, ModelSpec};
use weips::optim::{Ftrl, FtrlHyper, Optimizer};
use weips::proto::SparsePush;
use weips::queue::Queue;
use weips::runtime::ModelConfig;
use weips::server::master::MasterShard;
use weips::server::slave::SlaveShard;
use weips::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use weips::util::bench;
use weips::util::clock::ManualClock;

const DIM: usize = 8;
/// Stress cadence: 200× tighter than the 1000 ms default, so a real
/// per-tick cost would register even on a short run.
const TICK_MS: u64 = 5;

fn smoke() -> bool {
    std::env::var("WEIPS_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: DIM,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn serving() -> Arc<SlaveShard> {
    let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
    Arc::new(SlaveShard::with_stripes(
        0,
        0,
        "ctr",
        vec![("w".into(), 1), ("v".into(), DIM)],
        vec![("bias".into(), 1)],
        Arc::new(ServingWeights::new(vec![
            ("w".into(), ftrl.clone(), 1),
            ("v".into(), ftrl, DIM),
        ])),
        Router::new(1),
        8,
    ))
}

struct Pipeline {
    master: Arc<MasterShard>,
    gather: Gather,
    pusher: Pusher,
    scatter: Scatter,
}

fn pipeline() -> Pipeline {
    let clock = Arc::new(ManualClock::new(0));
    let master =
        Arc::new(MasterShard::with_stripes(0, spec(), None, 1, 8, clock.clone()).unwrap());
    let queue = Queue::new(1 << 30);
    let topic = queue.create_topic("sync.ctr", 1).unwrap();
    let gather =
        Gather::with_pool(master.clone(), GatherMode::Realtime, clock.clone(), None);
    let pusher = Pusher::new(topic.clone(), 0);
    let scatter = Scatter::with_pool(topic, serving(), 1, 1, clock, None);
    Pipeline { master, gather, pusher, scatter }
}

/// One full pipeline drive: `rounds` sparse pushes, each flushed through
/// the gather, queued, and scattered into serving, with the alert
/// evaluator ticking at `tick_ms` (0 = off). Returns rows/s.
fn drive(tick_ms: u64, rounds: u64, ids_per_round: u64) -> f64 {
    alerts::clear();
    let _ticker = alerts::spawn_ticker("bench", tick_ms);
    let mut p = pipeline();
    let t0 = Instant::now();
    for round in 0..rounds {
        let ids: Vec<u64> = (round * ids_per_round..(round + 1) * ids_per_round).collect();
        let grads = vec![0.1f32; ids.len() * DIM];
        p.master
            .sparse_push(&SparsePush { model: "ctr".into(), table: "v".into(), ids, grads })
            .unwrap();
        p.pusher.push_all(&p.gather.flush_now()).unwrap();
        p.scatter.poll(Duration::ZERO).unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    (rounds * ids_per_round) as f64 / secs
}

fn overhead(trials: u64, rounds: u64, ids_per_round: u64, results: &mut Vec<String>) {
    bench::header(&format!(
        "E15a: evaluator overhead, off vs ticking every {TICK_MS}ms \
         ({rounds} rounds x {ids_per_round} ids)"
    ));
    // Interleave the two configurations and keep each one's best trial:
    // min-noise estimates of the same workload on the same host.
    let mut best_off = 0.0f64;
    let mut best_on = 0.0f64;
    for _ in 0..trials {
        best_off = best_off.max(drive(0, rounds, ids_per_round));
        best_on = best_on.max(drive(TICK_MS, rounds, ids_per_round));
    }
    let overhead_frac = 1.0 - best_on / best_off;
    bench::metric("pipeline rows/s (evaluator off)", format!("{:.2} M", best_off / 1e6));
    bench::metric(
        &format!("pipeline rows/s (ticking every {TICK_MS}ms)"),
        format!("{:.2} M", best_on / 1e6),
    );
    bench::metric("evaluator overhead", format!("{:.2}%", overhead_frac * 100.0));
    for (mode, rate) in [("off", best_off), ("ticking", best_on)] {
        let json = format!(
            r#"{{"bench":"alerts","stage":"pipeline_throughput","mode":"{mode}","tick_ms":{},"rows_per_sec":{rate:.0}}}"#,
            if mode == "off" { 0 } else { TICK_MS }
        );
        println!("{json}");
        results.push(json);
    }
    let json = format!(
        r#"{{"bench":"alerts","stage":"overhead","tick_ms":{TICK_MS},"off_rows_per_sec":{best_off:.0},"ticking_rows_per_sec":{best_on:.0},"overhead_frac":{overhead_frac:.4}}}"#,
    );
    println!("{json}");
    results.push(json);
}

/// Raw cost of one full rule-set sweep, measured inline.
fn eval_cost(sweeps: u64, results: &mut Vec<String>) {
    bench::header(&format!("E15b: rule-set evaluation cost ({sweeps} sweeps)"));
    alerts::clear();
    let t0 = Instant::now();
    for _ in 0..sweeps {
        let statuses = alerts::evaluate("bench");
        assert_eq!(statuses.len(), alerts::RULES.len());
    }
    let secs = t0.elapsed().as_secs_f64();
    let per_sec = sweeps as f64 / secs;
    bench::metric("rule-set sweeps/s", format!("{per_sec:.0}"));
    bench::metric("mean sweep cost", format!("{:.1} µs", secs / sweeps as f64 * 1e6));
    let json = format!(
        r#"{{"bench":"alerts","stage":"eval_cost","sweeps":{sweeps},"sweeps_per_sec":{per_sec:.0}}}"#,
    );
    println!("{json}");
    results.push(json);
}

/// The pending → firing lifecycle must engage against a real breaching
/// source and leave a journal trail.
fn lifecycle(results: &mut Vec<String>) {
    bench::header("E15c: pending -> firing lifecycle against a breaching source");
    alerts::clear();
    alerts::register_source(
        "scatter_lag_records",
        "bench scatter".to_string(),
        Box::new(|| Some(5e9)),
    );
    let mut fired = false;
    for _ in 0..4 {
        let statuses = alerts::evaluate("bench");
        fired = statuses
            .iter()
            .any(|s| s.rule == "scatter_lag_high" && s.state == alerts::State::Firing);
        if fired {
            break;
        }
    }
    assert!(fired, "scatter_lag_high never fired against a 5e9 lag source");
    let journaled = alerts::recent_events(16)
        .iter()
        .any(|e| e.kind == "alert_firing" && e.name == "scatter_lag_high");
    assert!(journaled, "firing transition missing from the event journal");
    alerts::clear();
    bench::metric("lifecycle pending -> firing -> journal", "ok");
    let json = r#"{"bench":"alerts","stage":"lifecycle","fired":true,"journaled":true}"#
        .to_string();
    println!("{json}");
    results.push(json);
}

/// The engine only reads registry state: sync-batch bytes must be
/// identical with the evaluator off and ticking on every batch.
fn byte_identity(results: &mut Vec<String>) {
    bench::header("E15d: sync-batch byte identity, evaluator off vs ticking");
    let run = |tick_ms: u64| -> Vec<u8> {
        alerts::clear();
        let _ticker = alerts::spawn_ticker("bench", tick_ms);
        let mut p = pipeline();
        for round in 0..10u64 {
            let ids: Vec<u64> = (0..512).map(|i| (i * 13 + round) % 1_999).collect();
            let grads = vec![0.5f32; ids.len() * DIM];
            p.master
                .sparse_push(&SparsePush { model: "ctr".into(), table: "v".into(), ids, grads })
                .unwrap();
            // Give the ticker a real window to race the gather.
            if tick_ms > 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        p.gather.flush_now().iter().flat_map(|b| b.to_bytes()).collect()
    };
    let off = run(0);
    assert_eq!(run(1), off, "sync-batch bytes changed with the evaluator ticking");
    alerts::clear();
    bench::metric("sync-batch bytes identical with evaluator off/on", "ok");
    let json =
        r#"{"bench":"alerts","stage":"byte_identity","modes":2,"identical":true}"#.to_string();
    println!("{json}");
    results.push(json);
}

fn main() {
    let (trials, rounds, ids_per_round, sweeps) =
        if smoke() { (2u64, 10u64, 512u64, 200u64) } else { (3u64, 40u64, 2_048u64, 2_000u64) };
    let mut results = Vec::new();
    overhead(trials, rounds, ids_per_round, &mut results);
    eval_cost(sweeps, &mut results);
    lifecycle(&mut results);
    byte_identity(&mut results);
    let json = format!("[\n  {}\n]\n", results.join(",\n  "));
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package has a parent dir")
        .join("BENCH_alerts.json");
    std::fs::write(&out, &json).expect("write BENCH_alerts.json");
    println!("\nwrote {} ({} records)", out.display(), results.len());
}
