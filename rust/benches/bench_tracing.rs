//! E14 — update-journey tracing overhead on the streaming sync path.
//!
//! The trace layer derives its per-batch context from envelope fields
//! already on the wire and samples every n-th sequence number, so the
//! untraced hot path pays exactly one relaxed load + branch per stage.
//! This bench holds that claim to numbers:
//!   - gather → queue → scatter pipeline throughput, tracing off vs
//!     sampled at `trace_sample_every = 64` (the documented production
//!     cadence), interleaved best-of-trials so host noise cancels;
//!   - a fully-sampled push must leave one complete span chain covering
//!     at least 6 declared stages (asserted in-run);
//!   - sync-batch bytes must be identical with tracing off, sampled,
//!     and fully on (asserted in-run — the context never rides the
//!     wire).
//!
//! Needs no AOT artifacts. Emits one-line JSON records and writes the
//! result set to `BENCH_tracing.json`; CI uploads the artifact and
//! gates `overhead_frac <= 0.05` (≤5% sampled-tracing overhead) via
//! `tools/check_bench_regression.py --kind tracing`.
//! `WEIPS_BENCH_SMOKE=1` shrinks sizes for CI smoke runs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use weips::codec::Encode;
use weips::config::{GatherMode, ModelKind, ModelSpec};
use weips::optim::{Ftrl, FtrlHyper, Optimizer};
use weips::proto::SparsePush;
use weips::queue::Queue;
use weips::runtime::ModelConfig;
use weips::server::master::MasterShard;
use weips::server::slave::SlaveShard;
use weips::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use weips::trace;
use weips::util::bench;
use weips::util::clock::ManualClock;

const DIM: usize = 8;
const SAMPLE_EVERY: u64 = 64;

fn smoke() -> bool {
    std::env::var("WEIPS_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: DIM,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn serving() -> Arc<SlaveShard> {
    let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
    Arc::new(SlaveShard::with_stripes(
        0,
        0,
        "ctr",
        vec![("w".into(), 1), ("v".into(), DIM)],
        vec![("bias".into(), 1)],
        Arc::new(ServingWeights::new(vec![
            ("w".into(), ftrl.clone(), 1),
            ("v".into(), ftrl, DIM),
        ])),
        Router::new(1),
        8,
    ))
}

struct Pipeline {
    master: Arc<MasterShard>,
    gather: Gather,
    pusher: Pusher,
    scatter: Scatter,
}

fn pipeline() -> Pipeline {
    let clock = Arc::new(ManualClock::new(0));
    let master =
        Arc::new(MasterShard::with_stripes(0, spec(), None, 1, 8, clock.clone()).unwrap());
    let queue = Queue::new(1 << 30);
    let topic = queue.create_topic("sync.ctr", 1).unwrap();
    let gather =
        Gather::with_pool(master.clone(), GatherMode::Realtime, clock.clone(), None);
    let pusher = Pusher::new(topic.clone(), 0);
    let scatter = Scatter::with_pool(topic, serving(), 1, 1, clock, None);
    Pipeline { master, gather, pusher, scatter }
}

/// One full pipeline drive: `rounds` sparse pushes, each flushed through
/// the gather, queued, and scattered into serving. Returns rows/s.
fn drive(sample_every: u64, rounds: u64, ids_per_round: u64) -> f64 {
    trace::configure(sample_every);
    trace::clear();
    let mut p = pipeline();
    let t0 = Instant::now();
    for round in 0..rounds {
        let ids: Vec<u64> = (round * ids_per_round..(round + 1) * ids_per_round).collect();
        let grads = vec![0.1f32; ids.len() * DIM];
        p.master
            .sparse_push(&SparsePush { model: "ctr".into(), table: "v".into(), ids, grads })
            .unwrap();
        p.pusher.push_all(&p.gather.flush_now()).unwrap();
        p.scatter.poll(Duration::ZERO).unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    trace::configure(0);
    trace::clear();
    (rounds * ids_per_round) as f64 / secs
}

fn overhead(trials: u64, rounds: u64, ids_per_round: u64, results: &mut Vec<String>) {
    bench::header(&format!(
        "E14a: tracing overhead, off vs sampled every {SAMPLE_EVERY} \
         ({rounds} rounds x {ids_per_round} ids)"
    ));
    // Interleave the two configurations and keep each one's best trial:
    // min-noise estimates of the same workload on the same host.
    let mut best_off = 0.0f64;
    let mut best_on = 0.0f64;
    for _ in 0..trials {
        best_off = best_off.max(drive(0, rounds, ids_per_round));
        best_on = best_on.max(drive(SAMPLE_EVERY, rounds, ids_per_round));
    }
    let overhead_frac = 1.0 - best_on / best_off;
    bench::metric("pipeline rows/s (tracing off)", format!("{:.2} M", best_off / 1e6));
    bench::metric(
        &format!("pipeline rows/s (sampled every {SAMPLE_EVERY})"),
        format!("{:.2} M", best_on / 1e6),
    );
    bench::metric("sampled-tracing overhead", format!("{:.2}%", overhead_frac * 100.0));
    for (mode, rate) in [("off", best_off), ("sampled", best_on)] {
        let json = format!(
            r#"{{"bench":"tracing","stage":"pipeline_throughput","mode":"{mode}","sample_every":{},"rows_per_sec":{rate:.0}}}"#,
            if mode == "off" { 0 } else { SAMPLE_EVERY }
        );
        println!("{json}");
        results.push(json);
    }
    let json = format!(
        r#"{{"bench":"tracing","stage":"overhead","sample_every":{SAMPLE_EVERY},"off_rows_per_sec":{best_off:.0},"sampled_rows_per_sec":{best_on:.0},"overhead_frac":{overhead_frac:.4}}}"#,
    );
    println!("{json}");
    results.push(json);
}

/// A fully-sampled push must leave one complete retrievable span chain.
fn chain_check(ids_per_round: u64, results: &mut Vec<String>) {
    bench::header("E14b: sampled span-chain completeness");
    trace::configure(1);
    trace::clear();
    let mut p = pipeline();
    let ids: Vec<u64> = (0..ids_per_round).collect();
    let grads = vec![0.1f32; ids.len() * DIM];
    p.master
        .sparse_push(&SparsePush { model: "ctr".into(), table: "v".into(), ids, grads })
        .unwrap();
    let batches = p.gather.flush_now();
    let b = batches.iter().find(|b| b.table == "v").expect("no sparse batch emitted");
    let id = trace::trace_id(&b.model, &b.table, b.shard, b.seq);
    p.pusher.push_all(&batches).unwrap();
    p.scatter.poll(Duration::ZERO).unwrap();
    let spans = trace::spans_for(id);
    let mut stages: Vec<&str> = spans.iter().map(|s| s.stage).collect();
    stages.sort_unstable();
    stages.dedup();
    assert!(
        stages.len() >= 6,
        "sampled chain incomplete: {} stages ({stages:?})",
        stages.len()
    );
    trace::configure(0);
    trace::clear();
    bench::metric("distinct stages in sampled chain", stages.len());
    let json = format!(
        r#"{{"bench":"tracing","stage":"chain","distinct_stages":{},"complete":true}}"#,
        stages.len()
    );
    println!("{json}");
    results.push(json);
}

/// The trace context is derived, never encoded: sync-batch bytes must be
/// identical with tracing off, sampled, and fully on.
fn byte_identity(results: &mut Vec<String>) {
    bench::header("E14c: sync-batch byte identity across sample rates");
    let run = |sample_every: u64| -> Vec<u8> {
        trace::configure(sample_every);
        trace::clear();
        let mut p = pipeline();
        for round in 0..10u64 {
            let ids: Vec<u64> = (0..512).map(|i| (i * 13 + round) % 1_999).collect();
            let grads = vec![0.5f32; ids.len() * DIM];
            p.master
                .sparse_push(&SparsePush { model: "ctr".into(), table: "v".into(), ids, grads })
                .unwrap();
        }
        let bytes: Vec<u8> = p.gather.flush_now().iter().flat_map(|b| b.to_bytes()).collect();
        trace::configure(0);
        trace::clear();
        bytes
    };
    let off = run(0);
    for (label, rate) in [("sampled", SAMPLE_EVERY), ("every batch", 1)] {
        assert_eq!(run(rate), off, "sync-batch bytes changed with tracing {label}");
    }
    bench::metric("sync-batch bytes identical at sample rates 0/64/1", "ok");
    let json =
        r#"{"bench":"tracing","stage":"byte_identity","modes":3,"identical":true}"#.to_string();
    println!("{json}");
    results.push(json);
}

fn main() {
    let (trials, rounds, ids_per_round) =
        if smoke() { (2u64, 10u64, 512u64) } else { (3u64, 40u64, 2_048u64) };
    let mut results = Vec::new();
    overhead(trials, rounds, ids_per_round, &mut results);
    chain_check(ids_per_round, &mut results);
    byte_identity(&mut results);
    let json = format!("[\n  {}\n]\n", results.join(",\n  "));
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package has a parent dir")
        .join("BENCH_tracing.json");
    std::fs::write(&out, &json).expect("write BENCH_tracing.json");
    println!("\nwrote {} ({} records)", out.display(), results.len());
}
