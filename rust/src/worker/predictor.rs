//! Predictor worker (§3.1): low-latency online inference.
//!
//! Serves ranking requests from the slave cluster: pull serving weights
//! from replica groups (with hot-backup failover), execute the AOT
//! `*_predict` module. Requests are micro-batched up to the compiled
//! batch size; the tail is padded and the padding discarded — latency
//! stays bounded, the executable stays shape-static.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::{ModelKind, ModelSpec};
use crate::runtime::{Engine, Tensor};
use crate::util::Histogram;
use crate::worker::client::SlaveClient;
use crate::{Error, Result};

/// Serving metrics.
#[derive(Debug, Default)]
pub struct PredictorMetrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub failures: AtomicU64,
    /// Per-request latency (ns).
    pub latency_ns: Histogram,
}

/// The predictor worker.
pub struct Predictor {
    engine: Arc<Engine>,
    spec: ModelSpec,
    client: SlaveClient,
    pub metrics: PredictorMetrics,
}

impl Predictor {
    /// New predictor.
    pub fn new(engine: Arc<Engine>, spec: ModelSpec, client: SlaveClient) -> Predictor {
        Predictor { engine, spec, client, metrics: PredictorMetrics::default() }
    }

    /// The model spec in use.
    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// The slave client (failure injection in tests).
    pub fn client(&self) -> &SlaveClient {
        &self.client
    }

    /// Predict CTR for each request (`ids` per request = one sample's
    /// feature ids). Any request count; internally chunked to the compiled
    /// batch size.
    pub fn predict(&self, requests: &[Vec<u64>]) -> Result<Vec<f32>> {
        let start = crate::util::mono_ns();
        let b = self.spec.batch_predict;
        let f = self.spec.fields;
        let mut out = Vec::with_capacity(requests.len());
        for chunk in requests.chunks(b) {
            let mut flat_ids = Vec::with_capacity(b * f);
            for req in chunk {
                if req.len() != f {
                    self.metrics.failures.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::State(format!(
                        "request has {} fields, model wants {f}",
                        req.len()
                    )));
                }
                flat_ids.extend_from_slice(req);
            }
            // Pad the tail chunk by repeating the last request.
            let pad = b - chunk.len();
            for _ in 0..pad {
                let last = &chunk[chunk.len() - 1];
                flat_ids.extend_from_slice(last);
            }
            let preds = self.predict_padded(&flat_ids)?;
            out.extend_from_slice(&preds[..chunk.len()]);
        }
        self.metrics.requests.fetch_add(requests.len() as u64, Ordering::Relaxed);
        let elapsed = crate::util::mono_ns() - start;
        for _ in 0..requests.len() {
            self.metrics
                .latency_ns
                .record(elapsed / requests.len().max(1) as u64);
        }
        Ok(out)
    }

    fn predict_padded(&self, flat_ids: &[u64]) -> Result<Vec<f32>> {
        let b = self.spec.batch_predict;
        let f = self.spec.fields;
        let k = self.spec.dim;
        debug_assert_eq!(flat_ids.len(), b * f);
        self.metrics.batches.fetch_add(1, Ordering::Relaxed);

        let (_, w_vals) = self.client.sparse_pull("w", flat_ids)?;
        let w = Tensor::new(vec![b, f], w_vals);
        let dense: Vec<Tensor> = self
            .spec
            .dense
            .iter()
            .map(|d| {
                let values = self.client.dense_pull(&d.name)?;
                Ok(self.dense_to_tensor(&d.name, values))
            })
            .collect::<Result<Vec<_>>>()?;

        let outputs = match self.spec.kind {
            ModelKind::Lr => {
                let mut inputs = vec![w];
                inputs.extend(dense);
                self.engine.execute("lr_predict", &inputs)?
            }
            ModelKind::Fm => {
                let (_, v_vals) = self.client.sparse_pull("v", flat_ids)?;
                let v = Tensor::new(vec![b, f, k], v_vals);
                let mut inputs = vec![w, v];
                inputs.extend(dense);
                self.engine.execute("fm_predict", &inputs)?
            }
            ModelKind::DeepFm => {
                let (_, v_vals) = self.client.sparse_pull("v", flat_ids)?;
                let v = Tensor::new(vec![b, f, k], v_vals);
                let mut inputs = vec![w, v];
                inputs.extend(dense);
                self.engine.execute("deepfm_predict", &inputs)?
            }
        };
        Ok(outputs[0].data.clone())
    }

    fn dense_to_tensor(&self, name: &str, values: Vec<f32>) -> Tensor {
        let (f, k, h) = (self.spec.fields, self.spec.dim, self.spec.hidden);
        match name {
            "w1" => Tensor::new(vec![f * k, h], values),
            "w2" => Tensor::new(vec![h, 1], values),
            _ => Tensor::vec1(values),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Channel;
    use crate::optim::{Ftrl, FtrlHyper, Optimizer};
    use crate::proto::{SyncBatch, SyncEntry, SyncOp};
    use crate::replica::{BalancePolicy, ReplicaGroup};
    use crate::runtime::default_artifacts_dir;
    use crate::server::slave::{SlaveService, SlaveShard};
    use crate::sync::router::Router;
    use crate::sync::transform::ServingWeights;
    use crate::worker::client::SlaveEndpoint;

    fn build(kind: ModelKind) -> Option<(Predictor, Vec<Vec<Arc<SlaveShard>>>)> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping predictor test: run `make artifacts`");
            return None;
        }
        let engine = Arc::new(Engine::load(dir).unwrap());
        let spec = ModelSpec::derive("ctr", kind, engine.config());
        let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
        let mut table_layout = vec![("w".to_string(), 1usize)];
        let mut tf = vec![("w".to_string(), ftrl.clone(), 1usize)];
        if !matches!(kind, ModelKind::Lr) {
            table_layout.push(("v".to_string(), spec.dim));
            tf.push(("v".to_string(), ftrl.clone(), spec.dim));
        }
        let dense_layout: Vec<(String, usize)> =
            spec.dense.iter().map(|d| (d.name.clone(), d.len)).collect();
        let shards = 2u32;
        let mut groups = Vec::new();
        let mut all = Vec::new();
        for s in 0..shards {
            let mut eps = Vec::new();
            let mut reps = Vec::new();
            for r in 0..2u32 {
                let shard = Arc::new(SlaveShard::new(
                    s,
                    r,
                    "ctr",
                    table_layout.clone(),
                    dense_layout.clone(),
                    Arc::new(ServingWeights::new(tf.clone())),
                    Router::new(shards),
                ));
                let ch = Channel::local(Arc::new(SlaveService { shard: shard.clone() }));
                eps.push(Arc::new(SlaveEndpoint::local(ch, shard.clone())));
                reps.push(shard);
            }
            groups.push(Arc::new(ReplicaGroup::new(eps, BalancePolicy::RoundRobin)));
            all.push(reps);
        }
        let client = SlaveClient::new("ctr", groups);
        Some((Predictor::new(engine, spec, client), all))
    }

    fn seed_w(slaves: &[Vec<Arc<SlaveShard>>], id: u64, w: f32) {
        let router = Router::new(slaves.len() as u32);
        let batch = SyncBatch {
            model: "ctr".into(),
            table: "w".into(),
            shard: 0,
            seq: 0,
            created_ms: 0,
            entries: vec![SyncEntry { id, op: SyncOp::Upsert(vec![0.0, 0.0, w]) }],
            dense: vec![],
        };
        for replica in &slaves[router.shard_of(id) as usize] {
            replica.apply_batch(&batch).unwrap();
        }
    }

    #[test]
    fn lr_predictions_match_sigmoid_of_weights() {
        let Some((p, slaves)) = build(ModelKind::Lr) else { return };
        let f = p.spec().fields;
        // Request 0: all-zero weights (p = 0.5); request 1: each field 0.1.
        let req0: Vec<u64> = (1_000..1_000 + f as u64).collect();
        let req1: Vec<u64> = (2_000..2_000 + f as u64).collect();
        for &id in &req1 {
            seed_w(&slaves, id, 0.1);
        }
        let preds = p.predict(&[req0, req1]).unwrap();
        assert!((preds[0] - 0.5).abs() < 1e-6);
        let logit = 0.1 * f as f32;
        let want = 1.0 / (1.0 + (-logit).exp());
        assert!((preds[1] - want).abs() < 1e-5, "{} vs {want}", preds[1]);
    }

    #[test]
    fn odd_request_counts_are_padded_correctly() {
        let Some((p, _)) = build(ModelKind::Lr) else { return };
        let f = p.spec().fields;
        let b = p.spec().batch_predict;
        let reqs: Vec<Vec<u64>> = (0..(b * 2 + 1))
            .map(|i| ((i * 100) as u64..(i * 100 + f) as u64).collect())
            .collect();
        let preds = p.predict(&reqs).unwrap();
        assert_eq!(preds.len(), b * 2 + 1);
        assert!(preds.iter().all(|x| (x - 0.5).abs() < 1e-6)); // all zero weights
        assert_eq!(p.metrics.batches.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn fm_prediction_uses_factors() {
        let Some((p, slaves)) = build(ModelKind::Fm) else { return };
        let f = p.spec().fields;
        let req: Vec<u64> = (3_000..3_000 + f as u64).collect();
        let baseline = p.predict(&[req.clone()]).unwrap()[0];
        // Give two ids identical factor vectors -> positive interaction.
        let k = p.spec().dim;
        let router = Router::new(slaves.len() as u32);
        for &id in &req[..2] {
            let mut row = vec![0.0; 3 * k];
            row[2 * k..].iter_mut().for_each(|x| *x = 1.0); // w slot = ones
            let batch = SyncBatch {
                model: "ctr".into(),
                table: "v".into(),
                shard: 0,
                seq: 0,
                created_ms: 0,
                entries: vec![SyncEntry { id, op: SyncOp::Upsert(row) }],
                dense: vec![],
            };
            for replica in &slaves[router.shard_of(id) as usize] {
                replica.apply_batch(&batch).unwrap();
            }
        }
        let with_factors = p.predict(&[req]).unwrap()[0];
        assert!(with_factors > baseline + 0.1, "{with_factors} vs {baseline}");
    }

    #[test]
    fn replica_failure_transparent_to_serving() {
        let Some((p, slaves)) = build(ModelKind::Lr) else { return };
        let f = p.spec().fields;
        let req: Vec<u64> = (0..f as u64).collect();
        slaves[0][0].set_healthy(false);
        slaves[1][0].set_healthy(false);
        let preds = p.predict(&[req]).unwrap();
        assert_eq!(preds.len(), 1);
    }
}
