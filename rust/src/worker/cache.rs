//! Predictor-side hot-id cache with epoch-based invalidation (§3.1).
//!
//! Online-serving reads are extremely skewed: a small set of hot ids
//! (fresh users, trending items) dominates the pull stream. Caching them
//! at the worker removes the RPC round-trip — but a TTL cache would
//! reintroduce exactly the staleness the streaming channel exists to
//! eliminate. Instead the cache *subscribes* to the same update stream
//! that keeps slaves fresh: it is registered as a [`ScatterTap`] on the
//! local scatter, and every applied [`SyncBatch`] invalidates the touched
//! ids before the scatter's poll returns. The coherence guarantee is
//! therefore structural, not temporal: a pushed update is visible to
//! cached reads within one sync tick, the same bound the serving tables
//! themselves have. No clock is involved anywhere.
//!
//! Fill race: a reader may capture a value from a slave, lose the CPU,
//! and insert it *after* the scatter invalidated that id — resurrecting
//! the stale row with no future invalidation to evict it (the stream
//! only carries each update once). The cache closes this with a global
//! invalidation tick: readers snapshot the tick before fetching
//! ([`HotIdCache::fill_tick`]) and the insert is dropped when the id's
//! stripe was invalidated after the snapshot. Skip-on-doubt: a dropped
//! insert only costs the next read a miss, never correctness.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, RwLock, Weak};

use crate::proto::SyncBatch;
use crate::sync::ScatterTap;
use crate::util::fxhash64;

/// Stripe count for both the per-table maps and the invalidation ticks.
/// Power of two; bounds writer contention between the scatter thread and
/// concurrent predictor reads.
const STRIPES: usize = 64;

#[inline]
fn stripe_of(id: u64) -> usize {
    (fxhash64(id) as usize) & (STRIPES - 1)
}

/// Hit/miss/invalidation accounting, sampled into the metrics registry
/// via [`HotIdCache::register_metrics`].
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub invalidations: AtomicU64,
    pub inserts: AtomicU64,
    /// Inserts dropped by the fill-race guard or the capacity cap.
    pub rejected_inserts: AtomicU64,
}

/// One sparse table's cached rows. Width is learned from the first
/// filled row and is stable per table (serving width is fixed by the
/// slave's transform config).
struct TableCache {
    width: AtomicU32,
    stripes: Vec<RwLock<HashMap<u64, Box<[f32]>>>>,
}

impl TableCache {
    fn new() -> Arc<TableCache> {
        Arc::new(TableCache {
            width: AtomicU32::new(0),
            stripes: (0..STRIPES).map(|_| RwLock::new(HashMap::new())).collect(),
        })
    }
}

/// The worker-side hot-id cache. Shared between the serving client
/// (reads + fills) and the scatter (invalidations via [`ScatterTap`]).
pub struct HotIdCache {
    tables: RwLock<HashMap<String, Arc<TableCache>>>,
    /// Dense tables cached wholesale (they sync as full snapshots).
    dense: RwLock<HashMap<String, Arc<[f32]>>>,
    /// Per-stripe last-invalidation tick, shared across tables so the
    /// fill-race guard holds even for a table's very first fill (the
    /// stripe tick exists before the table map does).
    stripe_ticks: Vec<AtomicU64>,
    /// Tick guarding dense snapshots (dense tables have no stripes).
    dense_tick: AtomicU64,
    /// Global invalidation tick; bumped once per applied batch set.
    tick: AtomicU64,
    /// Soft cap on cached sparse rows across all tables; inserts beyond
    /// it are dropped (the working set keeps itself hot via misses).
    capacity_rows: u64,
    rows: AtomicU64,
    pub stats: CacheStats,
}

impl HotIdCache {
    /// New cache bounded to `capacity_rows` sparse rows (0 = cache
    /// nothing sparse; dense snapshots are always cached).
    pub fn new(capacity_rows: u64) -> Arc<HotIdCache> {
        Arc::new(HotIdCache {
            tables: RwLock::new(HashMap::new()),
            dense: RwLock::new(HashMap::new()),
            stripe_ticks: (0..STRIPES).map(|_| AtomicU64::new(0)).collect(),
            dense_tick: AtomicU64::new(0),
            tick: AtomicU64::new(0),
            capacity_rows,
            rows: AtomicU64::new(0),
            stats: CacheStats::default(),
        })
    }

    /// Expose hit/miss/invalidation counters under the given role label.
    /// Samplers hold a `Weak`; dropping the cache prunes them.
    pub fn register_metrics(self: &Arc<Self>, role: &str) {
        type Get = fn(&HotIdCache) -> u64;
        let series: [(&'static str, Get); 3] = [
            ("weips_cache_hits_total", |c| c.stats.hits.load(Ordering::Relaxed)),
            ("weips_cache_misses_total", |c| c.stats.misses.load(Ordering::Relaxed)),
            ("weips_cache_invalidations_total", |c| {
                c.stats.invalidations.load(Ordering::Relaxed)
            }),
        ];
        for (name, get) in series {
            let weak: Weak<HotIdCache> = Arc::downgrade(self);
            crate::metrics::register_fn(
                name,
                &[("role", role.to_string())],
                Box::new(move || weak.upgrade().map(|c| get(&c) as f64)),
            );
        }
    }

    /// Snapshot the invalidation tick *before* probing/fetching a fill
    /// round; pass it back to [`insert`](Self::insert) so racing
    /// invalidations win over the fill.
    pub fn fill_tick(&self) -> u64 {
        self.tick.load(Ordering::SeqCst)
    }

    /// Serving width for `table`, if any row was ever cached for it.
    pub fn width(&self, table: &str) -> Option<u32> {
        let tc = self.tables.read().unwrap().get(table).cloned()?;
        match tc.width.load(Ordering::Relaxed) {
            0 => None,
            w => Some(w),
        }
    }

    /// Copy the cached row for `(table, id)` into `out`; false on miss
    /// (including width mismatch, which never happens in practice).
    pub fn copy_into(&self, table: &str, id: u64, out: &mut [f32]) -> bool {
        let Some(tc) = self.tables.read().unwrap().get(table).cloned() else {
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            return false;
        };
        let map = tc.stripes[stripe_of(id)].read().unwrap();
        match map.get(&id) {
            Some(row) if row.len() == out.len() => {
                out.copy_from_slice(row);
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                true
            }
            _ => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                false
            }
        }
    }

    /// Insert a freshly fetched row. `fill_tick` must predate the remote
    /// fetch; the insert is dropped when the id's stripe was invalidated
    /// since (the fetched bytes may predate the invalidating update) or
    /// when the cache is at capacity.
    pub fn insert(&self, table: &str, id: u64, values: &[f32], fill_tick: u64) {
        if self.capacity_rows == 0 || values.is_empty() {
            return;
        }
        let stripe = stripe_of(id);
        if self.stripe_ticks[stripe].load(Ordering::SeqCst) > fill_tick {
            self.stats.rejected_inserts.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let tc = {
            let tables = self.tables.read().unwrap();
            match tables.get(table) {
                Some(tc) => tc.clone(),
                None => {
                    drop(tables);
                    self.tables
                        .write()
                        .unwrap()
                        .entry(table.to_string())
                        .or_insert_with(TableCache::new)
                        .clone()
                }
            }
        };
        tc.width.store(values.len() as u32, Ordering::Relaxed);
        let mut map = tc.stripes[stripe].write().unwrap();
        // Re-check under the stripe write lock: an invalidation that ran
        // between the guard check and lock acquisition must still win.
        if self.stripe_ticks[stripe].load(Ordering::SeqCst) > fill_tick {
            self.stats.rejected_inserts.fetch_add(1, Ordering::Relaxed);
            return;
        }
        match map.entry(id) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                e.insert(values.into());
                self.stats.inserts.fetch_add(1, Ordering::Relaxed);
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                if self.rows.load(Ordering::Relaxed) >= self.capacity_rows {
                    self.stats.rejected_inserts.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                self.rows.fetch_add(1, Ordering::Relaxed);
                e.insert(values.into());
                self.stats.inserts.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Cached dense snapshot for `table`.
    pub fn dense_get(&self, table: &str) -> Option<Arc<[f32]>> {
        let hit = self.dense.read().unwrap().get(table).cloned();
        match hit {
            Some(v) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Cache a dense snapshot fetched after `fill_tick` was captured.
    pub fn dense_insert(&self, table: &str, values: Vec<f32>, fill_tick: u64) {
        if self.dense_tick.load(Ordering::SeqCst) > fill_tick {
            self.stats.rejected_inserts.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut dense = self.dense.write().unwrap();
        if self.dense_tick.load(Ordering::SeqCst) > fill_tick {
            self.stats.rejected_inserts.fetch_add(1, Ordering::Relaxed);
            return;
        }
        dense.insert(table.to_string(), values.into());
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
    }

    /// Cached sparse rows across all tables (approximate under races).
    pub fn len(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    /// True when no sparse row is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop everything (tests; also useful after a full resync).
    pub fn clear(&self) {
        let tick = self.tick.fetch_add(1, Ordering::SeqCst) + 1;
        for t in &self.stripe_ticks {
            t.store(tick, Ordering::SeqCst);
        }
        self.dense_tick.store(tick, Ordering::SeqCst);
        for tc in self.tables.read().unwrap().values() {
            for s in &tc.stripes {
                s.write().unwrap().clear();
            }
        }
        self.dense.write().unwrap().clear();
        self.rows.store(0, Ordering::Relaxed);
    }

    /// Cumulative hit rate in `[0, 1]` (0 when nothing was probed).
    pub fn hit_rate(&self) -> f64 {
        let h = self.stats.hits.load(Ordering::Relaxed) as f64;
        let m = self.stats.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

impl ScatterTap for HotIdCache {
    /// Invalidate every id the scatter just applied. Runs on the scatter
    /// thread inside `poll()` — *before* the poll returns — which is what
    /// makes "visible within one sync tick" a hard guarantee rather than
    /// a TTL hope. Tick ordering: the global tick and the touched stripe
    /// ticks are bumped first, so any in-flight fill that fetched
    /// pre-apply bytes fails its guard check.
    fn on_applied(&self, batches: &[SyncBatch]) {
        if batches.is_empty() {
            return;
        }
        let tick = self.tick.fetch_add(1, Ordering::SeqCst) + 1;
        let tables = self.tables.read().unwrap();
        for batch in batches {
            if !batch.dense.is_empty() {
                self.dense_tick.store(tick, Ordering::SeqCst);
                if self.dense.write().unwrap().remove(&batch.table).is_some() {
                    self.stats.invalidations.fetch_add(1, Ordering::Relaxed);
                }
            }
            if batch.entries.is_empty() {
                continue;
            }
            let tc = tables.get(&batch.table);
            for entry in &batch.entries {
                let stripe = stripe_of(entry.id);
                self.stripe_ticks[stripe].store(tick, Ordering::SeqCst);
                if let Some(tc) = tc {
                    if tc.stripes[stripe].write().unwrap().remove(&entry.id).is_some() {
                        self.rows.fetch_sub(1, Ordering::Relaxed);
                        self.stats.invalidations.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::{SyncEntry, SyncOp};

    fn batch(table: &str, ids: &[u64]) -> SyncBatch {
        SyncBatch {
            model: "m".into(),
            table: table.into(),
            shard: 0,
            seq: 1,
            created_ms: 0,
            entries: ids
                .iter()
                .map(|&id| SyncEntry { id, op: SyncOp::Upsert(vec![1.0]) })
                .collect(),
            dense: Vec::new(),
        }
    }

    #[test]
    fn hit_after_insert_miss_after_invalidate() {
        let cache = HotIdCache::new(1024);
        let tick = cache.fill_tick();
        cache.insert("w", 7, &[0.5, 0.25], tick);
        let mut out = [0.0f32; 2];
        assert!(cache.copy_into("w", 7, &mut out));
        assert_eq!(out, [0.5, 0.25]);
        assert_eq!(cache.width("w"), Some(2));

        cache.on_applied(&[batch("w", &[7])]);
        assert!(!cache.copy_into("w", 7, &mut out));
        assert_eq!(cache.stats.invalidations.load(Ordering::Relaxed), 1);
        assert!(cache.is_empty());
    }

    #[test]
    fn racing_invalidation_beats_stale_fill() {
        let cache = HotIdCache::new(1024);
        // Reader snapshots the tick, then the scatter applies an update
        // for the id before the reader's insert lands.
        let stale_tick = cache.fill_tick();
        cache.on_applied(&[batch("w", &[7])]);
        cache.insert("w", 7, &[9.0], stale_tick);
        let mut out = [0.0f32];
        assert!(!cache.copy_into("w", 7, &mut out), "stale fill must not stick");
        assert_eq!(cache.stats.rejected_inserts.load(Ordering::Relaxed), 1);
        // A fill that starts after the invalidation is fine.
        cache.insert("w", 7, &[2.0], cache.fill_tick());
        assert!(cache.copy_into("w", 7, &mut out));
        assert_eq!(out, [2.0]);
    }

    #[test]
    fn invalidation_guards_table_never_filled_yet() {
        let cache = HotIdCache::new(1024);
        // First-ever fill for table "v" races an invalidation for the
        // same id: the stripe tick exists independently of the table map,
        // so the guard still rejects the insert.
        let stale_tick = cache.fill_tick();
        cache.on_applied(&[batch("v", &[42])]);
        cache.insert("v", 42, &[1.0], stale_tick);
        let mut out = [0.0f32];
        assert!(!cache.copy_into("v", 42, &mut out));
    }

    #[test]
    fn capacity_caps_new_rows_but_allows_updates() {
        let cache = HotIdCache::new(2);
        let t = cache.fill_tick();
        cache.insert("w", 1, &[1.0], t);
        cache.insert("w", 2, &[2.0], t);
        cache.insert("w", 3, &[3.0], t); // over cap: dropped
        assert_eq!(cache.len(), 2);
        let mut out = [0.0f32];
        assert!(!cache.copy_into("w", 3, &mut out));
        // Overwriting an existing row is not growth.
        cache.insert("w", 1, &[1.5], cache.fill_tick());
        assert!(cache.copy_into("w", 1, &mut out));
        assert_eq!(out, [1.5]);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn dense_snapshot_invalidated_by_dense_batch() {
        let cache = HotIdCache::new(16);
        let t = cache.fill_tick();
        cache.dense_insert("bias", vec![0.1, 0.2], t);
        assert_eq!(cache.dense_get("bias").unwrap().as_ref(), &[0.1, 0.2]);
        let dense_batch = SyncBatch {
            model: "m".into(),
            table: "bias".into(),
            shard: 0,
            seq: 2,
            created_ms: 0,
            entries: Vec::new(),
            dense: vec![0.3, 0.4],
        };
        cache.on_applied(&[dense_batch]);
        assert!(cache.dense_get("bias").is_none());
        // Stale dense fill captured before the invalidation is rejected.
        cache.dense_insert("bias", vec![0.1, 0.2], t);
        assert!(cache.dense_get("bias").is_none());
    }

    #[test]
    fn zero_capacity_disables_sparse_caching() {
        let cache = HotIdCache::new(0);
        cache.insert("w", 1, &[1.0], cache.fill_tick());
        let mut out = [0.0f32];
        assert!(!cache.copy_into("w", 1, &mut out));
        assert_eq!(cache.len(), 0);
    }
}
