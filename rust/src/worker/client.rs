//! WeiPS-client (§3.1): the worker-side access library.
//!
//! "The interactions between the servers are all through WeiPS-client ...
//! because the predictor and the trainer have different scheme
//! requirements, WeiPS-client carries different characteristics for that."
//!
//! Two profiles:
//! - [`ShardedClient`] (trainer profile): throughput-oriented fan-out of
//!   big pull/push batches across master shards, no failover (masters are
//!   checkpoint-recovered, §4.2.1);
//! - [`SlaveClient`] (predictor profile): latency-oriented reads against
//!   slave replica groups with health-aware failover (hot backup, §4.2.2).

use std::sync::Arc;

use crate::codec::{Decode, Encode};
use crate::net::Channel;
use crate::proto::{DensePull, DenseValues, SparsePull, SparsePush, SparseValues};
use crate::replica::{Endpoint, ReplicaGroup};
use crate::server::methods;
use crate::server::slave::SlaveShard;
use crate::sync::router::Router;
use crate::worker::cache::HotIdCache;
use crate::{Error, Result};

/// Hook invoked on a stale-route NACK before the retry re-splits: given
/// the client's router, refresh it from the authoritative published slot
/// map (a `FETCH_SLOT_MAP` RPC + `Router::install`, see
/// `cli::roles::route_refresher`). A callback keeps the client
/// transport-agnostic: in-process clients share the coordinator's router
/// cell and need no refresher at all.
pub type RouteRefresher = Arc<dyn Fn(&Router) + Send + Sync>;

/// Retry budget for routing-epoch NACKs: a push caught inside a
/// migration hand-off window re-splits and retries until the slot-map
/// epoch bump re-routes it. The budget (~40 s) deliberately outlasts
/// the coordinator's 30 s sealed-window drain deadline
/// (`LocalCluster::flush_and_drain_donor`) — a legal-but-slow migration
/// must stall concurrent trainers, never fail them.
const STALE_ROUTE_RETRIES: usize = 20_000;
const STALE_ROUTE_BACKOFF: std::time::Duration = std::time::Duration::from_millis(2);
/// Pulls retry wholesale (read-only, so restarting the whole split is
/// the simple correct shape) — at a coarser cadence than pushes so a
/// long hand-off window does not turn every stalled pull into a
/// 500-RPC/s storm. Same ~40 s total budget.
const STALE_PULL_RETRIES: usize = 2_000;
const STALE_PULL_BACKOFF: std::time::Duration = std::time::Duration::from_millis(20);

/// Trainer-profile client over the master cluster.
pub struct ShardedClient {
    model: String,
    router: Router,
    shards: Vec<Channel>,
    /// Stale-route NACKs absorbed by the retry loop (visibility for
    /// migration drills; never user-facing unless the budget runs out).
    pub stale_retries: std::sync::atomic::AtomicU64,
    /// Re-fetches the published slot map on stale-route NACKs, so remote
    /// trainers converge on a cutover without waiting out the window.
    refresher: Option<RouteRefresher>,
}

impl ShardedClient {
    /// Client over `shards` (index = master shard id) with a private
    /// uniform router.
    pub fn new(model: &str, shards: Vec<Channel>) -> ShardedClient {
        let router = Router::new(shards.len() as u32);
        Self::with_router(model, shards, router)
    }

    /// Client routing through a shared [`Router`] (the coordinator's
    /// master-cluster cell): a slot-map install re-routes this client's
    /// next split mid-stream — the elastic-resharding cutover.
    pub fn with_router(model: &str, shards: Vec<Channel>, router: Router) -> ShardedClient {
        ShardedClient {
            model: model.to_string(),
            router,
            shards,
            stale_retries: std::sync::atomic::AtomicU64::new(0),
            refresher: None,
        }
    }

    /// Master shard count.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The client's routing view (shared cell when built `with_router`).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Install the stale-route refresh hook (see [`RouteRefresher`]).
    pub fn set_route_refresher(&mut self, refresher: RouteRefresher) {
        self.refresher = Some(refresher);
    }

    /// Pull `slot` of `table` for `ids` (any length); returns values in
    /// request order, `width` floats per id. A pull NACKed with
    /// [`Error::StaleRoute`] (the split raced a migration cutover)
    /// restarts against the refreshed slot map — pulls are read-only, so
    /// wholesale retry is safe.
    pub fn sparse_pull(&self, table: &str, ids: &[u64], slot: &str) -> Result<(u32, Vec<f32>)> {
        let mut attempts = 0;
        loop {
            match self.try_sparse_pull(table, ids, slot) {
                Err(e) if e.is_stale_route() && attempts + 1 < STALE_PULL_RETRIES => {
                    attempts += 1;
                    self.stale_retries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if let Some(refresh) = &self.refresher {
                        refresh(&self.router);
                    }
                    std::thread::sleep(STALE_PULL_BACKOFF);
                }
                outcome => return outcome,
            }
        }
    }

    fn try_sparse_pull(&self, table: &str, ids: &[u64], slot: &str) -> Result<(u32, Vec<f32>)> {
        let buckets = self.router.split_ids(ids);
        let mut width = 0u32;
        let mut out: Vec<f32> = Vec::new();
        for (shard, (positions, shard_ids)) in buckets.iter().enumerate() {
            if shard_ids.is_empty() {
                continue;
            }
            if shard >= self.shards.len() {
                return Err(Error::Routing(format!(
                    "slot map routes to shard {shard} but client holds {} channels",
                    self.shards.len()
                )));
            }
            let req = SparsePull {
                model: self.model.clone(),
                table: table.to_string(),
                ids: shard_ids.clone(),
                slot: slot.to_string(),
            };
            let resp_bytes = self.shards[shard].call(methods::SPARSE_PULL, &req.to_bytes())?;
            let resp = SparseValues::from_bytes(&resp_bytes)?;
            if width == 0 {
                width = resp.width;
                out.resize(ids.len() * width as usize, 0.0);
            } else if width != resp.width {
                return Err(Error::Rpc(format!(
                    "width mismatch across shards: {width} vs {}",
                    resp.width
                )));
            }
            let w = width as usize;
            for (i, &pos) in positions.iter().enumerate() {
                out[pos * w..(pos + 1) * w].copy_from_slice(&resp.values[i * w..(i + 1) * w]);
            }
        }
        Ok((width, out))
    }

    /// Split one (ids, grads) set by the current slot map and push each
    /// bucket; NACKed buckets' ids + grads are appended to the retry
    /// accumulators instead of erroring. The hot path allocates exactly
    /// what the pre-reshard client did (per-bucket id/grad vectors) —
    /// retry state materializes only when a NACK actually happens.
    fn push_split(
        &self,
        table: &str,
        ids: &[u64],
        grads: &[f32],
        dim: usize,
        retry_ids: &mut Vec<u64>,
        retry_grads: &mut Vec<f32>,
    ) -> Result<()> {
        let buckets = self.router.split_ids(ids);
        for (shard, (positions, shard_ids)) in buckets.iter().enumerate() {
            if shard_ids.is_empty() {
                continue;
            }
            if shard >= self.shards.len() {
                return Err(Error::Routing(format!(
                    "slot map routes to shard {shard} but client holds {} channels",
                    self.shards.len()
                )));
            }
            let mut shard_grads = Vec::with_capacity(shard_ids.len() * dim);
            for &pos in positions {
                shard_grads.extend_from_slice(&grads[pos * dim..(pos + 1) * dim]);
            }
            let req = SparsePush {
                model: self.model.clone(),
                table: table.to_string(),
                ids: shard_ids.clone(),
                grads: shard_grads,
            };
            match self.shards[shard].call(methods::SPARSE_PUSH, &req.to_bytes()) {
                Ok(_) => {}
                Err(e) if e.is_stale_route() => {
                    self.stale_retries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    retry_ids.extend_from_slice(shard_ids);
                    for &pos in positions {
                        retry_grads.extend_from_slice(&grads[pos * dim..(pos + 1) * dim]);
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Push gradients for `ids` (`grads.len() == ids.len() * dim`).
    ///
    /// Stale-route aware: a shard push NACKed with [`Error::StaleRoute`]
    /// (the id's slot moved or is sealed for a live migration) was
    /// rejected *before* anything applied, so the failed subset is
    /// re-split by the then-current slot map and retried — each gradient
    /// lands exactly once, on the current owner, and nothing is silently
    /// dropped.
    pub fn sparse_push(&self, table: &str, ids: &[u64], grads: &[f32]) -> Result<()> {
        if ids.is_empty() {
            return Ok(());
        }
        let dim = grads.len() / ids.len();
        let mut pending_ids: Vec<u64> = Vec::new();
        let mut pending_grads: Vec<f32> = Vec::new();
        self.push_split(table, ids, grads, dim, &mut pending_ids, &mut pending_grads)?;
        let mut attempts = 0;
        while !pending_ids.is_empty() {
            attempts += 1;
            if attempts >= STALE_ROUTE_RETRIES {
                return Err(Error::StaleRoute(format!(
                    "push not accepted after {STALE_ROUTE_RETRIES} routing retries"
                )));
            }
            // Throttled refresh: a 2 ms retry cadence would hammer the
            // publisher with 500 map fetches a second for a window the
            // epoch bump resolves anyway; every 50th retry (~100 ms) is
            // plenty for a remote trainer to catch the cutover.
            if attempts % 50 == 1 {
                if let Some(refresh) = &self.refresher {
                    refresh(&self.router);
                }
            }
            std::thread::sleep(STALE_ROUTE_BACKOFF);
            let again_ids = std::mem::take(&mut pending_ids);
            let again_grads = std::mem::take(&mut pending_grads);
            self.push_split(
                table,
                &again_ids,
                &again_grads,
                dim,
                &mut pending_ids,
                &mut pending_grads,
            )?;
        }
        Ok(())
    }

    /// Pull a dense table (dense state lives on shard 0 — the designated
    /// dense owner, avoiding divergent replicas).
    pub fn dense_pull(&self, table: &str) -> Result<Vec<f32>> {
        let req = DensePull { model: self.model.clone(), table: table.to_string() };
        let resp = self.shards[0].call(methods::DENSE_PULL, &req.to_bytes())?;
        Ok(DenseValues::from_bytes(&resp)?.values)
    }

    /// Push a dense gradient (shard 0).
    pub fn dense_push(&self, table: &str, grads: Vec<f32>) -> Result<()> {
        let req = DenseValues {
            model: self.model.clone(),
            table: table.to_string(),
            values: grads,
        };
        self.shards[0].call(methods::DENSE_PUSH, &req.to_bytes())?;
        Ok(())
    }
}

/// A slave replica endpoint: channel + (for in-process replicas) a direct
/// health view; remote replicas are probed via PING.
pub struct SlaveEndpoint {
    pub channel: Channel,
    local: Option<Arc<SlaveShard>>,
}

impl SlaveEndpoint {
    /// In-process endpoint (health read directly off the shard).
    pub fn local(channel: Channel, shard: Arc<SlaveShard>) -> SlaveEndpoint {
        SlaveEndpoint { channel, local: Some(shard) }
    }

    /// Remote endpoint (health via PING).
    pub fn remote(channel: Channel) -> SlaveEndpoint {
        SlaveEndpoint { channel, local: None }
    }
}

impl Endpoint for SlaveEndpoint {
    fn healthy(&self) -> bool {
        match &self.local {
            Some(shard) => shard.is_healthy(),
            None => self.channel.call(methods::PING, &[]).is_ok(),
        }
    }
}

/// Predictor-profile client over the slave cluster: one replica group per
/// slave shard, failover on every read, and (when attached) a hot-id
/// cache that short-circuits the RPC entirely for ids the streaming
/// scatter has not invalidated since they were fetched.
pub struct SlaveClient {
    model: String,
    router: Router,
    groups: Vec<Arc<ReplicaGroup<SlaveEndpoint>>>,
    /// Failover attempts per read.
    attempts: usize,
    /// Hot-id cache, coherent via the scatter tap (see [`HotIdCache`]).
    cache: Option<Arc<HotIdCache>>,
    /// Per-shard remote pull latency (cache misses only).
    fanout_hist: Option<Arc<crate::util::Histogram>>,
    /// Refreshes the router from the published slot map on stale-route
    /// NACKs (remote predictors; in-process clients share the cell).
    refresher: Option<RouteRefresher>,
    /// Stale-route NACKs absorbed by the pull retry loop.
    pub stale_retries: std::sync::atomic::AtomicU64,
}

impl SlaveClient {
    /// Client over `groups` (index = slave shard id) with a private
    /// uniform router over the default slot universe.
    pub fn new(model: &str, groups: Vec<Arc<ReplicaGroup<SlaveEndpoint>>>) -> SlaveClient {
        let router = Router::new(groups.len() as u32);
        Self::with_router(model, groups, router)
    }

    /// Client routing through an explicit [`Router`] — must share the
    /// slave cluster's slot universe (`reshard_slots`) or pulls route to
    /// shards that never held the ids.
    pub fn with_router(
        model: &str,
        groups: Vec<Arc<ReplicaGroup<SlaveEndpoint>>>,
        router: Router,
    ) -> SlaveClient {
        SlaveClient {
            model: model.to_string(),
            router,
            groups,
            attempts: 3,
            cache: None,
            fanout_hist: None,
            refresher: None,
            stale_retries: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Slave shard count.
    pub fn shard_count(&self) -> usize {
        self.groups.len()
    }

    /// Replica group for a shard (failure injection in tests/benches).
    pub fn group(&self, shard: usize) -> &Arc<ReplicaGroup<SlaveEndpoint>> {
        &self.groups[shard]
    }

    /// The client's routing view (shared cell when built `with_router`).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Attach a hot-id cache. The caller is responsible for also
    /// registering the same cache as a scatter tap
    /// ([`crate::sync::Scatter::add_tap`]) — an untapped cache would
    /// serve stale rows forever, which is worse than no cache.
    pub fn set_cache(&mut self, cache: Arc<HotIdCache>) {
        self.cache = Some(cache);
    }

    /// The attached cache, if any (stats access in benches/tests).
    pub fn cache(&self) -> Option<&Arc<HotIdCache>> {
        self.cache.as_ref()
    }

    /// Install the stale-route refresh hook (see [`RouteRefresher`]).
    pub fn set_route_refresher(&mut self, refresher: RouteRefresher) {
        self.refresher = Some(refresher);
    }

    /// Export read-path series (fan-out latency histogram + the attached
    /// cache's counters) under the given role label.
    pub fn register_metrics(&mut self, role: &str) {
        self.fanout_hist = Some(crate::metrics::histogram(
            "weips_pull_fanout_latency_seconds",
            &[("role", role.to_string())],
        ));
        if let Some(cache) = &self.cache {
            cache.register_metrics(role);
        }
    }

    /// Pull serving values for `ids` in request order. Cached ids are
    /// served locally; only misses fan out to the replica groups. A
    /// stale-route NACK (pull raced a serving-side cutover) refreshes
    /// the route (when a refresher is installed) and retries wholesale.
    pub fn sparse_pull(&self, table: &str, ids: &[u64]) -> Result<(u32, Vec<f32>)> {
        let mut attempts = 0;
        loop {
            let outcome = match &self.cache {
                Some(cache) => self.pull_through_cache(cache, table, ids),
                None => self.pull_remote(table, ids),
            };
            match outcome {
                Err(e) if e.is_stale_route() && attempts + 1 < STALE_PULL_RETRIES => {
                    attempts += 1;
                    self.stale_retries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if let Some(refresh) = &self.refresher {
                        refresh(&self.router);
                    }
                    std::thread::sleep(STALE_PULL_BACKOFF);
                }
                outcome => return outcome,
            }
        }
    }

    /// Cache-aware pull: probe everything first (against a pre-captured
    /// invalidation tick), then fetch only the misses remotely and fill
    /// them back. Output is byte-identical to the uncached path — the
    /// cache stores exactly the serving rows the slaves return.
    fn pull_through_cache(
        &self,
        cache: &Arc<HotIdCache>,
        table: &str,
        ids: &[u64],
    ) -> Result<(u32, Vec<f32>)> {
        let fill_tick = cache.fill_tick();
        let mut width = cache.width(table).unwrap_or(0) as usize;
        let mut out = vec![0.0f32; ids.len() * width];
        let mut missing: Vec<(usize, u64)> = Vec::new();
        if width == 0 {
            // Nothing ever cached for this table: everything misses.
            missing.extend(ids.iter().copied().enumerate());
            cache
                .stats
                .misses
                .fetch_add(ids.len() as u64, std::sync::atomic::Ordering::Relaxed);
        } else {
            for (pos, &id) in ids.iter().enumerate() {
                if !cache.copy_into(table, id, &mut out[pos * width..(pos + 1) * width]) {
                    missing.push((pos, id));
                }
            }
        }
        if missing.is_empty() {
            return Ok((width as u32, out));
        }
        let miss_ids: Vec<u64> = missing.iter().map(|&(_, id)| id).collect();
        let (remote_width, fetched) = self.pull_remote(table, &miss_ids)?;
        let rw = remote_width as usize;
        if width == 0 {
            width = rw;
            out = vec![0.0f32; ids.len() * width];
        } else if rw != width {
            return Err(Error::Rpc(format!(
                "serving width changed under the cache: cached {width} vs remote {rw}"
            )));
        }
        for (i, &(pos, id)) in missing.iter().enumerate() {
            let row = &fetched[i * width..(i + 1) * width];
            out[pos * width..(pos + 1) * width].copy_from_slice(row);
            cache.insert(table, id, row, fill_tick);
        }
        Ok((width as u32, out))
    }

    /// The replica fan-out proper: split by slot map, one timed
    /// failover call per touched shard.
    fn pull_remote(&self, table: &str, ids: &[u64]) -> Result<(u32, Vec<f32>)> {
        let buckets = self.router.split_ids(ids);
        let mut width = 0u32;
        let mut out: Vec<f32> = Vec::new();
        for (shard, (positions, shard_ids)) in buckets.iter().enumerate() {
            if shard_ids.is_empty() {
                continue;
            }
            if shard >= self.groups.len() {
                return Err(Error::Routing(format!(
                    "slot map routes to slave shard {shard} but client holds {} groups",
                    self.groups.len()
                )));
            }
            let req = SparsePull {
                model: self.model.clone(),
                table: table.to_string(),
                ids: shard_ids.clone(),
                slot: "w".to_string(),
            }
            .to_bytes();
            let start = std::time::Instant::now();
            let resp_bytes = self.groups[shard]
                .call_with_failover(self.attempts, |ep| ep.channel.call(methods::SPARSE_PULL, &req))?;
            if let Some(hist) = &self.fanout_hist {
                hist.record(start.elapsed().as_nanos() as u64);
            }
            let resp = SparseValues::from_bytes(&resp_bytes)?;
            if width == 0 {
                width = resp.width;
                out.resize(ids.len() * width as usize, 0.0);
            }
            let w = width as usize;
            for (i, &pos) in positions.iter().enumerate() {
                out[pos * w..(pos + 1) * w].copy_from_slice(&resp.values[i * w..(i + 1) * w]);
            }
        }
        Ok((width, out))
    }

    /// Pull a dense table from any shard-0 replica (cached wholesale —
    /// dense sync batches carry full snapshots, so invalidation is
    /// per-table, not per-id).
    pub fn dense_pull(&self, table: &str) -> Result<Vec<f32>> {
        let fill_tick = self.cache.as_ref().map(|c| c.fill_tick());
        if let Some(cache) = &self.cache {
            if let Some(hit) = cache.dense_get(table) {
                return Ok(hit.to_vec());
            }
        }
        let req = DensePull { model: self.model.clone(), table: table.to_string() }.to_bytes();
        let start = std::time::Instant::now();
        let resp = self.groups[0]
            .call_with_failover(self.attempts, |ep| ep.channel.call(methods::DENSE_PULL, &req))?;
        if let Some(hist) = &self.fanout_hist {
            hist.record(start.elapsed().as_nanos() as u64);
        }
        let values = DenseValues::from_bytes(&resp)?.values;
        if let (Some(cache), Some(tick)) = (&self.cache, fill_tick) {
            cache.dense_insert(table, values.clone(), tick);
        }
        Ok(values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelKind, ModelSpec};
    use crate::replica::BalancePolicy;
    use crate::runtime::ModelConfig;
    use crate::server::master::{MasterService, MasterShard};
    use crate::server::slave::SlaveService;
    use crate::sync::transform::ServingWeights;
    use crate::util::clock::ManualClock;

    fn model_cfg() -> ModelConfig {
        ModelConfig {
            batch_train: 8,
            batch_predict: 2,
            fields: 4,
            dim: 2,
            hidden: 8,
            ftrl_block_rows: 64,
            ftrl_alpha: 0.05,
            ftrl_beta: 1.0,
            ftrl_l1: 1.0,
            ftrl_l2: 1.0,
        }
    }

    fn master_cluster(n: u32) -> (ShardedClient, Vec<Arc<MasterShard>>) {
        let spec = ModelSpec::derive("ctr", ModelKind::Fm, &model_cfg());
        let clock = Arc::new(ManualClock::new(0));
        let masters: Vec<Arc<MasterShard>> = (0..n)
            .map(|i| Arc::new(MasterShard::new(i, spec.clone(), None, 1, clock.clone()).unwrap()))
            .collect();
        let channels: Vec<Channel> = masters
            .iter()
            .map(|m| Channel::local(Arc::new(MasterService { shard: m.clone(), store: None })))
            .collect();
        (ShardedClient::new("ctr", channels), masters)
    }

    #[test]
    fn sharded_push_pull_round_trip() {
        let (client, masters) = master_cluster(4);
        let ids: Vec<u64> = (0..100).collect();
        let grads = vec![2.0f32; 100];
        client.sparse_push("w", &ids, &grads).unwrap();
        // Rows spread across shards.
        let spread: Vec<usize> = masters.iter().map(|m| m.total_rows()).collect();
        assert_eq!(spread.iter().sum::<usize>(), 100);
        assert!(spread.iter().all(|&c| c > 5), "spread {spread:?}");
        // Pull z in request order.
        let (width, z) = client.sparse_pull("w", &ids, "z").unwrap();
        assert_eq!(width, 1);
        assert!(z.iter().all(|&v| (v - 2.0).abs() < 1e-6));
        // Multi-dim table.
        client.sparse_push("v", &ids, &vec![0.5f32; 200]).unwrap();
        let (vw, vv) = client.sparse_pull("v", &ids, "*").unwrap();
        assert_eq!(vw, 6); // 3 slots * dim 2
        assert_eq!(vv.len(), 600);
    }

    #[test]
    fn dense_ops_go_to_shard_zero() {
        let (client, masters) = master_cluster(3);
        client.dense_push("bias", vec![1.0]).unwrap();
        let v = client.dense_pull("bias").unwrap();
        assert!(v[0] < 0.0);
        // Only shard 0's dense table moved.
        let d1 = masters[1]
            .dense_pull(&DensePull { model: "ctr".into(), table: "bias".into() })
            .unwrap();
        assert_eq!(d1.values, vec![0.0]);
    }

    #[test]
    fn stale_route_push_retries_to_new_owner() {
        use crate::reshard::SlotSet;
        use crate::server::master::MasterShard;
        let spec = ModelSpec::derive("ctr", ModelKind::Fm, &model_cfg());
        let clock = Arc::new(ManualClock::new(0));
        let masters: Vec<Arc<MasterShard>> = (0..2)
            .map(|i| Arc::new(MasterShard::new(i, spec.clone(), None, 1, clock.clone()).unwrap()))
            .collect();
        let router = crate::sync::Router::with_slots(2, 16);
        for m in &masters {
            m.set_route_guard(router.clone());
        }
        let channels: Vec<Channel> = masters
            .iter()
            .map(|m| Channel::local(Arc::new(MasterService { shard: m.clone(), store: None })))
            .collect();
        let client = Arc::new(ShardedClient::with_router("ctr", channels, router.clone()));
        let map = router.snapshot();
        let id: u64 = (0..1000).find(|&i| map.shard_of(i) == 0).unwrap();
        let slot = map.slot_of(id);
        // Seal the slot (migration hand-off window): the push NACKs and
        // spins in the retry loop until the cutover re-routes it.
        masters[0].seal_slots(SlotSet::from_slots(&[slot], 16).unwrap()).unwrap();
        let pusher = {
            let client = client.clone();
            std::thread::spawn(move || client.sparse_push("w", &[id], &[2.0]).unwrap())
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        router.install(map.rebalanced(&[(slot, 1)]).unwrap()).unwrap();
        masters[0].unseal_slots();
        pusher.join().unwrap();
        assert!(
            client.stale_retries.load(std::sync::atomic::Ordering::Relaxed) > 0,
            "push never hit the sealed window"
        );
        // Applied exactly once, at the new owner.
        assert_eq!(masters[1].total_rows(), 1);
        assert_eq!(masters[0].total_rows(), 0);
        let (_, z) = client.sparse_pull("w", &[id], "z").unwrap();
        assert_eq!(z, vec![2.0]);
    }

    fn slave_cluster(shards: u32, replicas: u32) -> (SlaveClient, Vec<Vec<Arc<SlaveShard>>>) {
        let ftrl: Arc<dyn crate::optim::Optimizer> =
            Arc::new(crate::optim::Ftrl::new(crate::optim::FtrlHyper::default()));
        let mut groups = Vec::new();
        let mut all = Vec::new();
        for s in 0..shards {
            let mut eps = Vec::new();
            let mut reps = Vec::new();
            for r in 0..replicas {
                let shard = Arc::new(SlaveShard::new(
                    s,
                    r,
                    "ctr",
                    vec![("w".into(), 1)],
                    vec![("bias".into(), 1)],
                    Arc::new(ServingWeights::new(vec![("w".into(), ftrl.clone(), 1)])),
                    Router::new(shards),
                ));
                let ch = Channel::local(Arc::new(SlaveService { shard: shard.clone() }));
                eps.push(Arc::new(SlaveEndpoint::local(ch, shard.clone())));
                reps.push(shard);
            }
            groups.push(Arc::new(ReplicaGroup::new(eps, BalancePolicy::RoundRobin)));
            all.push(reps);
        }
        (SlaveClient::new("ctr", groups), all)
    }

    fn seed_slaves(slaves: &[Vec<Arc<SlaveShard>>], ids: &[u64]) {
        use crate::proto::{SyncBatch, SyncEntry, SyncOp};
        let router = Router::new(slaves.len() as u32);
        for &id in ids {
            let shard = router.shard_of(id) as usize;
            let batch = SyncBatch {
                model: "ctr".into(),
                table: "w".into(),
                shard: 0,
                seq: 0,
                created_ms: 0,
                entries: vec![SyncEntry { id, op: SyncOp::Upsert(vec![2.0, 1.0, id as f32]) }],
                dense: vec![],
            };
            for replica in &slaves[shard] {
                replica.apply_batch(&batch).unwrap();
            }
        }
    }

    #[test]
    fn slave_pull_in_request_order() {
        let (client, slaves) = slave_cluster(2, 2);
        let ids: Vec<u64> = (10..30).collect();
        seed_slaves(&slaves, &ids);
        let (w, vals) = client.sparse_pull("w", &ids).unwrap();
        assert_eq!(w, 1);
        for (i, &id) in ids.iter().enumerate() {
            assert_eq!(vals[i], id as f32, "id {id}");
        }
    }

    #[test]
    fn cached_pull_identical_and_invalidated_by_tap() {
        use crate::proto::{SyncBatch, SyncEntry, SyncOp};
        use crate::sync::ScatterTap;
        use std::sync::atomic::Ordering;
        let (mut client, slaves) = slave_cluster(2, 2);
        let ids: Vec<u64> = (10..30).collect();
        seed_slaves(&slaves, &ids);
        let (uw, uncached) = client.sparse_pull("w", &ids).unwrap();

        let cache = HotIdCache::new(1 << 16);
        client.set_cache(cache.clone());
        let (w1, first) = client.sparse_pull("w", &ids).unwrap(); // fill
        let (w2, second) = client.sparse_pull("w", &ids).unwrap(); // all hits
        assert_eq!((uw, &uncached), (w1, &first), "cache must be byte-identical");
        assert_eq!(first, second);
        assert!(cache.stats.hits.load(Ordering::Relaxed) >= ids.len() as u64);

        // A streamed update applies to the serving tables, then hits the
        // tap (same order as Scatter::poll): the next pull re-fetches.
        let hot = ids[0];
        let shard = Router::new(2).shard_of(hot) as usize;
        let batch = SyncBatch {
            model: "ctr".into(),
            table: "w".into(),
            shard: 0,
            seq: 1,
            created_ms: 0,
            entries: vec![SyncEntry { id: hot, op: SyncOp::Upsert(vec![2.0, 1.0, 777.0]) }],
            dense: vec![],
        };
        for replica in &slaves[shard] {
            replica.apply_batch(&batch).unwrap();
        }
        cache.on_applied(std::slice::from_ref(&batch));
        let (_, third) = client.sparse_pull("w", &ids).unwrap();
        assert_eq!(third[0], 777.0, "update must be visible within one tick");
        assert_eq!(&third[1..], &second[1..], "untouched ids still served");
    }

    #[test]
    fn slave_failover_on_replica_death() {
        let (client, slaves) = slave_cluster(1, 3);
        let ids = vec![5u64, 6, 7];
        seed_slaves(&slaves, &ids);
        // Kill two replicas.
        slaves[0][0].set_healthy(false);
        slaves[0][1].set_healthy(false);
        let (_, vals) = client.sparse_pull("w", &ids).unwrap();
        assert_eq!(vals, vec![5.0, 6.0, 7.0]);
        // All dead -> unavailable.
        slaves[0][2].set_healthy(false);
        assert!(client.sparse_pull("w", &ids).is_err());
    }
}
