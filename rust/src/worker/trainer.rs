//! Trainer worker (§3.1): pull → AOT train graph → push.
//!
//! Per batch: pull the sparse rows for the batch's ids from the master
//! cluster, pull the dense tower tables, execute the AOT-compiled
//! `*_train` module (forward + loss + grads + *pre-update* predictions),
//! feed the predictions to the progressive-validation monitor (§4.3.1),
//! then push the sparse/dense gradients back. Python never runs here —
//! the graph is a compiled PJRT executable.

use std::sync::Arc;

use crate::config::{ModelKind, ModelSpec};
use crate::monitor::Monitor;
use crate::runtime::{Engine, Tensor};
use crate::sample::Sample;
use crate::worker::client::ShardedClient;
use crate::{Error, Result};

/// Result of one training step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub loss: f32,
    /// Pre-update predictions (progressive validation signal).
    pub preds: Vec<f32>,
}

/// The trainer worker.
pub struct Trainer {
    engine: Arc<Engine>,
    spec: ModelSpec,
    client: ShardedClient,
    monitor: Arc<Monitor>,
}

impl Trainer {
    /// New trainer.
    pub fn new(
        engine: Arc<Engine>,
        spec: ModelSpec,
        client: ShardedClient,
        monitor: Arc<Monitor>,
    ) -> Trainer {
        Trainer { engine, spec, client, monitor }
    }

    /// The model spec in use.
    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// Flatten the batch's ids (row-major `B × F`).
    fn flat_ids(&self, samples: &[Sample]) -> Result<Vec<u64>> {
        let f = self.spec.fields;
        let mut ids = Vec::with_capacity(samples.len() * f);
        for s in samples {
            if s.ids.len() != f {
                return Err(Error::State(format!(
                    "sample has {} fields, model wants {f}",
                    s.ids.len()
                )));
            }
            ids.extend_from_slice(&s.ids);
        }
        Ok(ids)
    }

    /// Run one training step on exactly `batch_train` samples.
    pub fn train_batch(&self, samples: &[Sample]) -> Result<StepOutput> {
        let b = self.spec.batch_train;
        if samples.len() != b {
            return Err(Error::State(format!(
                "train_batch needs exactly {b} samples, got {}",
                samples.len()
            )));
        }
        let f = self.spec.fields;
        let k = self.spec.dim;
        let ids = self.flat_ids(samples)?;
        let labels: Vec<f32> = samples.iter().map(|s| s.label).collect();

        // -- pull phase -----------------------------------------------------
        let (_, w_vals) = self.client.sparse_pull("w", &ids, "w")?;
        let w = Tensor::new(vec![b, f], w_vals);
        let label_t = Tensor::vec1(labels.clone());
        let dense_tensors: Vec<Tensor> = self
            .spec
            .dense
            .iter()
            .map(|d| {
                let values = self.client.dense_pull(&d.name)?;
                Ok(self.dense_to_tensor(&d.name, values))
            })
            .collect::<Result<Vec<_>>>()?;

        let outputs = match self.spec.kind {
            ModelKind::Lr => {
                // [w, bias, label] -> [pred, loss, grad_w, grad_bias]
                let mut inputs = vec![w];
                inputs.extend(dense_tensors);
                inputs.push(label_t);
                self.engine.execute("lr_train", &inputs)?
            }
            ModelKind::Fm => {
                let (_, v_vals) = self.client.sparse_pull("v", &ids, "w")?;
                let v = Tensor::new(vec![b, f, k], v_vals);
                let mut inputs = vec![w, v];
                inputs.extend(dense_tensors);
                inputs.push(label_t);
                self.engine.execute("fm_train", &inputs)?
            }
            ModelKind::DeepFm => {
                let (_, v_vals) = self.client.sparse_pull("v", &ids, "w")?;
                let v = Tensor::new(vec![b, f, k], v_vals);
                let mut inputs = vec![w, v];
                inputs.extend(dense_tensors);
                inputs.push(label_t);
                self.engine.execute("deepfm_train", &inputs)?
            }
        };

        // -- monitor (pre-update predictions) --------------------------------
        let preds = outputs[0].data.clone();
        let loss = outputs[1].item();
        self.monitor.observe_batch(&preds, &labels);

        // -- push phase -------------------------------------------------------
        // Output layout: [pred, loss, grad_sparse..., grad_dense...] in the
        // same order the graph takes its inputs.
        let mut out_idx = 2;
        self.client.sparse_push("w", &ids, &outputs[out_idx].data)?;
        out_idx += 1;
        if matches!(self.spec.kind, ModelKind::Fm | ModelKind::DeepFm) {
            self.client.sparse_push("v", &ids, &outputs[out_idx].data)?;
            out_idx += 1;
        }
        for d in &self.spec.dense {
            self.client.dense_push(&d.name, outputs[out_idx].data.clone())?;
            out_idx += 1;
        }
        debug_assert_eq!(out_idx, outputs.len());

        Ok(StepOutput { loss, preds })
    }

    fn dense_to_tensor(&self, name: &str, values: Vec<f32>) -> Tensor {
        // Tower matrices need their 2-D shapes back; vectors stay rank-1.
        let (f, k, h) = (self.spec.fields, self.spec.dim, self.spec.hidden);
        match name {
            "w1" => Tensor::new(vec![f * k, h], values),
            "w2" => Tensor::new(vec![h, 1], values),
            _ => Tensor::vec1(values),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;
    use crate::net::Channel;
    use crate::runtime::default_artifacts_dir;
    use crate::sample::{Workload, WorkloadConfig};
    use crate::server::master::{MasterService, MasterShard};
    use crate::util::clock::SystemClock;

    fn build(kind: ModelKind) -> Option<(Trainer, Vec<Arc<MasterShard>>, Workload)> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping trainer test: run `make artifacts`");
            return None;
        }
        let engine = Arc::new(Engine::load(dir).unwrap());
        let spec = ModelSpec::derive("ctr", kind, engine.config());
        let clock = Arc::new(SystemClock);
        let masters: Vec<Arc<MasterShard>> = (0..2)
            .map(|i| {
                Arc::new(
                    MasterShard::new(i, spec.clone(), Some(engine.clone()), 1, clock.clone())
                        .unwrap(),
                )
            })
            .collect();
        let channels: Vec<Channel> = masters
            .iter()
            .map(|m| Channel::local(Arc::new(MasterService { shard: m.clone(), store: None })))
            .collect();
        let client = ShardedClient::new("ctr", channels);
        let monitor = Arc::new(Monitor::new(1_000));
        let workload = Workload::new(WorkloadConfig {
            fields: spec.fields,
            ids_per_field: 1_000,
            seed: 7,
            ..Default::default()
        });
        Some((Trainer::new(engine, spec, client, monitor), masters, workload))
    }

    #[test]
    fn lr_training_reduces_loss() {
        let Some((trainer, masters, mut workload)) = build(ModelKind::Lr) else { return };
        let b = trainer.spec().batch_train;
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..30 {
            let samples = workload.batch(step * 1_000, b);
            let out = trainer.train_batch(&samples).unwrap();
            assert!(out.loss.is_finite());
            if step == 0 {
                first = out.loss;
            }
            last = out.loss;
        }
        assert!(last < first, "loss {first} -> {last}");
        assert!(masters.iter().map(|m| m.total_rows()).sum::<usize>() > 0);
    }

    #[test]
    fn fm_training_runs_and_monitors() {
        let Some((trainer, _masters, mut workload)) = build(ModelKind::Fm) else { return };
        let b = trainer.spec().batch_train;
        for step in 0..10 {
            let samples = workload.batch(step * 1_000, b);
            let out = trainer.train_batch(&samples).unwrap();
            assert_eq!(out.preds.len(), b);
            assert!(out.preds.iter().all(|p| (0.0..=1.0).contains(p)));
        }
    }

    #[test]
    fn wrong_batch_size_rejected() {
        let Some((trainer, _, mut workload)) = build(ModelKind::Lr) else { return };
        let samples = workload.batch(0, 3);
        assert!(trainer.train_batch(&samples).is_err());
    }
}
