//! Worker role (§3.1): trainer and predictor, plus the WeiPS-client.

pub mod cache;
pub mod client;
pub mod predictor;
pub mod trainer;

pub use cache::HotIdCache;
pub use client::{ShardedClient, SlaveClient, SlaveEndpoint};
pub use predictor::Predictor;
pub use trainer::Trainer;
