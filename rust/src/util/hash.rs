//! Fast non-cryptographic hashing (fxhash-style) and a `HashMap` wrapper.
//!
//! Parameter ids are already well-distributed 64-bit feature hashes, so the
//! shard router and the sparse tables want the cheapest possible mixer, not
//! SipHash. `fxhash64` is the rustc FxHasher multiply-xor scheme extended to
//! one-shot u64 keys; `FxHashMap` plugs it into `std::collections::HashMap`.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// One-shot mix of a 64-bit key (used by the shard router).
#[inline]
pub fn fxhash64(mut x: u64) -> u64 {
    x = x.wrapping_mul(SEED);
    x ^= x >> 32;
    x = x.wrapping_mul(SEED);
    x ^= x >> 32;
    x
}

/// Streaming FxHasher compatible with `std` hashing traits.
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        // Final avalanche so sequential keys spread across buckets.
        let mut h = self.hash;
        h ^= h >> 32;
        h = h.wrapping_mul(SEED);
        h ^= h >> 32;
        h
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

/// HashMap keyed with the fast hasher.
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// HashSet keyed with the fast hasher.
pub type FxHashSet<K> = HashSet<K, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_shot_is_deterministic_and_mixing() {
        assert_eq!(fxhash64(1), fxhash64(1));
        assert_ne!(fxhash64(1), fxhash64(2));
        // Low bits of sequential keys should differ (shard routing quality).
        let mask = 0xFF;
        let mut seen = std::collections::HashSet::new();
        for k in 0..64u64 {
            seen.insert(fxhash64(k) & mask);
        }
        assert!(seen.len() > 40, "only {} distinct low bytes", seen.len());
    }

    #[test]
    fn map_works_with_fx_hasher() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(i, (i * 2) as u32);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&7], 14);
    }

    #[test]
    fn streaming_hash_distinguishes_lengths() {
        use std::hash::Hash;
        fn h<T: Hash>(v: T) -> u64 {
            let mut hasher = FxHasher::default();
            v.hash(&mut hasher);
            hasher.finish()
        }
        assert_ne!(h(b"abc".as_slice()), h(b"abcd".as_slice()));
        assert_ne!(h((1u64, 2u64)), h((2u64, 1u64)));
    }

    #[test]
    fn shard_distribution_is_balanced() {
        // Routing quality: hashing 100k sequential ids into 16 shards should
        // land within ±15% of uniform.
        let shards = 16u64;
        let mut counts = vec![0usize; shards as usize];
        let n = 100_000u64;
        for id in 0..n {
            counts[(fxhash64(id) % shards) as usize] += 1;
        }
        let expect = (n / shards) as f64;
        for c in counts {
            assert!((c as f64 - expect).abs() / expect < 0.15, "count {c} vs {expect}");
        }
    }
}
