//! Fixed-size thread pool (no tokio in the offline build environment).
//!
//! Used by the RPC server (per-connection handlers), the checkpoint writer
//! (asynchronous saving, paper §4.2.1a) and the scatter appliers. Tasks are
//! boxed closures; `join` blocks until all submitted work has drained.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    pending: AtomicUsize,
    done_cv: Condvar,
    done_mu: Mutex<()>,
}

/// Fixed-size pool of worker threads consuming a shared task channel.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Task>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl ThreadPool {
    /// Spawn a pool with `size` worker threads (min 1).
    pub fn new(size: usize, name: &str) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            pending: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mu: Mutex::new(()),
        });
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = rx.clone();
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    let task = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match task {
                        Ok(task) => {
                            task();
                            if shared.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                                let _g = shared.done_mu.lock().unwrap();
                                shared.done_cv.notify_all();
                            }
                        }
                        Err(_) => break, // channel closed => shutdown
                    }
                })
                .expect("spawn worker");
            workers.push(handle);
        }
        ThreadPool { tx: Some(tx), workers, shared }
    }

    /// Submit a task for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.pending.fetch_add(1, Ordering::AcqRel);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Number of submitted-but-unfinished tasks.
    pub fn pending(&self) -> usize {
        self.shared.pending.load(Ordering::Acquire)
    }

    /// Block until every submitted task has completed.
    pub fn join(&self) {
        let mut guard = self.shared.done_mu.lock().unwrap();
        while self.shared.pending.load(Ordering::Acquire) > 0 {
            let (g, _timeout) = self
                .shared
                .done_cv
                .wait_timeout(guard, std::time::Duration::from_millis(50))
                .unwrap();
            guard = g;
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.join();
        drop(self.tx.take()); // close channel => workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_tasks() {
        let pool = ThreadPool::new(4, "test");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn join_waits_for_slow_tasks() {
        let pool = ThreadPool::new(2, "slow");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            let c = counter.clone();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn drop_drains_outstanding_work() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(1, "drop");
            for _ in 0..10 {
                let c = counter.clone();
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop joins
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn size_zero_clamped_to_one() {
        let pool = ThreadPool::new(0, "min");
        let c = Arc::new(AtomicU64::new(0));
        let c2 = c.clone();
        pool.execute(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.join();
        assert_eq!(c.load(Ordering::SeqCst), 1);
    }
}
