//! Fixed-size thread pool (no tokio in the offline build environment).
//!
//! Used by the RPC server (pooled connection handlers), the checkpoint
//! writer (asynchronous saving, paper §4.2.1a) and the parallel sync
//! pipeline (gather snapshots, scatter applies, expire passes). Tasks are
//! boxed closures; `join` blocks until all submitted work has drained.
//!
//! Panic safety: a panicking task decrements `pending` through a drop
//! guard (so `join` never hangs on a poisoned count) and the worker thread
//! survives via `catch_unwind`, so the pool keeps its full parallelism for
//! the tasks that follow.
//!
//! [`ThreadPool::run_borrowed`] is the scoped entry point the sync
//! pipeline uses: it submits closures that borrow from the caller's stack
//! (per-stripe table references, result slots) and blocks until every one
//! of them has finished before returning, which is what makes the borrow
//! sound. Never call it from inside a task running on the same pool — the
//! caller would occupy a worker while waiting for workers.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    pending: AtomicUsize,
    done_cv: Condvar,
    done_mu: Mutex<()>,
}

/// Decrements `pending` and notifies `join`ers on drop — runs on normal
/// completion *and* during unwind, so a panicking task can never strand
/// the count.
struct PendingGuard<'a>(&'a Shared);

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        if self.0.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = self.0.done_mu.lock().unwrap();
            self.0.done_cv.notify_all();
        }
    }
}

/// Completion latch for one [`ThreadPool::run_borrowed`] call: counts the
/// batch's own tasks (not the whole pool), records whether any panicked.
struct Latch {
    remaining: AtomicUsize,
    cv: Condvar,
    mu: Mutex<()>,
    panicked: AtomicBool,
}

struct LatchGuard(Arc<Latch>);

impl Drop for LatchGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.panicked.store(true, Ordering::Release);
        }
        if self.0.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = self.0.mu.lock().unwrap();
            self.0.cv.notify_all();
        }
    }
}

/// Fixed-size pool of worker threads consuming a shared task channel.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Task>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    size: usize,
}

impl ThreadPool {
    /// Spawn a pool with `size` worker threads (min 1).
    pub fn new(size: usize, name: &str) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            pending: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mu: Mutex::new(()),
        });
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = rx.clone();
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    let task = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match task {
                        Ok(task) => {
                            let guard = PendingGuard(&shared);
                            // The worker must outlive a panicking task;
                            // the pending count is kept honest by the
                            // guard's drop either way.
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(task),
                            );
                            drop(guard);
                        }
                        Err(_) => break, // channel closed => shutdown
                    }
                })
                .expect("spawn worker");
            workers.push(handle);
        }
        ThreadPool { tx: Some(tx), workers, shared, size }
    }

    /// Worker thread count.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a task for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.pending.fetch_add(1, Ordering::AcqRel);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Run a batch of closures that may borrow from the caller's stack,
    /// blocking until every one has completed. This is the parallel-sync
    /// primitive: per-stripe snapshot/apply tasks borrow the table and
    /// their result slots, and the wait-before-return is what makes those
    /// borrows sound. Panics inside a task are re-raised here after the
    /// whole batch has drained. Must not be called from a task running on
    /// this same pool (a waiting worker cannot also execute).
    pub fn run_borrowed<'a>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        if tasks.is_empty() {
            return;
        }
        let latch = Arc::new(Latch {
            remaining: AtomicUsize::new(tasks.len()),
            cv: Condvar::new(),
            mu: Mutex::new(()),
            panicked: AtomicBool::new(false),
        });
        for task in tasks {
            // SAFETY: the latch wait below blocks until this closure has
            // run to completion (or unwound — the LatchGuard drops either
            // way), so every borrow in `task` strictly outlives its use.
            let task = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'a>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(task)
            };
            let latch = latch.clone();
            self.execute(move || {
                let _guard = LatchGuard(latch);
                task();
            });
        }
        let mut guard = latch.mu.lock().unwrap();
        while latch.remaining.load(Ordering::Acquire) > 0 {
            let (g, _timeout) = latch
                .cv
                .wait_timeout(guard, std::time::Duration::from_millis(50))
                .unwrap();
            guard = g;
        }
        drop(guard);
        if latch.panicked.load(Ordering::Acquire) {
            panic!("ThreadPool::run_borrowed: a task panicked");
        }
    }

    /// Number of submitted-but-unfinished tasks.
    pub fn pending(&self) -> usize {
        self.shared.pending.load(Ordering::Acquire)
    }

    /// Block until every submitted task has completed.
    pub fn join(&self) {
        let mut guard = self.shared.done_mu.lock().unwrap();
        while self.shared.pending.load(Ordering::Acquire) > 0 {
            let (g, _timeout) = self
                .shared
                .done_cv
                .wait_timeout(guard, std::time::Duration::from_millis(50))
                .unwrap();
            guard = g;
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.join();
        drop(self.tx.take()); // close channel => workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_tasks() {
        let pool = ThreadPool::new(4, "test");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn join_waits_for_slow_tasks() {
        let pool = ThreadPool::new(2, "slow");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            let c = counter.clone();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn drop_drains_outstanding_work() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(1, "drop");
            for _ in 0..10 {
                let c = counter.clone();
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop joins
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn size_zero_clamped_to_one() {
        let pool = ThreadPool::new(0, "min");
        let c = Arc::new(AtomicU64::new(0));
        let c2 = c.clone();
        pool.execute(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.join();
        assert_eq!(c.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn panicking_task_does_not_hang_join_or_kill_worker() {
        // Regression: a panicking task used to leave `pending` stuck (join
        // spun forever) and killed its worker thread. Now the guard keeps
        // the count honest and catch_unwind keeps the worker alive.
        let pool = ThreadPool::new(1, "panic");
        pool.execute(|| panic!("boom"));
        pool.join(); // must return
        assert_eq!(pool.pending(), 0);
        // The single worker survived and still executes new work.
        let c = Arc::new(AtomicU64::new(0));
        let c2 = c.clone();
        pool.execute(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.join();
        assert_eq!(c.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn run_borrowed_sees_stack_data_and_blocks_until_done() {
        let pool = ThreadPool::new(4, "scope");
        let data: Vec<u64> = (0..64).collect();
        let mut sums = vec![0u64; 8];
        {
            let chunks: Vec<(&[u64], &mut u64)> =
                data.chunks(8).zip(sums.iter_mut()).collect();
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
                .into_iter()
                .map(|(chunk, slot)| {
                    Box::new(move || {
                        *slot = chunk.iter().sum();
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_borrowed(tasks);
        }
        assert_eq!(sums.iter().sum::<u64>(), (0..64).sum());
    }

    #[test]
    fn run_borrowed_propagates_task_panic() {
        let pool = ThreadPool::new(2, "scope-panic");
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("scoped boom")),
            ];
            pool.run_borrowed(tasks);
        }));
        assert!(result.is_err());
        // Pool remains serviceable.
        let c = Arc::new(AtomicU64::new(0));
        let c2 = c.clone();
        pool.execute(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.join();
        assert_eq!(c.load(Ordering::SeqCst), 1);
    }
}
