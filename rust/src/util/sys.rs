//! Raw Linux syscall bindings for the event-driven RPC substrate.
//!
//! The offline build has no `libc` crate, so the handful of kernel
//! interfaces the net layer needs — epoll readiness notification, an
//! eventfd waker, and the per-process CPU clock the idle-fleet bench
//! reads — are invoked directly through the architecture's syscall
//! instruction (`syscall` on x86_64, `svc 0` on aarch64). Everything is
//! wrapped in safe types ([`Epoll`], [`EventFd`]); on platforms without
//! these bindings the constructors return an error and callers fall back
//! to the portable peek-sweep poll loop (`net::PollMode::Peek`), which is
//! exactly what [`supported`] reports.
//!
//! Only the syscalls the repo actually uses are bound. Numbers come from
//! the kernel's `unistd` tables for each architecture and are stable ABI.

#![allow(clippy::missing_safety_doc)]

use std::io;

/// True when the epoll/eventfd bindings are functional on this target —
/// the `net` layer's `PollMode::Auto` resolves on this.
pub fn supported() -> bool {
    cfg!(all(
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))
}

/// One epoll readiness record. Layout matches the kernel ABI
/// (`struct epoll_event`), which is packed on x86_64 only.
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy, Default)]
pub struct EpollEvent {
    events: u32,
    data: u64,
}

impl EpollEvent {
    /// The token registered with [`Epoll::add`] for the ready fd.
    /// (Copies the field out — the struct may be packed.)
    pub fn token(&self) -> u64 {
        self.data
    }

    /// Raw readiness flags (EPOLLIN/EPOLLHUP/...).
    pub fn flags(&self) -> u32 {
        self.events
    }
}

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLRDHUP: u32 = 0x2000;

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    use super::EpollEvent;
    use std::io;

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const READ: usize = 0;
        pub const WRITE: usize = 1;
        pub const CLOSE: usize = 3;
        pub const CLOCK_GETTIME: usize = 228;
        pub const EPOLL_CTL: usize = 233;
        pub const EPOLL_PWAIT: usize = 281;
        pub const EVENTFD2: usize = 290;
        pub const EPOLL_CREATE1: usize = 291;
    }

    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const READ: usize = 63;
        pub const WRITE: usize = 64;
        pub const CLOSE: usize = 57;
        pub const CLOCK_GETTIME: usize = 113;
        pub const EPOLL_CTL: usize = 21;
        pub const EPOLL_PWAIT: usize = 22;
        pub const EVENTFD2: usize = 19;
        pub const EPOLL_CREATE1: usize = 20;
    }

    /// Raw 6-argument syscall; returns the kernel's raw result
    /// (negative = -errno).
    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") n as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "svc 0",
            inlateout("x0") a1 as isize => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            in("x8") n,
            options(nostack),
        );
        ret
    }

    fn check(ret: isize) -> io::Result<usize> {
        if ret < 0 {
            Err(io::Error::from_raw_os_error(-ret as i32))
        } else {
            Ok(ret as usize)
        }
    }

    pub fn epoll_create1() -> io::Result<i32> {
        const EPOLL_CLOEXEC: usize = 0o2000000;
        check(unsafe { syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0) })
            .map(|fd| fd as i32)
    }

    pub fn epoll_ctl(epfd: i32, op: usize, fd: i32, event: Option<&EpollEvent>) -> io::Result<()> {
        let ptr = event.map_or(0usize, |e| e as *const EpollEvent as usize);
        check(unsafe { syscall6(nr::EPOLL_CTL, epfd as usize, op, fd as usize, ptr, 0, 0) })
            .map(|_| ())
    }

    pub fn epoll_wait(epfd: i32, out: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        check(unsafe {
            syscall6(
                nr::EPOLL_PWAIT,
                epfd as usize,
                out.as_mut_ptr() as usize,
                out.len(),
                timeout_ms as usize,
                0, // sigmask = NULL: don't alter the signal mask
                0,
            )
        })
    }

    pub fn eventfd() -> io::Result<i32> {
        const EFD_CLOEXEC: usize = 0o2000000;
        const EFD_NONBLOCK: usize = 0o4000;
        check(unsafe { syscall6(nr::EVENTFD2, 0, EFD_CLOEXEC | EFD_NONBLOCK, 0, 0, 0, 0) })
            .map(|fd| fd as i32)
    }

    pub fn read(fd: i32, buf: &mut [u8]) -> io::Result<usize> {
        check(unsafe {
            syscall6(nr::READ, fd as usize, buf.as_mut_ptr() as usize, buf.len(), 0, 0, 0)
        })
    }

    pub fn write(fd: i32, buf: &[u8]) -> io::Result<usize> {
        check(unsafe {
            syscall6(nr::WRITE, fd as usize, buf.as_ptr() as usize, buf.len(), 0, 0, 0)
        })
    }

    pub fn close(fd: i32) {
        let _ = unsafe { syscall6(nr::CLOSE, fd as usize, 0, 0, 0, 0, 0) };
    }

    /// CLOCK_PROCESS_CPUTIME_ID in nanoseconds (the idle-fleet CPU bench).
    pub fn process_cpu_ns() -> Option<u64> {
        const CLOCK_PROCESS_CPUTIME_ID: usize = 2;
        #[repr(C)]
        struct Timespec {
            sec: i64,
            nsec: i64,
        }
        let mut ts = Timespec { sec: 0, nsec: 0 };
        let ret = unsafe {
            syscall6(
                nr::CLOCK_GETTIME,
                CLOCK_PROCESS_CPUTIME_ID,
                &mut ts as *mut Timespec as usize,
                0,
                0,
                0,
                0,
            )
        };
        if ret < 0 {
            return None;
        }
        Some(ts.sec as u64 * 1_000_000_000 + ts.nsec as u64)
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod imp {
    //! Stub bindings: constructors fail, `PollMode::Auto` resolves to the
    //! portable peek sweep, and nothing here is ever invoked at runtime.
    use super::EpollEvent;
    use std::io;

    fn unsupported<T>() -> io::Result<T> {
        Err(io::Error::new(io::ErrorKind::Unsupported, "no syscall bindings on this target"))
    }

    pub fn epoll_create1() -> io::Result<i32> {
        unsupported()
    }

    pub fn epoll_ctl(
        _epfd: i32,
        _op: usize,
        _fd: i32,
        _event: Option<&EpollEvent>,
    ) -> io::Result<()> {
        unsupported()
    }

    pub fn epoll_wait(_epfd: i32, _out: &mut [EpollEvent], _timeout_ms: i32) -> io::Result<usize> {
        unsupported()
    }

    pub fn eventfd() -> io::Result<i32> {
        unsupported()
    }

    pub fn read(_fd: i32, _buf: &mut [u8]) -> io::Result<usize> {
        unsupported()
    }

    pub fn write(_fd: i32, _buf: &[u8]) -> io::Result<usize> {
        unsupported()
    }

    pub fn close(_fd: i32) {}

    pub fn process_cpu_ns() -> Option<u64> {
        None
    }
}

const EPOLL_CTL_ADD: usize = 1;
const EPOLL_CTL_DEL: usize = 2;

/// Kernel readiness-notification set: register fds with tokens, sleep
/// until one is ready. Wakeups are O(ready), idle waits cost zero CPU —
/// the property the parked-connection poll loop needs at fleet scale.
pub struct Epoll {
    fd: i32,
}

impl Epoll {
    /// New epoll instance (fails on unsupported targets — callers fall
    /// back to the peek sweep).
    pub fn new() -> io::Result<Epoll> {
        imp::epoll_create1().map(|fd| Epoll { fd })
    }

    /// Watch `fd` for input readiness / peer hangup, tagged with `token`
    /// (level-triggered: already-buffered bytes report on the next wait).
    pub fn add(&self, fd: i32, token: u64) -> io::Result<()> {
        let ev = EpollEvent { events: EPOLLIN | EPOLLRDHUP, data: token };
        imp::epoll_ctl(self.fd, EPOLL_CTL_ADD, fd, Some(&ev))
    }

    /// Stop watching `fd`.
    pub fn delete(&self, fd: i32) -> io::Result<()> {
        imp::epoll_ctl(self.fd, EPOLL_CTL_DEL, fd, None)
    }

    /// Block up to `timeout_ms` (-1 = forever) for readiness; fills `out`
    /// and returns how many records are valid.
    pub fn wait(&self, out: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        imp::epoll_wait(self.fd, out, timeout_ms)
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        imp::close(self.fd);
    }
}

/// Cross-thread waker for an [`Epoll`] sleeper (nonblocking eventfd):
/// `signal` from any thread makes the fd readable, `drain` resets it.
pub struct EventFd {
    fd: i32,
}

impl EventFd {
    /// New waker (fails on unsupported targets).
    pub fn new() -> io::Result<EventFd> {
        imp::eventfd().map(|fd| EventFd { fd })
    }

    /// Raw fd for epoll registration.
    pub fn raw_fd(&self) -> i32 {
        self.fd
    }

    /// Make the fd readable (wake the sleeper). Infallible by design: a
    /// full counter still leaves the fd readable.
    pub fn signal(&self) {
        let one = 1u64.to_ne_bytes();
        let _ = imp::write(self.fd, &one);
    }

    /// Consume pending signals so the next wait sleeps again.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        let _ = imp::read(self.fd, &mut buf);
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        imp::close(self.fd);
    }
}

/// CPU time this process has consumed, in nanoseconds (`None` where the
/// binding is unavailable). The idle-fleet bench compares this across
/// poll modes: a parked fleet under epoll must burn ~no CPU.
pub fn process_cpu_ns() -> Option<u64> {
    imp::process_cpu_ns()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoll_reports_readable_pipe_like_socket() {
        if !supported() {
            return;
        }
        use std::io::Write;
        use std::os::unix::io::AsRawFd;
        // A connected TCP pair is the closest std-only fd pair.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut tx = std::net::TcpStream::connect(addr).unwrap();
        let (rx, _) = listener.accept().unwrap();

        let ep = Epoll::new().unwrap();
        ep.add(rx.as_raw_fd(), 42).unwrap();
        let mut events = [EpollEvent::default(); 8];
        // Nothing pending: times out with zero events.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        tx.write_all(b"x").unwrap();
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].token(), 42);
        assert!(events[0].flags() & EPOLLIN != 0);
        ep.delete(rx.as_raw_fd()).unwrap();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn eventfd_wakes_epoll_and_drains() {
        if !supported() {
            return;
        }
        let ep = Epoll::new().unwrap();
        let wake = EventFd::new().unwrap();
        ep.add(wake.raw_fd(), 7).unwrap();
        let mut events = [EpollEvent::default(); 4];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        wake.signal();
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
        assert_eq!(events[0].token(), 7);
        wake.drain();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        // Wake from another thread unblocks a sleeping wait.
        let ep = std::sync::Arc::new(ep);
        let wake = std::sync::Arc::new(wake);
        let w2 = wake.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            w2.signal();
        });
        let n = ep.wait(&mut events, 5_000).unwrap();
        assert_eq!(n, 1);
        t.join().unwrap();
    }

    #[test]
    fn process_cpu_clock_advances() {
        if !supported() {
            return;
        }
        let a = process_cpu_ns().unwrap();
        // Burn a little CPU.
        let mut x = 0u64;
        for i in 0..2_000_000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(x);
        let b = process_cpu_ns().unwrap();
        assert!(b >= a);
        assert!(b > 0);
    }
}
