//! Raw Linux syscall bindings for the event-driven RPC substrate.
//!
//! The offline build has no `libc` crate, so the handful of kernel
//! interfaces the net layer needs — epoll readiness notification, an
//! eventfd waker, and the per-process CPU clock the idle-fleet bench
//! reads — are invoked directly through the architecture's syscall
//! instruction (`syscall` on x86_64, `svc 0` on aarch64). Everything is
//! wrapped in safe types ([`Epoll`], [`EventFd`]); on platforms without
//! these bindings the constructors return an error and callers fall back
//! to the portable peek-sweep poll loop (`net::PollMode::Peek`), which is
//! exactly what [`supported`] reports.
//!
//! Only the syscalls the repo actually uses are bound. Numbers come from
//! the kernel's `unistd` tables for each architecture and are stable ABI.

#![allow(clippy::missing_safety_doc)]

use std::io;

/// True when the epoll/eventfd bindings are functional on this target —
/// the `net` layer's `PollMode::Auto` resolves on this.
pub fn supported() -> bool {
    cfg!(all(
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))
}

/// One epoll readiness record. Layout matches the kernel ABI
/// (`struct epoll_event`), which is packed on x86_64 only.
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy, Default)]
pub struct EpollEvent {
    events: u32,
    data: u64,
}

impl EpollEvent {
    /// The token registered with [`Epoll::add`] for the ready fd.
    /// (Copies the field out — the struct may be packed.)
    pub fn token(&self) -> u64 {
        self.data
    }

    /// Raw readiness flags (EPOLLIN/EPOLLHUP/...).
    pub fn flags(&self) -> u32 {
        self.events
    }
}

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLRDHUP: u32 = 0x2000;

/// One scatter/gather segment. Layout matches the kernel ABI
/// (`struct iovec`: pointer + length); the address is stored as `usize`
/// so the struct stays `Copy`/`Send` without pointer-field ceremony.
#[repr(C)]
#[derive(Clone, Copy)]
pub struct IoVec {
    base: usize,
    len: usize,
}

impl IoVec {
    /// Segment reading from (writev) an immutable buffer.
    pub fn from_slice(buf: &[u8]) -> IoVec {
        IoVec { base: buf.as_ptr() as usize, len: buf.len() }
    }

    /// Segment writing into (readv) a mutable buffer.
    pub fn from_mut_slice(buf: &mut [u8]) -> IoVec {
        IoVec { base: buf.as_mut_ptr() as usize, len: buf.len() }
    }

    /// Bytes remaining in this segment.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True once the segment is fully consumed.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop the first `n` bytes (partial-transfer bookkeeping for a
    /// retry loop). `n` must not exceed the segment length.
    pub fn advance(&mut self, n: usize) {
        debug_assert!(n <= self.len);
        self.base += n;
        self.len -= n;
    }
}

/// Gather-write `iovs` to `fd` in one syscall; returns bytes written
/// (may be short — callers loop with [`IoVec::advance`]).
pub fn writev(fd: i32, iovs: &[IoVec]) -> io::Result<usize> {
    imp::writev(fd, iovs)
}

/// Scatter-read from `fd` into `iovs` in one syscall; returns bytes
/// read (0 = EOF).
pub fn readv(fd: i32, iovs: &[IoVec]) -> io::Result<usize> {
    imp::readv(fd, iovs)
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    use super::EpollEvent;
    use std::io;

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const READ: usize = 0;
        pub const WRITE: usize = 1;
        pub const CLOSE: usize = 3;
        pub const MMAP: usize = 9;
        pub const MUNMAP: usize = 11;
        pub const READV: usize = 19;
        pub const WRITEV: usize = 20;
        pub const MADVISE: usize = 28;
        pub const CLOCK_GETTIME: usize = 228;
        pub const EPOLL_CTL: usize = 233;
        pub const EPOLL_PWAIT: usize = 281;
        pub const EVENTFD2: usize = 290;
        pub const EPOLL_CREATE1: usize = 291;
        pub const IO_URING_SETUP: usize = 425;
        pub const IO_URING_ENTER: usize = 426;
    }

    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const READ: usize = 63;
        pub const WRITE: usize = 64;
        pub const CLOSE: usize = 57;
        pub const MMAP: usize = 222;
        pub const MUNMAP: usize = 215;
        pub const READV: usize = 65;
        pub const WRITEV: usize = 66;
        pub const MADVISE: usize = 233;
        pub const CLOCK_GETTIME: usize = 113;
        pub const EPOLL_CTL: usize = 21;
        pub const EPOLL_PWAIT: usize = 22;
        pub const EVENTFD2: usize = 19;
        pub const EPOLL_CREATE1: usize = 20;
        pub const IO_URING_SETUP: usize = 425;
        pub const IO_URING_ENTER: usize = 426;
    }

    /// Raw 6-argument syscall; returns the kernel's raw result
    /// (negative = -errno).
    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") n as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "svc 0",
            inlateout("x0") a1 as isize => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            in("x8") n,
            options(nostack),
        );
        ret
    }

    fn check(ret: isize) -> io::Result<usize> {
        if ret < 0 {
            Err(io::Error::from_raw_os_error(-ret as i32))
        } else {
            Ok(ret as usize)
        }
    }

    pub fn epoll_create1() -> io::Result<i32> {
        const EPOLL_CLOEXEC: usize = 0o2000000;
        check(unsafe { syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0) })
            .map(|fd| fd as i32)
    }

    pub fn epoll_ctl(epfd: i32, op: usize, fd: i32, event: Option<&EpollEvent>) -> io::Result<()> {
        let ptr = event.map_or(0usize, |e| e as *const EpollEvent as usize);
        check(unsafe { syscall6(nr::EPOLL_CTL, epfd as usize, op, fd as usize, ptr, 0, 0) })
            .map(|_| ())
    }

    pub fn epoll_wait(epfd: i32, out: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        check(unsafe {
            syscall6(
                nr::EPOLL_PWAIT,
                epfd as usize,
                out.as_mut_ptr() as usize,
                out.len(),
                timeout_ms as usize,
                0, // sigmask = NULL: don't alter the signal mask
                0,
            )
        })
    }

    pub fn eventfd() -> io::Result<i32> {
        const EFD_CLOEXEC: usize = 0o2000000;
        const EFD_NONBLOCK: usize = 0o4000;
        check(unsafe { syscall6(nr::EVENTFD2, 0, EFD_CLOEXEC | EFD_NONBLOCK, 0, 0, 0, 0) })
            .map(|fd| fd as i32)
    }

    pub fn read(fd: i32, buf: &mut [u8]) -> io::Result<usize> {
        check(unsafe {
            syscall6(nr::READ, fd as usize, buf.as_mut_ptr() as usize, buf.len(), 0, 0, 0)
        })
    }

    pub fn write(fd: i32, buf: &[u8]) -> io::Result<usize> {
        check(unsafe {
            syscall6(nr::WRITE, fd as usize, buf.as_ptr() as usize, buf.len(), 0, 0, 0)
        })
    }

    pub fn close(fd: i32) {
        let _ = unsafe { syscall6(nr::CLOSE, fd as usize, 0, 0, 0, 0, 0) };
    }

    pub fn writev(fd: i32, iovs: &[super::IoVec]) -> io::Result<usize> {
        check(unsafe {
            syscall6(nr::WRITEV, fd as usize, iovs.as_ptr() as usize, iovs.len(), 0, 0, 0)
        })
    }

    pub fn readv(fd: i32, iovs: &[super::IoVec]) -> io::Result<usize> {
        check(unsafe {
            syscall6(nr::READV, fd as usize, iovs.as_ptr() as usize, iovs.len(), 0, 0, 0)
        })
    }

    const PROT_READ: usize = 0x1;
    const PROT_WRITE: usize = 0x2;
    const MAP_SHARED: usize = 0x01;
    const MAP_PRIVATE: usize = 0x02;
    const MAP_POPULATE: usize = 0x8000;

    /// Map `len` bytes of `fd` read-only, private. Returns the mapping
    /// address. `len` must be non-zero (the kernel rejects empty maps).
    pub fn mmap_ro(fd: i32, len: usize) -> io::Result<usize> {
        check(unsafe { syscall6(nr::MMAP, 0, len, PROT_READ, MAP_PRIVATE, fd as usize, 0) })
    }

    pub fn munmap(addr: usize, len: usize) {
        let _ = unsafe { syscall6(nr::MUNMAP, addr, len, 0, 0, 0, 0) };
    }

    pub fn madvise(addr: usize, len: usize, advice: usize) -> io::Result<()> {
        check(unsafe { syscall6(nr::MADVISE, addr, len, advice, 0, 0, 0) }).map(|_| ())
    }

    // ---- io_uring ------------------------------------------------------
    //
    // Minimal binding: the ring is used purely as a readiness driver
    // (one-shot IORING_OP_POLL_ADD per fd + IORING_OP_TIMEOUT for the
    // wait deadline), which keeps the unsafe surface to the two mmap'd
    // ring buffers and mirrors the epoll loop's delete-on-ready shape.

    const IORING_OFF_SQ_RING: usize = 0;
    const IORING_OFF_CQ_RING: usize = 0x0800_0000;
    const IORING_OFF_SQES: usize = 0x1000_0000;
    const IORING_ENTER_GETEVENTS: usize = 1;
    const IORING_FEAT_SINGLE_MMAP: u32 = 1;
    const IORING_OP_POLL_ADD: u8 = 6;
    const IORING_OP_TIMEOUT: u8 = 11;
    /// `user_data` sentinel for the internal timeout op — never surfaced.
    const TIMEOUT_DATA: u64 = u64::MAX - 7;

    #[repr(C)]
    #[derive(Default)]
    struct SqOffsets {
        head: u32,
        tail: u32,
        ring_mask: u32,
        ring_entries: u32,
        flags: u32,
        dropped: u32,
        array: u32,
        resv1: u32,
        user_addr: u64,
    }

    #[repr(C)]
    #[derive(Default)]
    struct CqOffsets {
        head: u32,
        tail: u32,
        ring_mask: u32,
        ring_entries: u32,
        overflow: u32,
        cqes: u32,
        flags: u32,
        resv1: u32,
        user_addr: u64,
    }

    #[repr(C)]
    #[derive(Default)]
    struct UringParams {
        sq_entries: u32,
        cq_entries: u32,
        flags: u32,
        sq_thread_cpu: u32,
        sq_thread_idle: u32,
        features: u32,
        wq_fd: u32,
        resv: [u32; 3],
        sq_off: SqOffsets,
        cq_off: CqOffsets,
    }

    /// Submission queue entry (64 bytes, kernel layout; the trailing
    /// union members the binding never touches are folded into `pad`).
    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    struct Sqe {
        opcode: u8,
        flags: u8,
        ioprio: u16,
        fd: i32,
        off: u64,
        addr: u64,
        len: u32,
        op_flags: u32,
        user_data: u64,
        pad: [u64; 3],
    }

    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    struct Cqe {
        user_data: u64,
        res: i32,
        flags: u32,
    }

    /// `__kernel_timespec`: the 64-bit timespec io_uring timeouts take.
    #[repr(C)]
    struct KernelTimespec {
        sec: i64,
        nsec: i64,
    }

    /// One ring mmap; unmapped on drop so partial construction cleans up.
    struct RingMap {
        addr: usize,
        len: usize,
    }

    impl RingMap {
        fn new(fd: i32, len: usize, off: usize) -> io::Result<RingMap> {
            let addr = check(unsafe {
                syscall6(
                    nr::MMAP,
                    0,
                    len,
                    PROT_READ | PROT_WRITE,
                    MAP_SHARED | MAP_POPULATE,
                    fd as usize,
                    off,
                )
            })?;
            Ok(RingMap { addr, len })
        }
    }

    impl Drop for RingMap {
        fn drop(&mut self) {
            munmap(self.addr, self.len);
        }
    }

    /// Owned ring fd: closed on drop (keeps `Uring::new` leak-free on
    /// partial mmap failure).
    struct RingFd(i32);

    impl Drop for RingFd {
        fn drop(&mut self) {
            close(self.0);
        }
    }

    /// A minimal io_uring instance driving readiness notification:
    /// one-shot poll registrations complete when the fd turns readable,
    /// so "completion arrived" means exactly what an epoll wakeup plus
    /// `Epoll::delete` means — the fd is ready and unwatched.
    pub struct Uring {
        fd: RingFd,
        // Keep the three mappings alive; all raw pointers below point
        // into them.
        _sq_map: RingMap,
        _cq_map: Option<RingMap>,
        _sqes_map: RingMap,
        sq_head: usize,
        sq_tail: usize,
        sq_mask: u32,
        sq_entries: u32,
        sq_array: usize,
        sqes: usize,
        cq_head: usize,
        cq_tail: usize,
        cq_mask: u32,
        cqes: usize,
        to_submit: u32,
        /// Stable address handed to the kernel for IORING_OP_TIMEOUT.
        timeout: Box<KernelTimespec>,
    }

    // The raw pointers reference the ring mappings owned by the same
    // struct; the Uring is driven from one poll thread at a time.
    unsafe impl Send for Uring {}

    impl Uring {
        /// Set up a ring with `entries` submission slots. Fails with
        /// ENOSYS/EPERM on kernels or sandboxes without io_uring —
        /// callers fall back to epoll.
        pub fn new(entries: u32) -> io::Result<Uring> {
            let mut p = UringParams::default();
            let fd = check(unsafe {
                syscall6(
                    nr::IO_URING_SETUP,
                    entries as usize,
                    &mut p as *mut UringParams as usize,
                    0,
                    0,
                    0,
                    0,
                )
            })? as i32;
            let fd = RingFd(fd);
            let sq_len = p.sq_off.array as usize + p.sq_entries as usize * 4;
            let cq_len = p.cq_off.cqes as usize + p.cq_entries as usize * 16;
            let (sq_map, cq_map) = if p.features & IORING_FEAT_SINGLE_MMAP != 0 {
                (RingMap::new(fd.0, sq_len.max(cq_len), IORING_OFF_SQ_RING)?, None)
            } else {
                (
                    RingMap::new(fd.0, sq_len, IORING_OFF_SQ_RING)?,
                    Some(RingMap::new(fd.0, cq_len, IORING_OFF_CQ_RING)?),
                )
            };
            let sqes_map = RingMap::new(fd.0, p.sq_entries as usize * 64, IORING_OFF_SQES)?;
            let sq = sq_map.addr;
            let cq = cq_map.as_ref().map_or(sq, |m| m.addr);
            Ok(Uring {
                sq_head: sq + p.sq_off.head as usize,
                sq_tail: sq + p.sq_off.tail as usize,
                sq_mask: unsafe { *((sq + p.sq_off.ring_mask as usize) as *const u32) },
                sq_entries: p.sq_entries,
                sq_array: sq + p.sq_off.array as usize,
                sqes: sqes_map.addr,
                cq_head: cq + p.cq_off.head as usize,
                cq_tail: cq + p.cq_off.tail as usize,
                cq_mask: unsafe { *((cq + p.cq_off.ring_mask as usize) as *const u32) },
                cqes: cq + p.cq_off.cqes as usize,
                to_submit: 0,
                timeout: Box::new(KernelTimespec { sec: 0, nsec: 0 }),
                fd,
                _sq_map: sq_map,
                _cq_map: cq_map,
                _sqes_map: sqes_map,
            })
        }

        fn atomic(addr: usize) -> &'static std::sync::atomic::AtomicU32 {
            unsafe { &*(addr as *const std::sync::atomic::AtomicU32) }
        }

        fn enter(&self, submit: u32, min_complete: u32, flags: usize) -> io::Result<usize> {
            loop {
                let ret = check(unsafe {
                    syscall6(
                        nr::IO_URING_ENTER,
                        self.fd.0 as usize,
                        submit as usize,
                        min_complete as usize,
                        flags,
                        0,
                        0,
                    )
                });
                match ret {
                    // EINTR is only returned when nothing was submitted,
                    // so retrying with the same arguments is safe.
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    other => return other,
                }
            }
        }

        fn push(&mut self, sqe: Sqe) -> io::Result<()> {
            use std::sync::atomic::Ordering;
            loop {
                let head = Self::atomic(self.sq_head).load(Ordering::Acquire);
                let tail = Self::atomic(self.sq_tail).load(Ordering::Relaxed);
                if tail.wrapping_sub(head) < self.sq_entries {
                    let idx = tail & self.sq_mask;
                    unsafe {
                        *(self.sqes as *mut Sqe).add(idx as usize) = sqe;
                        *(self.sq_array as *mut u32).add(idx as usize) = idx;
                    }
                    Self::atomic(self.sq_tail).store(tail.wrapping_add(1), Ordering::Release);
                    self.to_submit += 1;
                    return Ok(());
                }
                // Ring full: hand what we have to the kernel and retry.
                if self.to_submit == 0 {
                    return Err(io::Error::new(
                        io::ErrorKind::WouldBlock,
                        "io_uring submission queue full",
                    ));
                }
                let n = self.enter(self.to_submit, 0, 0)?;
                self.to_submit -= n.min(self.to_submit as usize) as u32;
            }
        }

        /// Watch `fd` for input readiness / peer hangup (one-shot): a
        /// completion tagged `token` arrives when it turns readable, and
        /// the registration is consumed with it.
        pub fn poll_add(&mut self, fd: i32, token: u64) -> io::Result<()> {
            let sqe = Sqe {
                opcode: IORING_OP_POLL_ADD,
                fd,
                // Same numeric values as the epoll flag constants.
                op_flags: super::EPOLLIN | super::EPOLLRDHUP,
                user_data: token,
                ..Sqe::default()
            };
            self.push(sqe)
        }

        /// Submit pending registrations and block up to `timeout_ms` for
        /// completions; fills `out` and returns how many are valid.
        pub fn wait(
            &mut self,
            out: &mut [super::UringCompletion],
            timeout_ms: i32,
        ) -> io::Result<usize> {
            use std::sync::atomic::Ordering;
            self.timeout.sec = timeout_ms as i64 / 1000;
            self.timeout.nsec = (timeout_ms as i64 % 1000) * 1_000_000;
            let sqe = Sqe {
                opcode: IORING_OP_TIMEOUT,
                fd: -1,
                addr: &*self.timeout as *const KernelTimespec as u64,
                len: 1,
                // off = completion count that also satisfies the timeout:
                // fire after 1 real completion or when the clock runs out.
                off: 1,
                user_data: TIMEOUT_DATA,
                ..Sqe::default()
            };
            self.push(sqe)?;
            self.enter(self.to_submit, 1, IORING_ENTER_GETEVENTS)?;
            self.to_submit = 0;
            let mut n = 0;
            let mut head = Self::atomic(self.cq_head).load(Ordering::Relaxed);
            let tail = Self::atomic(self.cq_tail).load(Ordering::Acquire);
            while head != tail && n < out.len() {
                let cqe = unsafe { *(self.cqes as *const Cqe).add((head & self.cq_mask) as usize) };
                head = head.wrapping_add(1);
                if cqe.user_data == TIMEOUT_DATA {
                    continue;
                }
                out[n] = super::UringCompletion { token: cqe.user_data, res: cqe.res };
                n += 1;
            }
            Self::atomic(self.cq_head).store(head, Ordering::Release);
            Ok(n)
        }
    }

    /// CLOCK_PROCESS_CPUTIME_ID in nanoseconds (the idle-fleet CPU bench).
    pub fn process_cpu_ns() -> Option<u64> {
        const CLOCK_PROCESS_CPUTIME_ID: usize = 2;
        #[repr(C)]
        struct Timespec {
            sec: i64,
            nsec: i64,
        }
        let mut ts = Timespec { sec: 0, nsec: 0 };
        let ret = unsafe {
            syscall6(
                nr::CLOCK_GETTIME,
                CLOCK_PROCESS_CPUTIME_ID,
                &mut ts as *mut Timespec as usize,
                0,
                0,
                0,
                0,
            )
        };
        if ret < 0 {
            return None;
        }
        Some(ts.sec as u64 * 1_000_000_000 + ts.nsec as u64)
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod imp {
    //! Stub bindings: constructors fail, `PollMode::Auto` resolves to the
    //! portable peek sweep, and nothing here is ever invoked at runtime.
    use super::EpollEvent;
    use std::io;

    fn unsupported<T>() -> io::Result<T> {
        Err(io::Error::new(io::ErrorKind::Unsupported, "no syscall bindings on this target"))
    }

    pub fn epoll_create1() -> io::Result<i32> {
        unsupported()
    }

    pub fn epoll_ctl(
        _epfd: i32,
        _op: usize,
        _fd: i32,
        _event: Option<&EpollEvent>,
    ) -> io::Result<()> {
        unsupported()
    }

    pub fn epoll_wait(_epfd: i32, _out: &mut [EpollEvent], _timeout_ms: i32) -> io::Result<usize> {
        unsupported()
    }

    pub fn eventfd() -> io::Result<i32> {
        unsupported()
    }

    pub fn read(_fd: i32, _buf: &mut [u8]) -> io::Result<usize> {
        unsupported()
    }

    pub fn write(_fd: i32, _buf: &[u8]) -> io::Result<usize> {
        unsupported()
    }

    pub fn close(_fd: i32) {}

    pub fn writev(_fd: i32, _iovs: &[super::IoVec]) -> io::Result<usize> {
        unsupported()
    }

    pub fn readv(_fd: i32, _iovs: &[super::IoVec]) -> io::Result<usize> {
        unsupported()
    }

    pub fn mmap_ro(_fd: i32, _len: usize) -> io::Result<usize> {
        unsupported()
    }

    pub fn munmap(_addr: usize, _len: usize) {}

    pub fn madvise(_addr: usize, _len: usize, _advice: usize) -> io::Result<()> {
        unsupported()
    }

    /// Stub ring: the constructor fails, so the uring poll loop is never
    /// entered and `PollMode::Uring` falls back like `Event` does.
    pub struct Uring;

    impl Uring {
        pub fn new(_entries: u32) -> io::Result<Uring> {
            unsupported()
        }

        pub fn poll_add(&mut self, _fd: i32, _token: u64) -> io::Result<()> {
            unsupported()
        }

        pub fn wait(
            &mut self,
            _out: &mut [super::UringCompletion],
            _timeout_ms: i32,
        ) -> io::Result<usize> {
            unsupported()
        }
    }

    pub fn process_cpu_ns() -> Option<u64> {
        None
    }
}

const EPOLL_CTL_ADD: usize = 1;
const EPOLL_CTL_DEL: usize = 2;

/// Kernel readiness-notification set: register fds with tokens, sleep
/// until one is ready. Wakeups are O(ready), idle waits cost zero CPU —
/// the property the parked-connection poll loop needs at fleet scale.
pub struct Epoll {
    fd: i32,
}

impl Epoll {
    /// New epoll instance (fails on unsupported targets — callers fall
    /// back to the peek sweep).
    pub fn new() -> io::Result<Epoll> {
        imp::epoll_create1().map(|fd| Epoll { fd })
    }

    /// Watch `fd` for input readiness / peer hangup, tagged with `token`
    /// (level-triggered: already-buffered bytes report on the next wait).
    pub fn add(&self, fd: i32, token: u64) -> io::Result<()> {
        let ev = EpollEvent { events: EPOLLIN | EPOLLRDHUP, data: token };
        imp::epoll_ctl(self.fd, EPOLL_CTL_ADD, fd, Some(&ev))
    }

    /// Stop watching `fd`.
    pub fn delete(&self, fd: i32) -> io::Result<()> {
        imp::epoll_ctl(self.fd, EPOLL_CTL_DEL, fd, None)
    }

    /// Block up to `timeout_ms` (-1 = forever) for readiness; fills `out`
    /// and returns how many records are valid.
    pub fn wait(&self, out: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        imp::epoll_wait(self.fd, out, timeout_ms)
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        imp::close(self.fd);
    }
}

/// Cross-thread waker for an [`Epoll`] sleeper (nonblocking eventfd):
/// `signal` from any thread makes the fd readable, `drain` resets it.
pub struct EventFd {
    fd: i32,
}

impl EventFd {
    /// New waker (fails on unsupported targets).
    pub fn new() -> io::Result<EventFd> {
        imp::eventfd().map(|fd| EventFd { fd })
    }

    /// Raw fd for epoll registration.
    pub fn raw_fd(&self) -> i32 {
        self.fd
    }

    /// Make the fd readable (wake the sleeper). Infallible by design: a
    /// full counter still leaves the fd readable.
    pub fn signal(&self) {
        let one = 1u64.to_ne_bytes();
        let _ = imp::write(self.fd, &one);
    }

    /// Consume pending signals so the next wait sleeps again.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        let _ = imp::read(self.fd, &mut buf);
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        imp::close(self.fd);
    }
}

/// CPU time this process has consumed, in nanoseconds (`None` where the
/// binding is unavailable). The idle-fleet bench compares this across
/// poll modes: a parked fleet under epoll must burn ~no CPU.
pub fn process_cpu_ns() -> Option<u64> {
    imp::process_cpu_ns()
}

/// One io_uring completion surfaced by [`Uring::wait`]: the `user_data`
/// token from the matching registration plus the kernel result code.
#[derive(Clone, Copy, Default)]
pub struct UringCompletion {
    pub token: u64,
    pub res: i32,
}

/// Minimal io_uring readiness driver (real on Linux, failing constructor
/// elsewhere) — see the module docs in `imp` for the design.
pub use imp::Uring;

/// `madvise` advice values the checkpoint loader uses.
pub const MADV_SEQUENTIAL: usize = 2;
pub const MADV_WILLNEED: usize = 3;

/// A read-only private file mapping with RAII unmap. Dereferences to the
/// file bytes, so decoders can borrow directly from the page cache
/// instead of streaming the file through an intermediate heap buffer.
pub struct Mmap {
    addr: usize,
    len: usize,
}

// The mapping is immutable bytes; concurrent readers are fine.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map an already-open file read-only. Fails on unsupported targets,
    /// empty files (the kernel rejects zero-length maps), or any mmap
    /// error — callers fall back to `std::fs::read`.
    pub fn map(file: &std::fs::File) -> io::Result<Mmap> {
        let len = file.metadata()?.len();
        if len == 0 {
            return Err(io::Error::new(io::ErrorKind::InvalidInput, "empty file"));
        }
        if len > usize::MAX as u64 {
            return Err(io::Error::new(io::ErrorKind::InvalidInput, "file too large to map"));
        }
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let len = len as usize;
            let addr = imp::mmap_ro(file.as_raw_fd(), len)?;
            Ok(Mmap { addr, len })
        }
        #[cfg(not(unix))]
        {
            Err(io::Error::new(io::ErrorKind::Unsupported, "no mmap binding on this target"))
        }
    }

    /// Hint the access pattern to the kernel (best-effort).
    pub fn advise(&self, advice: usize) {
        let _ = imp::madvise(self.addr, self.len, advice);
    }
}

impl std::ops::Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.addr as *const u8, self.len) }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        imp::munmap(self.addr, self.len);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoll_reports_readable_pipe_like_socket() {
        if !supported() {
            return;
        }
        use std::io::Write;
        use std::os::unix::io::AsRawFd;
        // A connected TCP pair is the closest std-only fd pair.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut tx = std::net::TcpStream::connect(addr).unwrap();
        let (rx, _) = listener.accept().unwrap();

        let ep = Epoll::new().unwrap();
        ep.add(rx.as_raw_fd(), 42).unwrap();
        let mut events = [EpollEvent::default(); 8];
        // Nothing pending: times out with zero events.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        tx.write_all(b"x").unwrap();
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].token(), 42);
        assert!(events[0].flags() & EPOLLIN != 0);
        ep.delete(rx.as_raw_fd()).unwrap();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn eventfd_wakes_epoll_and_drains() {
        if !supported() {
            return;
        }
        let ep = Epoll::new().unwrap();
        let wake = EventFd::new().unwrap();
        ep.add(wake.raw_fd(), 7).unwrap();
        let mut events = [EpollEvent::default(); 4];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        wake.signal();
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
        assert_eq!(events[0].token(), 7);
        wake.drain();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        // Wake from another thread unblocks a sleeping wait.
        let ep = std::sync::Arc::new(ep);
        let wake = std::sync::Arc::new(wake);
        let w2 = wake.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            w2.signal();
        });
        let n = ep.wait(&mut events, 5_000).unwrap();
        assert_eq!(n, 1);
        t.join().unwrap();
    }

    #[test]
    fn writev_readv_round_trip_scattered_buffers() {
        if !supported() {
            return;
        }
        use std::os::unix::io::AsRawFd;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let tx = std::net::TcpStream::connect(addr).unwrap();
        let (rx, _) = listener.accept().unwrap();

        let head = b"HEAD".to_vec();
        let body = (0..=255u8).collect::<Vec<u8>>();
        let iovs = [IoVec::from_slice(&head), IoVec::from_slice(&body)];
        // 260 bytes always fit a fresh loopback socket buffer whole.
        let n = writev(tx.as_raw_fd(), &iovs).unwrap();
        assert_eq!(n, head.len() + body.len());

        let mut a = [0u8; 4];
        let mut b = vec![0u8; 256];
        let mut got = 0;
        while got < 260 {
            let (ai, bi) = (got.min(4), got.saturating_sub(4));
            let riovs = [IoVec::from_mut_slice(&mut a[ai..]), IoVec::from_mut_slice(&mut b[bi..])];
            let n = readv(rx.as_raw_fd(), &riovs).unwrap();
            assert!(n > 0, "EOF before full message");
            got += n;
        }
        assert_eq!(&a, b"HEAD");
        assert_eq!(b, body);
    }

    #[test]
    fn iovec_advance_tracks_partial_transfers() {
        let buf = [1u8, 2, 3, 4, 5];
        let mut iov = IoVec::from_slice(&buf);
        assert_eq!(iov.len(), 5);
        iov.advance(3);
        assert_eq!(iov.len(), 2);
        assert!(!iov.is_empty());
        iov.advance(2);
        assert!(iov.is_empty());
    }

    #[test]
    fn mmap_exposes_file_bytes_and_rejects_empty() {
        if !supported() {
            return;
        }
        let dir = std::env::temp_dir().join(format!("weips_sys_mmap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob");
        let payload: Vec<u8> = (0..10_000u32).flat_map(|i| i.to_le_bytes()).collect();
        std::fs::write(&path, &payload).unwrap();
        let map = Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap();
        map.advise(MADV_SEQUENTIAL);
        map.advise(MADV_WILLNEED);
        assert_eq!(&map[..], &payload[..]);

        let empty = dir.join("empty");
        std::fs::write(&empty, b"").unwrap();
        assert!(Mmap::map(&std::fs::File::open(&empty).unwrap()).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn uring_reports_readiness_like_epoll() {
        if !supported() {
            return;
        }
        let mut ring = match Uring::new(8) {
            Ok(r) => r,
            // Kernel or sandbox without io_uring: the fallback path is
            // exercised by the net-layer tests instead.
            Err(_) => return,
        };
        use std::io::Write;
        use std::os::unix::io::AsRawFd;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut tx = std::net::TcpStream::connect(addr).unwrap();
        let (rx, _) = listener.accept().unwrap();

        ring.poll_add(rx.as_raw_fd(), 42).unwrap();
        let mut out = [UringCompletion::default(); 8];
        // Not readable yet: the wait times out with no completions.
        assert_eq!(ring.wait(&mut out, 50).unwrap(), 0);
        tx.write_all(b"x").unwrap();
        let n = ring.wait(&mut out, 2_000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(out[0].token, 42);
        assert!(out[0].res >= 0);
        // One-shot: readiness was consumed with the completion.
        assert_eq!(ring.wait(&mut out, 50).unwrap(), 0);
        // Re-arm and observe readiness again (bytes still buffered).
        ring.poll_add(rx.as_raw_fd(), 43).unwrap();
        let n = ring.wait(&mut out, 2_000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(out[0].token, 43);
    }

    #[test]
    fn process_cpu_clock_advances() {
        if !supported() {
            return;
        }
        let a = process_cpu_ns().unwrap();
        // Burn a little CPU.
        let mut x = 0u64;
        for i in 0..2_000_000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(x);
        let b = process_cpu_ns().unwrap();
        assert!(b >= a);
        assert!(b > 0);
    }
}
