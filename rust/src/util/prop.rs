//! In-repo property-based testing harness (proptest is unavailable offline).
//!
//! A tiny shrinking property tester: generators are closures over [`Rng`],
//! `check` runs N seeded cases, and on failure greedily shrinks the input
//! via the strategy's `shrink` before panicking with the minimal
//! counterexample and its reproduction seed. Used by the coordinator
//! invariant suites (routing totality, queue idempotence, gather
//! last-write-wins, codec round-trips — DESIGN.md §6).

use super::rng::Rng;

/// A value generator plus shrinker.
pub trait Strategy {
    /// Generated value type.
    type Value: Clone + std::fmt::Debug;
    /// Generate one value.
    fn gen(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values (tried in order during shrinking).
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run `cases` seeded random cases of `prop` against `strategy`; on failure
/// shrink (up to 200 steps) and panic with the minimal counterexample.
pub fn check<S, F>(name: &str, strategy: &S, cases: usize, mut prop: F)
where
    S: Strategy,
    F: FnMut(&S::Value) -> std::result::Result<(), String>,
{
    // Honor WEIPS_PROP_SEED for reproduction.
    let base_seed = std::env::var("WEIPS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let value = strategy.gen(&mut rng);
        if let Err(msg) = prop(&value) {
            // Shrink.
            let mut best = value;
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < 200 {
                for cand in strategy.shrink(&best) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if steps >= 200 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (seed={seed}, case={case}):\n  input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Uniform u64 in [lo, hi].
pub struct U64Range(pub u64, pub u64);

impl Strategy for U64Range {
    type Value = u64;

    fn gen(&self, rng: &mut Rng) -> u64 {
        self.0 + rng.gen_range(self.1 - self.0 + 1)
    }

    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Vector of values from an element strategy, length in [0, max_len].
pub struct VecOf<S>(pub S, pub usize);

impl<S: Strategy> Strategy for VecOf<S> {
    type Value = Vec<S::Value>;

    fn gen(&self, rng: &mut Rng) -> Self::Value {
        let len = rng.gen_range(self.1 as u64 + 1) as usize;
        (0..len).map(|_| self.0.gen(rng)).collect()
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.is_empty() {
            return out;
        }
        // Halve, drop-front, drop-back, then shrink one element.
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[1..].to_vec());
        out.push(v[..v.len() - 1].to_vec());
        for (i, elem) in v.iter().enumerate().take(4) {
            for smaller in self.0.shrink(elem) {
                let mut copy = v.clone();
                copy[i] = smaller;
                out.push(copy);
            }
        }
        out
    }
}

/// Pair of two strategies.
pub struct PairOf<A, B>(pub A, pub B);

impl<A: Strategy, B: Strategy> Strategy for PairOf<A, B> {
    type Value = (A::Value, B::Value);

    fn gen(&self, rng: &mut Rng) -> Self::Value {
        (self.0.gen(rng), self.1.gen(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// f32 in [lo, hi] (finite).
pub struct F32Range(pub f32, pub f32);

impl Strategy for F32Range {
    type Value = f32;

    fn gen(&self, rng: &mut Rng) -> f32 {
        self.0 + rng.gen_f32() * (self.1 - self.0)
    }

    fn shrink(&self, v: &f32) -> Vec<f32> {
        let mut out = Vec::new();
        if *v != 0.0 && self.0 <= 0.0 && self.1 >= 0.0 {
            out.push(0.0);
            out.push(v / 2.0);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check("sum-commutes", &PairOf(U64Range(0, 100), U64Range(0, 100)), 200, |(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'finds-bug' failed")]
    fn failing_property_panics_with_counterexample() {
        check("finds-bug", &U64Range(0, 1000), 500, |v| {
            if *v < 500 {
                Ok(())
            } else {
                Err(format!("{v} >= 500"))
            }
        });
    }

    #[test]
    fn shrinking_reaches_small_counterexample() {
        // Catch the panic and confirm the reported input shrank to <= a
        // small multiple of the boundary.
        let result = std::panic::catch_unwind(|| {
            check("shrinks", &VecOf(U64Range(0, 100), 50), 200, |v| {
                if v.len() < 5 {
                    Ok(())
                } else {
                    Err("too long".into())
                }
            });
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // Minimal failing length is 5; shrinker should get close.
        let input_part = msg.split("input: ").nth(1).unwrap();
        let commas = input_part.chars().filter(|&c| c == ',').count();
        assert!(commas <= 7, "shrunk input still large: {msg}");
    }

    #[test]
    fn vec_strategy_respects_max_len() {
        let s = VecOf(U64Range(0, 10), 8);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert!(s.gen(&mut rng).len() <= 8);
        }
    }
}
