//! Lock-free multi-producer single-consumer queue.
//!
//! §4.1.1 of the paper: the collector "writes to the internal lock-free
//! cache queue ... to collect the weight increment generated in the
//! multi-threading to ensure thread safety without affecting the parameter
//! update performance". This is that queue: a Vyukov-style intrusive MPSC
//! linked queue — producers are wait-free (one `swap` + one `store`), the
//! single consumer (the gather thread) pops without CAS loops.

use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

struct Node<T> {
    next: AtomicPtr<Node<T>>,
    value: Option<T>,
}

/// Vyukov MPSC queue. `push` may be called from any thread concurrently;
/// `pop`/`drain` must only be called from one consumer thread at a time.
pub struct LockFreeQueue<T> {
    head: AtomicPtr<Node<T>>, // producers swap here
    tail: AtomicPtr<Node<T>>, // consumer reads here (stub node)
    len: AtomicUsize,
}

unsafe impl<T: Send> Send for LockFreeQueue<T> {}
unsafe impl<T: Send> Sync for LockFreeQueue<T> {}

impl<T> Default for LockFreeQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> LockFreeQueue<T> {
    /// Empty queue (allocates one stub node).
    pub fn new() -> Self {
        let stub = Box::into_raw(Box::new(Node {
            next: AtomicPtr::new(ptr::null_mut()),
            value: None,
        }));
        LockFreeQueue {
            head: AtomicPtr::new(stub),
            tail: AtomicPtr::new(stub),
            len: AtomicUsize::new(0),
        }
    }

    /// Enqueue from any thread. Wait-free: one atomic swap.
    pub fn push(&self, value: T) {
        let node = Box::into_raw(Box::new(Node {
            next: AtomicPtr::new(ptr::null_mut()),
            value: Some(value),
        }));
        // Publish: swap ourselves in as head, then link the previous head.
        let prev = self.head.swap(node, Ordering::AcqRel);
        unsafe { (*prev).next.store(node, Ordering::Release) };
        self.len.fetch_add(1, Ordering::Relaxed);
    }

    /// Dequeue; `None` when empty (or when a producer has swapped but not
    /// yet linked — momentarily treated as empty, which is safe for the
    /// gather loop: it will see the element on the next poll).
    pub fn pop(&self) -> Option<T> {
        unsafe {
            let tail = self.tail.load(Ordering::Acquire);
            let next = (*tail).next.load(Ordering::Acquire);
            if next.is_null() {
                return None;
            }
            // Advance tail; old tail (the stub) is freed, `next` becomes
            // the new stub carrying the value out.
            self.tail.store(next, Ordering::Release);
            let value = (*next).value.take();
            drop(Box::from_raw(tail));
            self.len.fetch_sub(1, Ordering::Relaxed);
            value
        }
    }

    /// Pop everything currently linked into `out`; returns count.
    pub fn drain_into(&self, out: &mut Vec<T>) -> usize {
        let mut n = 0;
        while let Some(v) = self.pop() {
            out.push(v);
            n += 1;
        }
        n
    }

    /// Approximate length (racy; for metrics/backpressure only).
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    /// True if approximately empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Drop for LockFreeQueue<T> {
    fn drop(&mut self) {
        while self.pop().is_some() {}
        // Free the remaining stub.
        let stub = self.tail.load(Ordering::Relaxed);
        if !stub.is_null() {
            unsafe { drop(Box::from_raw(stub)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = LockFreeQueue::new();
        assert!(q.pop().is_none());
        for i in 0..100 {
            q.push(i);
        }
        assert_eq!(q.len(), 100);
        for i in 0..100 {
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn drain_collects_all() {
        let q = LockFreeQueue::new();
        for i in 0..10 {
            q.push(i);
        }
        let mut out = Vec::new();
        assert_eq!(q.drain_into(&mut out), 10);
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn multi_producer_no_loss() {
        let q = Arc::new(LockFreeQueue::new());
        let producers = 4;
        let per = 10_000u64;
        let mut handles = Vec::new();
        for p in 0..producers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    q.push(p as u64 * per + i);
                }
            }));
        }
        // Consume concurrently from this (single consumer) thread.
        let mut seen = Vec::with_capacity((producers as u64 * per) as usize);
        while seen.len() < (producers as u64 * per) as usize {
            if let Some(v) = q.pop() {
                seen.push(v);
            } else {
                std::hint::spin_loop();
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), (producers as u64 * per) as usize, "lost or duplicated items");
        assert!(q.pop().is_none());
    }

    #[test]
    fn per_producer_order_preserved() {
        let q = Arc::new(LockFreeQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            for i in 0..1000u64 {
                q2.push((1u64, i));
            }
        });
        for i in 0..1000u64 {
            q.push((0u64, i));
        }
        h.join().unwrap();
        let mut last = [None::<u64>; 2];
        while let Some((p, i)) = q.pop() {
            if let Some(prev) = last[p as usize] {
                assert!(i > prev, "producer {p} reordered: {i} after {prev}");
            }
            last[p as usize] = Some(i);
        }
        assert_eq!(last, [Some(999), Some(999)]);
    }

    #[test]
    fn drop_releases_pending_items() {
        // Drop with items still queued; run under the test allocator to
        // ensure no leaks/UAF (implicitly covered by miri-less sanity).
        let q = LockFreeQueue::new();
        for i in 0..32 {
            q.push(vec![i; 16]);
        }
        drop(q);
    }
}
