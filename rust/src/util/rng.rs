//! Deterministic pseudo-random number generation (splitmix64 + xoshiro256**).
//!
//! Used for synthetic workload generation, the randomized checkpoint trigger
//! (§4.2.1a of the paper), and the in-repo property-testing harness. All
//! randomness in WeiPS flows through seeded [`Rng`] instances so every
//! experiment and test is reproducible.

/// Splitmix64 step: good enough to seed and to derive stream ids.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG — fast, 256-bit state, statistically strong for
/// simulation purposes (not cryptographic).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-thread generators).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Next 64 uniformly random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; n must be > 0).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        -self.gen_f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick an index from unnormalized weights.
    pub fn pick_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len().saturating_sub(1)
    }
}

/// Zipf(s) sampler over `{0, .., n-1}` via rejection-inversion
/// (Hörmann & Derflinger) — O(1) per sample, used to model the power-law
/// popularity of feature ids that drives the paper's 90 %-repetition
/// observation (DESIGN.md E2).
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dense: Option<Vec<f64>>, // cdf for tiny n
}

impl Zipf {
    /// New sampler over `n` items with exponent `s > 0`, `s != 1` handled.
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n > 0 && s > 0.0);
        if n <= 64 {
            // Exact CDF for small domains.
            let mut cdf = Vec::with_capacity(n as usize);
            let mut acc = 0.0;
            for k in 1..=n {
                acc += (k as f64).powf(-s);
                cdf.push(acc);
            }
            let total = acc;
            for v in cdf.iter_mut() {
                *v /= total;
            }
            return Zipf { n, s, h_x1: 0.0, h_n: 0.0, dense: Some(cdf) };
        }
        let h = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-9 {
                (x).ln()
            } else {
                (x.powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        Zipf { n, s, h_x1: h(1.5) - 1.0, h_n: h(n as f64 + 0.5), dense: None }
    }

    fn h_inv(&self, x: f64) -> f64 {
        if (self.s - 1.0).abs() < 1e-9 {
            x.exp()
        } else {
            (1.0 + x * (1.0 - self.s)).powf(1.0 / (1.0 - self.s))
        }
    }

    /// Sample a rank in `[0, n)`; rank 0 is the most popular item.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if let Some(cdf) = &self.dense {
            let u = rng.gen_f64();
            return cdf.partition_point(|&c| c < u) as u64;
        }
        loop {
            let u = self.h_x1 + rng.gen_f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(1.0).min(self.n as f64);
            let h = |y: f64| -> f64 {
                if (self.s - 1.0).abs() < 1e-9 {
                    y.ln()
                } else {
                    (y.powf(1.0 - self.s) - 1.0) / (1.0 - self.s)
                }
            };
            if u >= h(k + 0.5) - (k).powf(-self.s) {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn range_is_bounded_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn f64_in_unit_interval_with_plausible_mean() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_has_unit_variance() {
        let mut r = Rng::new(11);
        let (mut s, mut s2) = (0.0, 0.0);
        let n = 20_000;
        for _ in 0..n {
            let v = r.gen_normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_head_is_heavier_than_tail() {
        let z = Zipf::new(10_000, 1.1);
        let mut r = Rng::new(5);
        let mut head = 0usize;
        let n = 50_000;
        for _ in 0..n {
            if z.sample(&mut r) < 100 {
                head += 1;
            }
        }
        // With s=1.1 the top-1% of ranks should get a large share of mass.
        assert!(head as f64 / n as f64 > 0.35, "head share {}", head as f64 / n as f64);
    }

    #[test]
    fn zipf_small_domain_exact() {
        let z = Zipf::new(3, 1.0);
        let mut r = Rng::new(13);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        // Expected proportions 6/11, 3/11, 2/11.
        let p0 = counts[0] as f64 / 30_000.0;
        assert!((p0 - 6.0 / 11.0).abs() < 0.02, "p0={p0}");
        assert!(counts[0] > counts[1] && counts[1] > counts[2]);
    }

    #[test]
    fn pick_weighted_respects_weights() {
        let mut r = Rng::new(21);
        let mut c = [0usize; 2];
        for _ in 0..10_000 {
            c[r.pick_weighted(&[9.0, 1.0])] += 1;
        }
        assert!(c[0] > 8_000 && c[1] > 500);
    }
}
