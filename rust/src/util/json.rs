//! Minimal JSON parser/serializer (no serde in the offline environment).
//!
//! Used for the AOT artifact manifest written by `python/compile/aot.py`,
//! checkpoint manifests, and human-readable metric dumps. Supports the full
//! JSON value grammar; numbers are f64 (adequate for manifests).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Codec(format!("trailing JSON at byte {}", p.pos)));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer content (number with no fraction).
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    /// Array content if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object content if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Bool content if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Codec(format!("JSON parse error at byte {}: {}", self.pos, msg))
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected token")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x\ny"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"o":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn escapes_round_trip() {
        let j = Json::Str("line\n\"quote\"\t\\".into());
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é中""#).unwrap();
        assert_eq!(j.as_str(), Some("é中"));
        // Raw multibyte UTF-8 also survives.
        let j2 = Json::parse("\"é中\"").unwrap();
        assert_eq!(j2.as_str(), Some("é中"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
