//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! Every `cargo bench` target uses this: timed closures with warmup,
//! per-iteration latency histograms, and aligned table output so each
//! bench prints the rows of the experiment it reproduces (DESIGN.md §7).

use super::histogram::{fmt_ns, Histogram};

/// Result of one measured case.
#[derive(Debug, Clone)]
pub struct Stats {
    pub label: String,
    pub iters: u64,
    pub total_ns: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
}

impl Stats {
    /// Operations per second.
    pub fn ops_per_sec(&self) -> f64 {
        if self.total_ns == 0 {
            0.0
        } else {
            self.iters as f64 / (self.total_ns as f64 / 1e9)
        }
    }

    /// One formatted table row.
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12} {:>14.0}",
            self.label,
            self.iters,
            fmt_ns(self.mean_ns as u64),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.ops_per_sec(),
        )
    }
}

/// Print the standard table header.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>10} {:>12} {:>12} {:>12} {:>14}",
        "case", "iters", "mean", "p50", "p99", "ops/s"
    );
    println!("{}", "-".repeat(110));
}

/// Measure `f` for `iters` iterations after `warmup` unmeasured ones.
/// Records per-iteration latency.
pub fn run<F: FnMut()>(label: &str, warmup: u64, iters: u64, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let hist = Histogram::new();
    let start = std::time::Instant::now();
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        hist.record(t0.elapsed().as_nanos() as u64);
    }
    let total_ns = start.elapsed().as_nanos() as u64;
    let stats = Stats {
        label: label.to_string(),
        iters,
        total_ns,
        mean_ns: hist.mean(),
        p50_ns: hist.quantile(0.5),
        p99_ns: hist.quantile(0.99),
        max_ns: hist.max(),
    };
    println!("{}", stats.row());
    stats
}

/// Measure a closure that does `batch` logical operations per call;
/// reported ops/s is per logical op.
pub fn run_batched<F: FnMut()>(label: &str, warmup: u64, iters: u64, batch: u64, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let hist = Histogram::new();
    let start = std::time::Instant::now();
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        hist.record(t0.elapsed().as_nanos() as u64 / batch.max(1));
    }
    let total_ns = start.elapsed().as_nanos() as u64;
    let stats = Stats {
        label: label.to_string(),
        iters: iters * batch,
        total_ns,
        mean_ns: hist.mean(),
        p50_ns: hist.quantile(0.5),
        p99_ns: hist.quantile(0.99),
        max_ns: hist.max(),
    };
    println!("{}", stats.row());
    stats
}

/// Simple named-value output line for non-latency metrics (ratios, bytes).
pub fn metric(name: &str, value: impl std::fmt::Display) {
    println!("  {name:<58} {value}");
}

/// Machine-readable result line in the repo's one-line JSON shape (the
/// same `{"key":value,...}` form the server `STATS` endpoints emit), so
/// bench sweeps can be diffed/plotted without parsing the human tables.
/// Values are emitted verbatim — pass numbers, or pre-quoted strings.
pub fn json_metric(bench: &str, fields: &[(&str, String)]) {
    let mut line = format!(r#"{{"bench":"{bench}""#);
    for (k, v) in fields {
        line.push_str(&format!(r#","{k}":{v}"#));
    }
    line.push('}');
    println!("{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_measures_something() {
        let s = run("spin", 2, 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(s.iters, 50);
        assert!(s.ops_per_sec() > 0.0);
        assert!(s.p99_ns >= s.p50_ns);
    }

    #[test]
    fn json_metric_is_valid_json() {
        // Shape-check via the in-repo parser.
        let mut line = String::from(r#"{"bench":"contended_push_pull""#);
        for (k, v) in [("stripes", "8"), ("ops_per_sec", "12345.0")] {
            line.push_str(&format!(r#","{k}":{v}"#));
        }
        line.push('}');
        let parsed = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(parsed.get("bench").and_then(|j| j.as_str()), Some("contended_push_pull"));
        assert_eq!(parsed.get("stripes").and_then(|j| j.as_i64()), Some(8));
    }

    #[test]
    fn batched_divides_latency() {
        let s = run_batched("batch", 0, 10, 100, || {
            std::thread::sleep(std::time::Duration::from_micros(100));
        });
        // Per-op latency ~1us, not ~100us.
        assert!(s.mean_ns < 50_000.0, "mean {}", s.mean_ns);
        assert_eq!(s.iters, 1_000);
    }
}
