//! Small self-contained utilities shared by every subsystem.
//!
//! The build environment has no network access to crates.io (the only
//! non-std dependency is the in-workspace `xla` PJRT stub), so the usual
//! ecosystem crates (rand, fxhash, hdrhistogram, proptest, serde, flate2,
//! crc32fast) are reimplemented here and in `codec` in the minimal form
//! WeiPS needs. Each is unit-tested in its own module.

pub mod bench;
pub mod clock;
pub mod hash;
pub mod histogram;
pub mod json;
pub mod lockfree;
pub mod prop;
pub mod rng;
pub mod sys;
pub mod threadpool;

pub use clock::{Clock, ManualClock, SystemClock};
pub use hash::fxhash64;
pub use histogram::Histogram;
pub use lockfree::LockFreeQueue;
pub use rng::Rng;
pub use threadpool::ThreadPool;

/// Current wall-clock time in milliseconds since the unix epoch.
pub fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Current monotonic time in nanoseconds (process-relative).
pub fn mono_ns() -> u64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_nanos() as u64
}
