//! Time abstraction so fault-tolerance and downgrade logic is testable.
//!
//! Production code paths take a `&dyn Clock` (usually [`SystemClock`]);
//! tests and the recovery/downgrade benches drive a [`ManualClock`] so
//! TTL expiry, heartbeat timeouts and smoothing windows are deterministic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Source of milliseconds-since-epoch timestamps.
pub trait Clock: Send + Sync {
    /// Current time in ms.
    fn now_ms(&self) -> u64;
    /// Sleep for `ms` (manual clocks return immediately).
    fn sleep_ms(&self, ms: u64);
}

/// Real wall clock.
#[derive(Debug, Default, Clone)]
pub struct SystemClock;

impl Clock for SystemClock {
    fn now_ms(&self) -> u64 {
        super::now_ms()
    }

    fn sleep_ms(&self, ms: u64) {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

/// Deterministic, manually advanced clock for tests.
#[derive(Debug, Default, Clone)]
pub struct ManualClock {
    t: Arc<AtomicU64>,
}

impl ManualClock {
    /// New clock starting at `t0` ms.
    pub fn new(t0: u64) -> Self {
        ManualClock { t: Arc::new(AtomicU64::new(t0)) }
    }

    /// Advance by `ms`.
    pub fn advance(&self, ms: u64) {
        self.t.fetch_add(ms, Ordering::SeqCst);
    }

    /// Set absolute time.
    pub fn set(&self, ms: u64) {
        self.t.store(ms, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_ms(&self) -> u64 {
        self.t.load(Ordering::SeqCst)
    }

    fn sleep_ms(&self, ms: u64) {
        // Deterministic tests: sleeping just advances the clock.
        self.advance(ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_advances() {
        let c = ManualClock::new(100);
        assert_eq!(c.now_ms(), 100);
        c.advance(50);
        assert_eq!(c.now_ms(), 150);
        c.sleep_ms(10);
        assert_eq!(c.now_ms(), 160);
        c.set(0);
        assert_eq!(c.now_ms(), 0);
    }

    #[test]
    fn manual_clock_shared_across_clones() {
        let c = ManualClock::new(0);
        let c2 = c.clone();
        c.advance(5);
        assert_eq!(c2.now_ms(), 5);
    }

    #[test]
    fn system_clock_monotonic_enough() {
        let c = SystemClock;
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a);
    }
}
