//! Log-bucketed latency histogram (HdrHistogram-lite).
//!
//! Every latency number reported by the monitor and the bench harness flows
//! through this: fixed 2×64 log2 sub-bucketed layout covering 1 ns .. ~17 min
//! with ≤ ~1.6% relative error, constant memory, lock-free recording.

use std::sync::atomic::{AtomicU64, Ordering};

const SUB_BITS: u32 = 5; // 32 sub-buckets per power of two => <= 3.1% width
const SUB: usize = 1 << SUB_BITS;
const BUCKETS: usize = 64 - SUB_BITS as usize; // exponents
const SLOTS: usize = BUCKETS * SUB;

/// Concurrent log-bucketed histogram of u64 values (typically nanoseconds).
#[derive(Debug)]
pub struct Histogram {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    min: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        let mut counts = Vec::with_capacity(SLOTS);
        counts.resize_with(SLOTS, || AtomicU64::new(0));
        Histogram {
            counts,
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
        }
    }

    #[inline]
    fn slot(value: u64) -> usize {
        let v = value.max(1);
        let exp = 63 - v.leading_zeros() as usize; // floor(log2 v)
        if exp < SUB_BITS as usize {
            // Values below 2^SUB_BITS map directly onto the first slots.
            return v as usize;
        }
        let sub = ((v >> (exp - SUB_BITS as usize)) as usize) & (SUB - 1);
        (exp - SUB_BITS as usize) * SUB + sub + SUB // offset past direct range
    }

    #[inline]
    fn slot_mid(slot: usize) -> u64 {
        if slot < SUB {
            return slot as u64;
        }
        let s = slot - SUB;
        let exp = s / SUB + SUB_BITS as usize;
        let sub = (s % SUB) as u64;
        let base = (1u64 << exp) + (sub << (exp - SUB_BITS as usize));
        base + (1u64 << (exp - SUB_BITS as usize)) / 2
    }

    /// Record one value.
    #[inline]
    pub fn record(&self, value: u64) {
        let idx = Self::slot(value).min(SLOTS - 1);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.max.fetch_max(value, Ordering::Relaxed);
        self.min.fetch_min(value, Ordering::Relaxed);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Sum of recorded values (exact).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Cumulative counts at the given ascending `bounds`: `out[i]` is the
    /// number of recorded values whose bucket representative is `<=
    /// bounds[i]` (Prometheus `le` semantics, with the histogram's ≤3.1%
    /// bucket-width error).
    pub fn cumulative(&self, bounds: &[u64]) -> Vec<u64> {
        let mut out = vec![0u64; bounds.len()];
        for (i, c) in self.counts.iter().enumerate() {
            let n = c.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            let v = Self::slot_mid(i);
            for (o, &bound) in out.iter_mut().zip(bounds) {
                if v <= bound {
                    *o += n;
                }
            }
        }
        out
    }

    /// Maximum recorded value (exact).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Minimum recorded value (exact; 0 when empty).
    pub fn min(&self) -> u64 {
        let m = self.min.load(Ordering::Relaxed);
        if m == u64::MAX {
            0
        } else {
            m
        }
    }

    /// Approximate quantile `q` in [0,1] (bucket midpoint).
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= rank {
                return Self::slot_mid(i).min(self.max());
            }
        }
        self.max()
    }

    /// Reset all counters.
    pub fn clear(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.total.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
    }

    /// One-line human summary with ns→µs/ms scaling.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p99={} p999={} max={}",
            self.count(),
            fmt_ns(self.mean() as u64),
            fmt_ns(self.quantile(0.50)),
            fmt_ns(self.quantile(0.99)),
            fmt_ns(self.quantile(0.999)),
            fmt_ns(self.max()),
        )
    }
}

/// Format a nanosecond count with an adaptive unit.
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn small_values_exact() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 3, 3, 4] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.max(), 4);
        assert_eq!(h.min(), 1);
        assert_eq!(h.quantile(0.5), 3);
    }

    #[test]
    fn quantiles_within_relative_error() {
        let h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        for (q, expect) in [(0.5, 50_000.0), (0.9, 90_000.0), (0.99, 99_000.0)] {
            let got = h.quantile(q) as f64;
            assert!(
                (got - expect).abs() / expect < 0.05,
                "q={q} got={got} expect={expect}"
            );
        }
    }

    #[test]
    fn mean_is_exact() {
        let h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.mean(), 20.0);
    }

    #[test]
    fn concurrent_records() {
        let h = std::sync::Arc::new(Histogram::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    h.record(t * 10_000 + i + 1);
                }
            }));
        }
        for hd in handles {
            hd.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn cumulative_bounds_are_monotone_and_cover() {
        let h = Histogram::new();
        for v in [10u64, 1_000, 100_000, 10_000_000] {
            h.record(v);
        }
        let bounds = [100u64, 10_000, 1_000_000, 100_000_000];
        let cum = h.cumulative(&bounds);
        assert_eq!(cum.len(), 4);
        for w in cum.windows(2) {
            assert!(w[0] <= w[1], "cumulative must be monotone: {cum:?}");
        }
        assert_eq!(cum[0], 1, "only 10 fits under 100");
        assert_eq!(cum[3], 4, "everything fits under 1e8");
        assert_eq!(h.sum(), 10 + 1_000 + 100_000 + 10_000_000);
    }

    #[test]
    fn clear_resets() {
        let h = Histogram::new();
        h.record(5);
        h.clear();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_ns(500), "500ns");
        assert_eq!(fmt_ns(1_500), "1.5us");
        assert_eq!(fmt_ns(2_000_000), "2.00ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.00s");
    }
}
