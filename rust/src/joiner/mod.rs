//! Real-time sample joining (§1.2, the Flink-substitute substrate).
//!
//! "Real-time samples joining based on user real-time feedback behaviors
//! and real-time exposure data ... online training modules have to wait
//! for this time window during the sample joining so that valid sample
//! data can be spliced."
//!
//! Two input streams — exposures (impression shown, features attached) and
//! feedbacks (click events referencing an exposure) — joined within a time
//! window W: a click arriving within W of its exposure emits a positive
//! sample immediately; an exposure aging past W without a click emits a
//! negative. This is the standard delayed-feedback join and is the
//! source of the "incomparably avoidable" minutes-level latency the paper
//! cites; the window is configurable so E1 can separate join latency from
//! sync latency.

use std::collections::VecDeque;

use crate::sample::Sample;
use crate::util::hash::FxHashMap;

/// An impression event entering the joiner.
#[derive(Debug, Clone)]
pub struct Exposure {
    pub exposure_id: u64,
    pub ts_ms: u64,
    pub ids: Vec<u64>,
}

/// A positive-feedback (click) event.
#[derive(Debug, Clone, Copy)]
pub struct Feedback {
    pub exposure_id: u64,
    pub ts_ms: u64,
}

/// Joiner statistics.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct JoinerStats {
    pub exposures: u64,
    pub feedbacks: u64,
    pub joined_positive: u64,
    pub expired_negative: u64,
    /// Feedback that referenced an unknown / already-emitted exposure.
    pub orphan_feedback: u64,
}

/// Windowed exposure × feedback joiner.
pub struct Joiner {
    window_ms: u64,
    pending: FxHashMap<u64, Exposure>,
    /// Expiry queue (exposure_id, ts) in arrival order.
    order: VecDeque<(u64, u64)>,
    pub stats: JoinerStats,
}

impl Joiner {
    /// Joiner with window `window_ms`.
    pub fn new(window_ms: u64) -> Joiner {
        Joiner {
            window_ms,
            pending: FxHashMap::default(),
            order: VecDeque::new(),
            stats: JoinerStats::default(),
        }
    }

    /// Exposures currently waiting for feedback.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Feed an exposure.
    pub fn on_exposure(&mut self, e: Exposure) {
        self.stats.exposures += 1;
        self.order.push_back((e.exposure_id, e.ts_ms));
        self.pending.insert(e.exposure_id, e);
    }

    /// Feed a feedback; returns the joined positive sample when it matches
    /// a pending exposure within the window.
    pub fn on_feedback(&mut self, f: Feedback) -> Option<Sample> {
        self.stats.feedbacks += 1;
        match self.pending.remove(&f.exposure_id) {
            Some(e) if f.ts_ms.saturating_sub(e.ts_ms) <= self.window_ms => {
                self.stats.joined_positive += 1;
                Some(Sample { ts_ms: e.ts_ms, ids: e.ids, label: 1.0 })
            }
            Some(e) => {
                // Feedback after the window: by the paper's trade-off the
                // exposure already aged out as a negative; treat as orphan.
                self.stats.orphan_feedback += 1;
                let _ = e;
                None
            }
            None => {
                self.stats.orphan_feedback += 1;
                None
            }
        }
    }

    /// Advance time: expire exposures older than the window into negative
    /// samples (label 0).
    pub fn advance(&mut self, now_ms: u64) -> Vec<Sample> {
        let mut out = Vec::new();
        while let Some(&(id, ts)) = self.order.front() {
            if now_ms.saturating_sub(ts) <= self.window_ms {
                break;
            }
            self.order.pop_front();
            if let Some(e) = self.pending.remove(&id) {
                self.stats.expired_negative += 1;
                out.push(Sample { ts_ms: e.ts_ms, ids: e.ids, label: 0.0 });
            }
            // else: already joined positive.
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exposure(id: u64, ts: u64) -> Exposure {
        Exposure { exposure_id: id, ts_ms: ts, ids: vec![id * 10, id * 10 + 1] }
    }

    #[test]
    fn click_within_window_joins_positive() {
        let mut j = Joiner::new(1_000);
        j.on_exposure(exposure(1, 100));
        let s = j.on_feedback(Feedback { exposure_id: 1, ts_ms: 600 }).unwrap();
        assert_eq!(s.label, 1.0);
        assert_eq!(s.ids, vec![10, 11]);
        assert_eq!(s.ts_ms, 100);
        assert_eq!(j.pending(), 0);
        // Expiry later emits nothing for it.
        assert!(j.advance(10_000).is_empty());
        assert_eq!(j.stats.joined_positive, 1);
    }

    #[test]
    fn no_click_expires_negative() {
        let mut j = Joiner::new(1_000);
        j.on_exposure(exposure(1, 100));
        j.on_exposure(exposure(2, 500));
        assert!(j.advance(1_000).is_empty()); // neither aged out yet
        let neg = j.advance(1_200);
        assert_eq!(neg.len(), 1);
        assert_eq!(neg[0].label, 0.0);
        assert_eq!(j.pending(), 1);
        let neg2 = j.advance(2_000);
        assert_eq!(neg2.len(), 1);
        assert_eq!(j.stats.expired_negative, 2);
    }

    #[test]
    fn late_click_is_orphan() {
        let mut j = Joiner::new(1_000);
        j.on_exposure(exposure(1, 0));
        // Click arrives after the window but before expiry sweep.
        assert!(j.on_feedback(Feedback { exposure_id: 1, ts_ms: 5_000 }).is_none());
        assert_eq!(j.stats.orphan_feedback, 1);
        // Unknown exposure id.
        assert!(j.on_feedback(Feedback { exposure_id: 99, ts_ms: 10 }).is_none());
        assert_eq!(j.stats.orphan_feedback, 2);
    }

    #[test]
    fn duplicate_feedback_joins_once() {
        let mut j = Joiner::new(1_000);
        j.on_exposure(exposure(1, 0));
        assert!(j.on_feedback(Feedback { exposure_id: 1, ts_ms: 100 }).is_some());
        assert!(j.on_feedback(Feedback { exposure_id: 1, ts_ms: 150 }).is_none());
        assert_eq!(j.stats.joined_positive, 1);
    }

    #[test]
    fn mixed_stream_conserves_samples() {
        // Every exposure becomes exactly one sample (positive or negative).
        let mut j = Joiner::new(500);
        let mut emitted = 0;
        for i in 0..100u64 {
            j.on_exposure(exposure(i, i * 10));
            if i % 3 == 0 {
                if j.on_feedback(Feedback { exposure_id: i, ts_ms: i * 10 + 50 }).is_some() {
                    emitted += 1;
                }
            }
            emitted += j.advance(i * 10).len();
        }
        emitted += j.advance(u64::MAX / 2).len();
        assert_eq!(emitted, 100);
        assert_eq!(j.pending(), 0);
        assert_eq!(
            j.stats.joined_positive + j.stats.expired_negative,
            100
        );
    }
}
