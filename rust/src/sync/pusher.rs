//! Pusher (§4.1.3): serialize + compress gathered batches and append them
//! to the external queue partition mapped from this master shard's id.
//!
//! "We combine the concept of fragmentation of the external queue with the
//! fragmentation mechanism of the Parameter Server ... performing the
//! partition mapping according to the server-id before sending."

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::codec::{maybe_compress_into, Encode, LzState, Writer};
use crate::proto::SyncBatch;
use crate::queue::log::SyncLog;
use crate::sync::router::partition_of_shard;
use crate::Result;

/// Bandwidth accounting (E1/E2).
#[derive(Debug, Default)]
pub struct PusherStats {
    pub batches: AtomicU64,
    pub bytes_raw: AtomicU64,
    pub bytes_on_wire: AtomicU64,
}

impl PusherStats {
    /// Compression ratio achieved (1.0 = no win).
    pub fn compression_ratio(&self) -> f64 {
        let raw = self.bytes_raw.load(Ordering::Relaxed) as f64;
        let wire = self.bytes_on_wire.load(Ordering::Relaxed) as f64;
        if wire == 0.0 {
            1.0
        } else {
            raw / wire
        }
    }
}

/// Reusable serialize + compress buffers: the encode target, the LZ hash
/// tables and the wire envelope all persist across pushes, so a
/// steady-state push allocates only the owned payload the queue keeps.
struct PushScratch {
    raw: Writer,
    wire: Vec<u8>,
    lz: LzState,
}

/// Pushes one master shard's batches into its queue partition.
pub struct Pusher {
    log: Arc<dyn SyncLog>,
    partition: u32,
    /// Compress payloads before queueing (§4.1.3). Deflate costs ~1 ms per
    /// 400 KiB batch on this testbed — a latency/bandwidth knob; set
    /// WEIPS_SYNC_COMPRESS=0 for latency-critical deployments
    /// (EXPERIMENTS.md §Perf ablation).
    compress: bool,
    scratch: Mutex<PushScratch>,
    pub stats: PusherStats,
}

impl Pusher {
    /// Pusher for `master_shard` onto `log`.
    pub fn new(log: Arc<dyn SyncLog>, master_shard: u32) -> Pusher {
        let partition = partition_of_shard(master_shard, log.partition_count() as u32);
        let compress = std::env::var("WEIPS_SYNC_COMPRESS").map(|v| v != "0").unwrap_or(true);
        Pusher {
            log,
            partition,
            compress,
            scratch: Mutex::new(PushScratch {
                raw: Writer::new(),
                wire: Vec::new(),
                lz: LzState::new(),
            }),
            stats: PusherStats::default(),
        }
    }

    /// The partition this pusher appends to.
    pub fn partition(&self) -> u32 {
        self.partition
    }

    /// Serialize, compress and enqueue one batch; returns its offset.
    ///
    /// Sparse batches go to this shard's mapped partition; dense-table
    /// snapshots are broadcast to *every* partition — each slave shard
    /// subscribes to a partition subset but all of them serve the dense
    /// tower, so a single-partition dense record would never reach some
    /// shards.
    pub fn push(&self, batch: &SyncBatch) -> Result<u64> {
        // Update-journey trace: serialize + compress + append is the
        // `queue_append` stage for a sampled batch.
        let trace_start = crate::trace::sampled(batch.seq).then(crate::util::mono_ns);
        // Serialize + compress in the pooled scratch buffers; only the
        // final owned payload handed to the queue is allocated.
        let mut s = self.scratch.lock().unwrap();
        let PushScratch { raw, wire, lz } = &mut *s;
        raw.clear();
        batch.encode(raw);
        let raw_len = raw.len();
        if self.compress {
            maybe_compress_into(raw.as_bytes(), wire, lz);
        } else {
            // Stored-mode envelope (decompress() still decodes it).
            wire.clear();
            wire.push(0); // CompressMode::None
            wire.extend_from_slice(raw.as_bytes());
        }
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_raw.fetch_add(raw_len as u64, Ordering::Relaxed);
        let result = if batch.dense.is_empty() {
            self.stats.bytes_on_wire.fetch_add(wire.len() as u64, Ordering::Relaxed);
            self.log.append(self.partition, batch.created_ms, wire.clone())
        } else {
            let mut last = Ok(0);
            for p in 0..self.log.partition_count() as u32 {
                self.stats.bytes_on_wire.fetch_add(wire.len() as u64, Ordering::Relaxed);
                last = self.log.append(p, batch.created_ms, wire.clone());
                if last.is_err() {
                    break;
                }
            }
            last
        };
        if let (Some(t0), Ok(_)) = (trace_start, &result) {
            crate::trace::record_stage(
                crate::trace::trace_id(&batch.model, &batch.table, batch.shard, batch.seq),
                "queue_append",
                "master",
                format!("partition={}", self.partition),
                t0,
                crate::util::mono_ns().saturating_sub(t0),
                batch.created_ms,
                batch.seq,
                batch.shard,
            );
        }
        result
    }

    /// Push a set of batches; returns the last offset written.
    pub fn push_all(&self, batches: &[SyncBatch]) -> Result<Option<u64>> {
        let mut last = None;
        for b in batches {
            last = Some(self.push(b)?);
        }
        Ok(last)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{decompress, Decode};
    use crate::proto::{SyncEntry, SyncOp};
    use crate::queue::Queue;

    fn batch(shard: u32, n: usize) -> SyncBatch {
        SyncBatch {
            model: "ctr".into(),
            table: "w".into(),
            shard,
            seq: 1,
            created_ms: 42,
            entries: (0..n as u64)
                .map(|id| SyncEntry { id, op: SyncOp::Upsert(vec![0.1, 0.2, 0.3]) })
                .collect(),
            dense: vec![],
        }
    }

    #[test]
    fn push_routes_to_mapped_partition() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("sync", 4).unwrap();
        let p2 = Pusher::new(topic.clone(), 2);
        let p6 = Pusher::new(topic.clone(), 6); // 6 % 4 = 2
        assert_eq!(p2.partition(), 2);
        assert_eq!(p6.partition(), 2);
        p2.push(&batch(2, 3)).unwrap();
        p6.push(&batch(6, 3)).unwrap();
        assert_eq!(topic.partition(2).unwrap().latest_offset(), 2);
        assert_eq!(topic.partition(0).unwrap().latest_offset(), 0);
    }

    #[test]
    fn wire_payload_round_trips() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("sync", 2).unwrap();
        let pusher = Pusher::new(topic.clone(), 1);
        let b = batch(1, 100);
        let off = pusher.push(&b).unwrap();
        let recs = topic
            .partition(1)
            .unwrap()
            .fetch(off, 1, std::time::Duration::ZERO)
            .unwrap();
        let raw = decompress(&recs[0].payload).unwrap();
        let back = SyncBatch::from_bytes(&raw).unwrap();
        assert_eq!(back, b);
    }

    #[test]
    fn compression_helps_on_repetitive_batches() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("sync", 1).unwrap();
        let pusher = Pusher::new(topic, 0);
        pusher.push(&batch(0, 2_000)).unwrap();
        assert!(
            pusher.stats.compression_ratio() > 1.5,
            "ratio {}",
            pusher.stats.compression_ratio()
        );
    }

    #[test]
    fn push_all_returns_last_offset() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("sync", 1).unwrap();
        let pusher = Pusher::new(topic, 0);
        assert_eq!(pusher.push_all(&[]).unwrap(), None);
        let last = pusher.push_all(&[batch(0, 1), batch(0, 2), batch(0, 3)]).unwrap();
        assert_eq!(last, Some(2));
        assert_eq!(pusher.stats.batches.load(Ordering::Relaxed), 3);
    }
}
