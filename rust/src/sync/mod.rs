//! Streaming synchronization (§4.1): the paper's core mechanism.
//!
//! ```text
//!  master push path          sync pipeline                 slave serve path
//!  ───────────────  ┌───────────────────────────────────┐  ───────────────
//!  optimizer apply ─► collector ─► gather ─► pusher ─► queue ─► scatter ─►
//!  (dirty ids)        lock-free    dedup +    serialize  parts   route +
//!                     per-stripe   pooled     compress           pooled
//!                     id queues    snapshot                      apply
//! ```
//!
//! Eventual consistency contract (§4.1d): every upsert carries the id's
//! *full current value* (never a delta), so batches are idempotent and
//! replayable from any checkpoint-recorded offset.

pub mod collector;
pub mod gather;
pub mod pusher;
pub mod router;
pub mod scatter;
pub mod transform;

pub use collector::{Collector, DirtyEvent, DirtyOp};
pub use gather::{Gather, GatherStats};
pub use pusher::{Pusher, PusherStats};
pub use router::Router;
pub use scatter::{Scatter, ScatterStats, ScatterTap};
pub use transform::{EmbeddingOnly, FullRows, ServingWeights, Transform};
