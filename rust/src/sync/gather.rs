//! Gather (§4.1.2): drain the collector, dedup dirty ids, snapshot their
//! current values, and emit sync batches per the configured mode.
//!
//! Three gather frequencies, exactly as the paper enumerates:
//! - **real-time**: flush on every poll that finds events (freshest,
//!   highest bandwidth);
//! - **threshold**: flush when the distinct dirty-id count reaches N;
//! - **period**: flush every P ms.
//!
//! Dedup is the bandwidth lever: the paper measured that "the repetition
//! rate of model parameters updates within 10 seconds reach 90 %", so a
//! windowed gather sends one full-value record per id regardless of how
//! many times it changed (§4.1d's ID-granularity eventual consistency).
//! [`GatherStats`] records raw vs deduped counts — experiment E2.
//!
//! Value snapshots go through the master's lock-striped tables: the
//! striped collector hands this worker events **already grouped by
//! stripe**, the dedup window is kept per stripe, and the flush passes
//! those groups straight to
//! [`MasterShard::read_rows_for_sync_grouped`] — no flush-time re-hash.
//! With a shared [`ThreadPool`], the per-stripe snapshots run
//! concurrently, each holding only its own stripe's *read* lock inside
//! the task, so a gather flush overlaps optimizer applies on every other
//! stripe *and* parallelizes its own value reads.
//!
//! Determinism: each flushed batch's entries are sorted by id before
//! emission. One entry exists per id (the window dedups), so the sort is
//! a total order and the encoded batch bytes are identical for any
//! stripe count and any pool size — the property the sync-pipeline bench
//! asserts, and what keeps replica replay byte-stable.

use std::collections::BTreeMap;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::GatherMode;
use crate::proto::{SyncBatch, SyncEntry, SyncOp};
use crate::server::master::MasterShard;
use crate::sync::collector::{DirtyEvent, DirtyOp};
use crate::util::clock::Clock;
use crate::util::hash::FxHashMap;
use crate::util::ThreadPool;

/// Bandwidth/dedup accounting (E2).
#[derive(Debug, Default)]
pub struct GatherStats {
    /// Raw dirty events drained from the collector.
    pub raw_events: AtomicU64,
    /// Entries actually emitted after windowed dedup.
    pub emitted_entries: AtomicU64,
    /// Batches emitted.
    pub batches: AtomicU64,
    /// Flush polls that found nothing.
    pub empty_polls: AtomicU64,
}

impl GatherStats {
    /// Fraction of raw updates suppressed by dedup (the paper's
    /// repetition rate). 0 when nothing was recorded.
    pub fn repetition_rate(&self) -> f64 {
        let raw = self.raw_events.load(Ordering::Relaxed) as f64;
        let emitted = self.emitted_entries.load(Ordering::Relaxed) as f64;
        if raw == 0.0 {
            0.0
        } else {
            1.0 - emitted / raw
        }
    }
}

/// The gather worker for one master shard. Call [`Gather::poll`] from the
/// shard's sync thread; it returns the batches to hand to the pusher.
pub struct Gather {
    master: Arc<MasterShard>,
    mode: GatherMode,
    clock: Arc<dyn Clock>,
    /// Shared sync pool for parallel per-stripe value snapshots and
    /// window absorbs (`None` = sequential).
    pool: Option<Arc<ThreadPool>>,
    /// Dirty window, stripe-major: `window[s]` maps table -> (id -> latest
    /// op) for stripe `s`. The stripe index matches the collector's (and
    /// therefore the table's) stripes, so the absorb is N independent
    /// hashmap merges — one task per stripe on the shared pool — and the
    /// flush hands groups to the snapshot without re-hashing.
    window: Vec<BTreeMap<u16, FxHashMap<u64, DirtyOp>>>,
    window_distinct: usize,
    last_flush_ms: u64,
    scratch: Vec<Vec<DirtyEvent>>,
    seq: u64,
    /// Shared with the metrics registry (scrape-time samplers hold a
    /// Weak); callers keep reading fields through the `Arc` deref.
    pub stats: Arc<GatherStats>,
}

impl Gather {
    /// New gather worker (sequential snapshots).
    pub fn new(master: Arc<MasterShard>, mode: GatherMode, clock: Arc<dyn Clock>) -> Gather {
        Self::with_pool(master, mode, clock, None)
    }

    /// New gather worker snapshotting stripes on `pool` (typically the
    /// cluster's shared sync pool).
    pub fn with_pool(
        master: Arc<MasterShard>,
        mode: GatherMode,
        clock: Arc<dyn Clock>,
        pool: Option<Arc<ThreadPool>>,
    ) -> Gather {
        let now = clock.now_ms();
        let stats = Arc::new(GatherStats::default());
        // Per-shard sync-pipeline occupancy on /metrics. Weak-held: a
        // rebuilt gather (e.g. after resharding) replaces its series.
        {
            let labels =
                [("role", "master".to_string()), ("shard", master.shard_id.to_string())];
            let counters: [(&'static str, fn(&GatherStats) -> &AtomicU64); 4] = [
                ("weips_gather_raw_events_total", |s| &s.raw_events),
                ("weips_gather_emitted_entries_total", |s| &s.emitted_entries),
                ("weips_gather_batches_total", |s| &s.batches),
                ("weips_gather_empty_polls_total", |s| &s.empty_polls),
            ];
            for (name, get) in counters {
                let weak = Arc::downgrade(&stats);
                crate::metrics::register_fn(
                    name,
                    &labels,
                    Box::new(move || {
                        weak.upgrade().map(|s| get(&s).load(Ordering::Relaxed) as f64)
                    }),
                );
            }
        }
        Gather {
            master,
            mode,
            clock,
            pool,
            window: Vec::new(),
            window_distinct: 0,
            last_flush_ms: now,
            scratch: Vec::new(),
            seq: 0,
            stats,
        }
    }

    /// Events an absorb must carry before it fans out over the pool: per
    /// stripe the merge is a few ns per event, so tiny drains are cheaper
    /// inline than a pool round-trip.
    const PARALLEL_ABSORB_MIN: usize = 1024;

    /// Drain newly collected events into the dedup window. The collector
    /// hands events already grouped by stripe and the window is
    /// stripe-major, so each stripe's merge is independent: with the
    /// shared pool attached and a large enough drain, the absorb — the
    /// last serial stage of a flush — runs as one task per busy stripe.
    fn absorb(&mut self) {
        for stripe in &mut self.scratch {
            stripe.clear();
        }
        let collector = self.master.collector();
        let drained = collector.drain_grouped(&mut self.scratch);
        if drained == 0 {
            return;
        }
        let stripes = collector.stripe_count();
        if self.window.len() != stripes {
            // First absorb (or re-striped collector): size the window.
            self.window.resize_with(stripes, BTreeMap::new);
        }
        self.stats.raw_events.fetch_add(drained as u64, Ordering::Relaxed);
        // Last op wins within the window (delete after update = delete;
        // update after delete = update with the new full value). Ids hash
        // to exactly one stripe, so per-stripe maps dedup exactly like a
        // single map — and merge order across stripes cannot matter.
        let absorb_stripe = |win: &mut BTreeMap<u16, FxHashMap<u64, DirtyOp>>,
                             events: &[DirtyEvent],
                             added: &mut usize| {
            for ev in events {
                if win.entry(ev.table).or_default().insert(ev.id, ev.op).is_none() {
                    *added += 1;
                }
            }
        };
        let mut added = vec![0usize; stripes];
        let busy = self.scratch.iter().filter(|e| !e.is_empty()).count();
        match &self.pool {
            Some(pool) if busy > 1 && drained >= Self::PARALLEL_ABSORB_MIN => {
                let absorb_stripe = &absorb_stripe;
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = self
                    .window
                    .iter_mut()
                    .zip(&self.scratch)
                    .zip(added.iter_mut())
                    .filter(|((_, events), _)| !events.is_empty())
                    .map(|((win, events), slot)| {
                        Box::new(move || absorb_stripe(win, events, slot))
                            as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_borrowed(tasks);
            }
            _ => {
                for ((win, events), slot) in
                    self.window.iter_mut().zip(&self.scratch).zip(added.iter_mut())
                {
                    if !events.is_empty() {
                        absorb_stripe(win, events, slot);
                    }
                }
            }
        }
        self.window_distinct += added.iter().sum::<usize>();
    }

    fn should_flush(&self, now: u64) -> bool {
        if self.window_distinct == 0 {
            return false;
        }
        match self.mode {
            GatherMode::Realtime => true,
            GatherMode::Threshold(n) => self.window_distinct >= n,
            GatherMode::Period(ms) => now.saturating_sub(self.last_flush_ms) >= ms,
        }
    }

    /// Poll once: absorb events and flush if the mode says so. Returns the
    /// emitted batches (possibly empty).
    pub fn poll(&mut self) -> Vec<SyncBatch> {
        let tracing = crate::trace::enabled();
        let absorb_start = if tracing { crate::util::mono_ns() } else { 0 };
        self.absorb();
        let absorb_ns =
            if tracing { crate::util::mono_ns().saturating_sub(absorb_start) } else { 0 };
        let now = self.clock.now_ms();
        let mut out = Vec::new();
        let flush_start = if tracing { crate::util::mono_ns() } else { 0 };
        if self.should_flush(now) {
            out = self.flush(now);
        } else {
            self.stats.empty_polls.fetch_add(1, Ordering::Relaxed);
        }
        // Dense tables piggyback on any flush tick in period/threshold
        // mode and on every poll in realtime mode. Only the dense-owner
        // shard (0) emits them — other shards' dense copies are never
        // pushed to and would overwrite the trained state out of order.
        if self.master.shard_id == 0
            && (!out.is_empty() || matches!(self.mode, GatherMode::Realtime))
        {
            for (_, name, values) in self.master.dense_changed_since_sync() {
                self.seq += 1;
                out.push(SyncBatch {
                    model: self.master.spec.name.clone(),
                    table: name,
                    shard: self.master.shard_id,
                    seq: self.seq,
                    created_ms: now,
                    entries: Vec::new(),
                    dense: values,
                });
            }
        }
        if tracing {
            let flush_ns = crate::util::mono_ns().saturating_sub(flush_start);
            self.record_spans(&out, absorb_start, absorb_ns, flush_start, flush_ns);
        }
        out
    }

    /// Force a flush regardless of mode (used at shutdown / tests).
    pub fn flush_now(&mut self) -> Vec<SyncBatch> {
        let tracing = crate::trace::enabled();
        let absorb_start = if tracing { crate::util::mono_ns() } else { 0 };
        self.absorb();
        let absorb_ns =
            if tracing { crate::util::mono_ns().saturating_sub(absorb_start) } else { 0 };
        let now = self.clock.now_ms();
        let flush_start = if tracing { crate::util::mono_ns() } else { 0 };
        let mut out = self.flush(now);
        if self.master.shard_id != 0 {
            if tracing {
                let flush_ns = crate::util::mono_ns().saturating_sub(flush_start);
                self.record_spans(&out, absorb_start, absorb_ns, flush_start, flush_ns);
            }
            return out;
        }
        for (_, name, values) in self.master.dense_changed_since_sync() {
            self.seq += 1;
            out.push(SyncBatch {
                model: self.master.spec.name.clone(),
                table: name,
                shard: self.master.shard_id,
                seq: self.seq,
                created_ms: now,
                entries: Vec::new(),
                dense: values,
            });
        }
        if tracing {
            let flush_ns = crate::util::mono_ns().saturating_sub(flush_start);
            self.record_spans(&out, absorb_start, absorb_ns, flush_start, flush_ns);
        }
        out
    }

    /// Record the master-side stages of the update journey for every
    /// sampled batch this poll emitted. A batch is a deduped *window* of
    /// pushes, so the window-level stage timings (push apply since the
    /// last sampled flush, this poll's collector drain and flush) are
    /// attributed to each sampled batch of the flush.
    fn record_spans(
        &self,
        batches: &[SyncBatch],
        absorb_start: u64,
        absorb_ns: u64,
        flush_start: u64,
        flush_ns: u64,
    ) {
        let mut apply_ns = None;
        for b in batches {
            if !crate::trace::sampled(b.seq) {
                continue;
            }
            // Drain the master's apply accumulator once per poll, and only
            // when something is sampled — otherwise it keeps accumulating
            // toward the next sampled flush of this window.
            let apply = *apply_ns.get_or_insert_with(|| self.master.take_push_apply_ns());
            let id = crate::trace::trace_id(&b.model, &b.table, b.shard, b.seq);
            let detail = format!("shard={}", b.shard);
            if apply > 0 {
                crate::trace::record_stage(
                    id,
                    "push_apply",
                    "master",
                    detail.clone(),
                    absorb_start.saturating_sub(apply),
                    apply,
                    b.created_ms,
                    b.seq,
                    b.shard,
                );
            }
            crate::trace::record_stage(
                id,
                "collector_drain",
                "master",
                detail.clone(),
                absorb_start,
                absorb_ns,
                b.created_ms,
                b.seq,
                b.shard,
            );
            crate::trace::record_stage(
                id,
                "gather_emit",
                "master",
                detail,
                flush_start,
                flush_ns,
                b.created_ms,
                b.seq,
                b.shard,
            );
        }
    }

    fn flush(&mut self, now: u64) -> Vec<SyncBatch> {
        let mut batches = Vec::new();
        let window = std::mem::take(&mut self.window);
        self.window_distinct = 0;
        self.last_flush_ms = now;
        // Tables present anywhere in the window, in ascending index order
        // (deterministic batch order regardless of stripe layout).
        let tables: BTreeSet<u16> =
            window.iter().flat_map(|w| w.keys().copied()).collect();
        for table_idx in tables {
            let table_name = self.master.spec.sparse[table_idx as usize].name.clone();
            let mut entries = Vec::new();
            let mut upsert_groups: Vec<Vec<u64>> = Vec::with_capacity(window.len());
            for stripe_window in &window {
                let mut group = Vec::new();
                if let Some(stripe) = stripe_window.get(&table_idx) {
                    for (id, op) in stripe {
                        match op {
                            DirtyOp::Update => group.push(*id),
                            DirtyOp::Delete => {
                                entries.push(SyncEntry { id: *id, op: SyncOp::Delete })
                            }
                        }
                    }
                }
                upsert_groups.push(group);
            }
            // Snapshot current full values (not increments): replay-safe.
            // The groups are already the table's lock stripes, so each
            // stripe takes its read lock once — in parallel on the shared
            // pool when one is attached — concurrent with pushes on every
            // other stripe.
            let snapshots = self.master.read_rows_for_sync_grouped(
                table_idx,
                &upsert_groups,
                self.pool.as_deref(),
            );
            for (id, row) in snapshots.into_iter().flatten() {
                match row {
                    Some(values) => entries.push(SyncEntry { id, op: SyncOp::Upsert(values) }),
                    // Row vanished between update and flush (expired):
                    // propagate as delete.
                    None => entries.push(SyncEntry { id, op: SyncOp::Delete }),
                }
            }
            if entries.is_empty() {
                continue;
            }
            // One entry per id (windowed dedup), so sorting by id is a
            // total order: batch bytes are identical for any stripe count
            // or pool size.
            entries.sort_unstable_by_key(|e| e.id);
            self.stats
                .emitted_entries
                .fetch_add(entries.len() as u64, Ordering::Relaxed);
            self.stats.batches.fetch_add(1, Ordering::Relaxed);
            self.seq += 1;
            batches.push(SyncBatch {
                model: self.master.spec.name.clone(),
                table: table_name,
                shard: self.master.shard_id,
                seq: self.seq,
                created_ms: now,
                entries,
                dense: Vec::new(),
            });
        }
        batches
    }

    /// Distinct ids currently pending in the window.
    pub fn pending_distinct(&self) -> usize {
        self.window_distinct
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelKind, ModelSpec};
    use crate::proto::SparsePush;
    use crate::runtime::ModelConfig;
    use crate::util::clock::ManualClock;

    fn master() -> (Arc<MasterShard>, ManualClock) {
        let cfg = ModelConfig {
            batch_train: 8,
            batch_predict: 2,
            fields: 4,
            dim: 2,
            hidden: 8,
            ftrl_block_rows: 64,
            ftrl_alpha: 0.05,
            ftrl_beta: 1.0,
            ftrl_l1: 1.0,
            ftrl_l2: 1.0,
        };
        let spec = ModelSpec::derive("ctr", ModelKind::Fm, &cfg);
        let clock = ManualClock::new(0);
        (
            Arc::new(MasterShard::new(0, spec, None, 1, Arc::new(clock.clone())).unwrap()),
            clock,
        )
    }

    fn push(m: &MasterShard, ids: Vec<u64>) {
        let grads = vec![2.0; ids.len()];
        m.sparse_push(&SparsePush { model: "ctr".into(), table: "w".into(), ids, grads })
            .unwrap();
    }

    #[test]
    fn realtime_flushes_every_poll() {
        let (m, clock) = master();
        let mut g = Gather::new(m.clone(), GatherMode::Realtime, Arc::new(clock.clone()));
        let _ = g.poll(); // initial dense sync
        push(&m, vec![1, 2]);
        let batches = g.poll();
        let sparse: Vec<&SyncBatch> = batches.iter().filter(|b| b.table == "w").collect();
        assert_eq!(sparse.len(), 1);
        assert_eq!(sparse[0].entries.len(), 2);
        // Values are full rows (z, n, w).
        for e in &sparse[0].entries {
            match &e.op {
                SyncOp::Upsert(v) => assert_eq!(v.len(), 3),
                _ => panic!("expected upsert"),
            }
        }
        assert!(g.poll().iter().all(|b| b.table != "w")); // drained
    }

    #[test]
    fn threshold_mode_waits_for_n_distinct() {
        let (m, clock) = master();
        let mut g = Gather::new(m.clone(), GatherMode::Threshold(3), Arc::new(clock.clone()));
        push(&m, vec![1]);
        push(&m, vec![1]); // repeat: still 1 distinct
        assert!(g.poll().is_empty());
        assert_eq!(g.pending_distinct(), 1);
        push(&m, vec![2]);
        assert!(g.poll().is_empty());
        push(&m, vec![3]);
        let batches = g.poll();
        assert_eq!(batches.iter().filter(|b| b.table == "w").count(), 1);
        let b = batches.iter().find(|b| b.table == "w").unwrap();
        assert_eq!(b.entries.len(), 3);
        // Dedup accounting: 4 raw events, 3 emitted.
        assert_eq!(g.stats.raw_events.load(Ordering::Relaxed), 4);
        assert_eq!(g.stats.emitted_entries.load(Ordering::Relaxed), 3);
        assert!(g.stats.repetition_rate() > 0.24 && g.stats.repetition_rate() < 0.26);
    }

    #[test]
    fn period_mode_flushes_on_time() {
        let (m, clock) = master();
        let mut g = Gather::new(m.clone(), GatherMode::Period(1_000), Arc::new(clock.clone()));
        push(&m, vec![1, 2, 3]);
        assert!(g.poll().is_empty());
        clock.advance(999);
        assert!(g.poll().is_empty());
        clock.advance(2);
        let batches = g.poll();
        assert_eq!(batches.iter().filter(|b| b.table == "w").count(), 1);
    }

    #[test]
    fn window_dedups_repeated_ids() {
        let (m, clock) = master();
        let mut g = Gather::new(m.clone(), GatherMode::Period(100), Arc::new(clock.clone()));
        for _ in 0..50 {
            push(&m, vec![7]);
        }
        clock.advance(200);
        let batches = g.poll();
        let b = batches.iter().find(|b| b.table == "w").unwrap();
        assert_eq!(b.entries.len(), 1); // one full-value record for id 7
        assert!(g.stats.repetition_rate() > 0.97);
    }

    #[test]
    fn delete_after_update_wins() {
        let (m, clock) = master();
        let mut g = Gather::new(m.clone(), GatherMode::Period(10), Arc::new(clock.clone()));
        push(&m, vec![5]);
        // Manually record a delete (as feature-expire would).
        m.collector().record_deletes(0, &[5]);
        clock.advance(20);
        let batches = g.poll();
        let b = batches.iter().find(|b| b.table == "w").unwrap();
        assert_eq!(b.entries.len(), 1);
        assert!(matches!(b.entries[0].op, SyncOp::Delete));
    }

    #[test]
    fn dense_changes_emit_snapshot_batches() {
        use crate::proto::DenseValues;
        let (m, clock) = master();
        let mut g = Gather::new(m.clone(), GatherMode::Realtime, Arc::new(clock.clone()));
        let first = g.poll(); // initial dense state
        assert!(first.iter().any(|b| b.table == "bias" && !b.dense.is_empty()));
        assert!(g.poll().is_empty());
        m.dense_push(&DenseValues { model: "ctr".into(), table: "bias".into(), values: vec![1.0] })
            .unwrap();
        let after = g.poll();
        assert!(after.iter().any(|b| b.table == "bias"));
    }

    #[test]
    fn flush_now_forces_pending_out() {
        let (m, clock) = master();
        let mut g = Gather::new(m.clone(), GatherMode::Threshold(1_000_000), Arc::new(clock.clone()));
        push(&m, vec![1]);
        assert!(g.poll().is_empty());
        let batches = g.flush_now();
        assert!(batches.iter().any(|b| b.table == "w"));
    }

    #[test]
    fn flush_bytes_identical_across_stripe_counts_and_pools() {
        use crate::codec::Encode;
        let cfg = ModelConfig {
            batch_train: 8,
            batch_predict: 2,
            fields: 4,
            dim: 2,
            hidden: 8,
            ftrl_block_rows: 64,
            ftrl_alpha: 0.05,
            ftrl_beta: 1.0,
            ftrl_l1: 1.0,
            ftrl_l2: 1.0,
        };
        let mut blobs = Vec::new();
        for (stripes, threads) in [(1usize, 0usize), (8, 0), (8, 4), (32, 2)] {
            let spec = ModelSpec::derive("ctr", ModelKind::Fm, &cfg);
            let clock = ManualClock::new(0);
            let m = Arc::new(
                MasterShard::with_stripes(0, spec, None, 1, stripes, Arc::new(clock.clone()))
                    .unwrap(),
            );
            let pool = if threads > 0 {
                Some(Arc::new(crate::util::ThreadPool::new(threads, "gather-det")))
            } else {
                None
            };
            let mut g = Gather::with_pool(
                m.clone(),
                GatherMode::Threshold(1_000_000),
                Arc::new(clock.clone()),
                pool,
            );
            // 3000 raw events: enough to engage the parallel per-stripe
            // absorb (PARALLEL_ABSORB_MIN) in the pooled cases, so the
            // byte-equality below covers it too.
            for i in 0..1500u64 {
                push(&m, vec![i % 97, i]);
            }
            m.collector().record_deletes(0, &[10_000]);
            let bytes: Vec<u8> = g.flush_now().iter().flat_map(|b| b.to_bytes()).collect();
            assert!(!bytes.is_empty());
            blobs.push(bytes);
        }
        for b in &blobs[1..] {
            assert_eq!(b, &blobs[0], "sync-batch bytes differ across stripes/pool sizes");
        }
    }

    #[test]
    fn seq_is_monotonic_per_shard() {
        let (m, clock) = master();
        let mut g = Gather::new(m.clone(), GatherMode::Realtime, Arc::new(clock.clone()));
        let mut last = 0;
        for round in 0..5 {
            push(&m, vec![round]);
            for b in g.poll() {
                assert!(b.seq > last, "seq regressed");
                last = b.seq;
            }
        }
    }
}
