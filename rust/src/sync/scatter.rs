//! Scatter (§4.1.4): consume sync batches from the external queue and
//! apply them to a slave shard, with partition-subset subscription, id
//! routing and model transform.
//!
//! "The slave can specify certain partitions for consuming so that there
//! is no need to read the full Kafka queue while reducing bandwidth
//! pressure." The subset comes from [`partitions_for_slave`]; when the
//! topology is incompatible the scatter falls back to all partitions and
//! the slave filters per id (both paths covered by tests).
//!
//! Applies land in the slave's lock-striped serving tables
//! ([`SlaveShard::apply_batch`] transforms rows outside any lock, then
//! writes one stripe at a time), so a scatter worker streaming upserts
//! never stalls serving pulls on other stripes — the slave-side half of
//! the striped-table design (DESIGN.md §"Lock-striped tables").

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::codec::{decompress_into, Decode};
use crate::proto::SyncBatch;
use crate::queue::log::SyncLog;
use crate::server::slave::SlaveShard;
use crate::sync::router::partitions_for_slave;
use crate::util::clock::Clock;
use crate::util::{Histogram, ThreadPool};
use crate::{Error, Result};

/// Observer of applied sync batches — the coherence channel for any
/// read-side cache layered over the slave (the worker's hot-id cache
/// registers one). Taps run inside [`Scatter::poll`] *after* the run is
/// applied to the serving tables and *before* the poll returns, which is
/// what makes the cache freshness guarantee hard: a pushed update is
/// invalidated out of every tap-subscribed cache within the same sync
/// tick that made it pull-visible — no TTL involved.
pub trait ScatterTap: Send + Sync {
    /// Called once per applying poll with the batches just applied.
    fn on_applied(&self, batches: &[SyncBatch]);
}

/// Scatter-side accounting (E1: sync latency lives here).
#[derive(Debug, Default)]
pub struct ScatterStats {
    pub batches_applied: AtomicU64,
    pub decode_errors: AtomicU64,
    /// Poll rounds that applied at least one batch — `batches_applied /
    /// coalesced_polls` is the mean coalescing depth (lock amortization
    /// factor).
    pub coalesced_polls: AtomicU64,
    /// created_ms -> applied latency distribution (ms).
    pub latency_ms: Histogram,
    /// Records behind log end as of the last poll (gauge input).
    pub lag_records: AtomicU64,
}

/// The scatter worker for one slave replica.
pub struct Scatter {
    log: Arc<dyn SyncLog>,
    slave: Arc<SlaveShard>,
    clock: Arc<dyn Clock>,
    /// Shared sync pool for parallel per-stripe applies
    /// (`None` = sequential).
    pool: Option<Arc<ThreadPool>>,
    /// (partition, next offset) pairs this scatter consumes.
    cursors: Vec<(u32, u64)>,
    /// Reusable decompress target (zero-allocation record decode).
    raw_scratch: Vec<u8>,
    /// Batches decoded by the current poll, applied as one coalesced run.
    pending: Vec<SyncBatch>,
    /// Shared with the metrics registry (scrape-time samplers hold a
    /// Weak); callers keep reading fields through the `Arc` deref.
    pub stats: Arc<ScatterStats>,
    /// Registry histogram behind `weips_push_visible_latency_seconds`
    /// for this replica; records created_ms -> applied latency in ns.
    visible_hist: Arc<Histogram>,
    /// Applied-batch observers (read-side cache invalidation).
    taps: Vec<Arc<dyn ScatterTap>>,
}

impl Scatter {
    /// Build a scatter for `slave`, subscribing to the partition subset
    /// implied by the topology (sequential applies).
    pub fn new(
        log: Arc<dyn SyncLog>,
        slave: Arc<SlaveShard>,
        master_shards: u32,
        slave_shards: u32,
        clock: Arc<dyn Clock>,
    ) -> Scatter {
        Self::with_pool(log, slave, master_shards, slave_shards, clock, None)
    }

    /// [`Self::new`] applying batches over `pool` (typically the cluster's
    /// shared sync pool): each batch's per-stripe transform+upsert work
    /// fans out across pool threads.
    pub fn with_pool(
        log: Arc<dyn SyncLog>,
        slave: Arc<SlaveShard>,
        master_shards: u32,
        slave_shards: u32,
        clock: Arc<dyn Clock>,
        pool: Option<Arc<ThreadPool>>,
    ) -> Scatter {
        let parts = partitions_for_slave(
            master_shards,
            log.partition_count() as u32,
            slave_shards,
            slave.shard_id,
        );
        let cursors = parts.into_iter().map(|p| (p, 0u64)).collect();
        let stats = Arc::new(ScatterStats::default());
        // Per-replica apply/lag series plus the push→visible latency
        // histogram — the fusion pipeline's end-to-end freshness signal.
        let labels = [
            ("role", "slave".to_string()),
            ("shard", slave.shard_id.to_string()),
            ("replica", slave.replica_id.to_string()),
        ];
        {
            let counters: [(&'static str, fn(&ScatterStats) -> &AtomicU64); 3] = [
                ("weips_scatter_batches_applied_total", |s| &s.batches_applied),
                ("weips_scatter_decode_errors_total", |s| &s.decode_errors),
                ("weips_scatter_lag_records", |s| &s.lag_records),
            ];
            for (name, get) in counters {
                let weak = Arc::downgrade(&stats);
                crate::metrics::register_fn(
                    name,
                    &labels,
                    Box::new(move || {
                        weak.upgrade().map(|s| get(&s).load(Ordering::Relaxed) as f64)
                    }),
                );
            }
        }
        let visible_hist = crate::metrics::histogram("weips_push_visible_latency_seconds", &labels);
        // Readiness probe: /healthz reports `degraded` when this replica's
        // scatter lag exceeds the configured bound (see
        // `metrics::set_health_bound`). Weak-held like the samplers, so a
        // rebuilt scatter replaces its probe.
        {
            let weak = Arc::downgrade(&stats);
            crate::metrics::register_health(
                "scatter_lag_records",
                format!("shard={} replica={}", slave.shard_id, slave.replica_id),
                Box::new(move || {
                    weak.upgrade().map(|s| s.lag_records.load(Ordering::Relaxed) as f64)
                }),
            );
        }
        Scatter {
            log,
            slave,
            clock,
            pool,
            cursors,
            raw_scratch: Vec::new(),
            pending: Vec::new(),
            stats,
            visible_hist,
            taps: Vec::new(),
        }
    }

    /// Register an applied-batch observer (e.g. a hot-id cache's
    /// invalidation hook). Taps see every batch this scatter applies,
    /// within the applying poll.
    pub fn add_tap(&mut self, tap: Arc<dyn ScatterTap>) {
        self.taps.push(tap);
    }

    /// Partitions this scatter consumes.
    pub fn partitions(&self) -> Vec<u32> {
        self.cursors.iter().map(|(p, _)| *p).collect()
    }

    /// Current offsets (parallel to [`Scatter::partitions`]).
    pub fn offsets(&self) -> Vec<u64> {
        self.cursors.iter().map(|(_, o)| *o).collect()
    }

    /// Current cursor for one partition (None = not subscribed).
    pub fn offset_for(&self, partition: u32) -> Option<u64> {
        self.cursors.iter().find(|(p, _)| *p == partition).map(|(_, o)| *o)
    }

    /// Widen the subscription to **every** partition. A slot-map
    /// rebalance makes the master-shard → partition mapping of an id's
    /// updates dynamic, so the reduced subset is no longer sound; the
    /// slave's per-id filter handles the extra traffic. Existing cursors
    /// keep their offsets; newly added partitions start at the current
    /// log end — call this *before* the routing-epoch cutover, so no
    /// post-cutover record on a new partition can be missed. Idempotent.
    pub fn subscribe_all(&mut self) -> Result<()> {
        for p in 0..self.log.partition_count() as u32 {
            if self.cursors.iter().all(|(q, _)| *q != p) {
                let end = self.log.latest_offset(p)?;
                self.cursors.push((p, end));
            }
        }
        self.cursors.sort_by_key(|(p, _)| *p);
        Ok(())
    }

    /// Seek all cursors (downgrade replay: offsets from the checkpoint
    /// manifest, §4.3.2). `offsets` must be parallel to `partitions()`.
    pub fn seek(&mut self, offsets: &[u64]) -> Result<()> {
        if offsets.len() != self.cursors.len() {
            return Err(Error::State(format!(
                "seek: {} offsets for {} partitions",
                offsets.len(),
                self.cursors.len()
            )));
        }
        for ((_, cur), &o) in self.cursors.iter_mut().zip(offsets) {
            *cur = o;
        }
        Ok(())
    }

    /// Seek every cursor to the current log end (skip history; used after
    /// a full sync bootstrapped from a fresh checkpoint).
    pub fn seek_to_latest(&mut self) -> Result<()> {
        for (p, cur) in self.cursors.iter_mut() {
            *cur = self.log.latest_offset(*p)?;
        }
        Ok(())
    }

    /// Consume and apply everything currently available (waiting up to
    /// `timeout` for the first record per partition). Returns batches
    /// applied.
    ///
    /// Coalesced: the poll first drains every available queue record
    /// across its partitions, decoding into a reusable buffer, then
    /// applies the whole run through
    /// [`SlaveShard::apply_batches_pooled`] — entries grouped per
    /// table × stripe across batches, one stripe-lock acquisition per
    /// busy group for the entire backlog. A scatter catching up after a
    /// stall therefore pays lock traffic proportional to the stripes it
    /// touches, not to the queue depth.
    pub fn poll(&mut self, timeout: Duration) -> Result<usize> {
        let tracing = crate::trace::enabled();
        self.pending.clear();
        for (p, cursor) in self.cursors.iter_mut() {
            loop {
                let records = match self.log.fetch(*p, *cursor, 256, timeout) {
                    Ok(r) => r,
                    Err(Error::OffsetOutOfRange(_)) => {
                        // Retention overtook us: jump to earliest and count
                        // it as a decode gap (full sync should follow).
                        *cursor = self.log.earliest_offset(*p)?;
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                if records.is_empty() {
                    break;
                }
                for rec in &records {
                    *cursor = rec.offset + 1;
                    // `scatter_decode`: fetch payload -> decoded batch.
                    // Whether the record is sampled is only known after
                    // decoding (the seq lives inside), so time every
                    // record while tracing is on.
                    let t0 = if tracing { crate::util::mono_ns() } else { 0 };
                    if decompress_into(&rec.payload, &mut self.raw_scratch).is_err() {
                        self.stats.decode_errors.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    match SyncBatch::from_bytes(&self.raw_scratch) {
                        Ok(b) => {
                            if tracing && crate::trace::sampled(b.seq) {
                                crate::trace::record_stage(
                                    crate::trace::trace_id(&b.model, &b.table, b.shard, b.seq),
                                    "scatter_decode",
                                    "slave",
                                    self.trace_detail(),
                                    t0,
                                    crate::util::mono_ns().saturating_sub(t0),
                                    b.created_ms,
                                    b.seq,
                                    b.shard,
                                );
                            }
                            self.pending.push(b)
                        }
                        Err(_) => {
                            self.stats.decode_errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                if records.len() < 256 {
                    break;
                }
            }
        }
        if self.pending.is_empty() {
            return Ok(0);
        }
        let applied = self.pending.len();
        let apply_start = if tracing { crate::util::mono_ns() } else { 0 };
        let outcome = self.slave.apply_batches_pooled(&self.pending, self.pool.as_deref());
        let apply_ns =
            if tracing { crate::util::mono_ns().saturating_sub(apply_start) } else { 0 };
        // Taps fire after the serving tables hold the new rows and before
        // this poll returns — the one-tick cache-coherence window.
        let tap_start = if tracing { crate::util::mono_ns() } else { 0 };
        for tap in &self.taps {
            tap.on_applied(&self.pending);
        }
        let tap_ns = if tracing { crate::util::mono_ns().saturating_sub(tap_start) } else { 0 };
        let now = self.clock.now_ms();
        for b in &self.pending {
            let lat_ms = now.saturating_sub(b.created_ms);
            self.stats.latency_ms.record(lat_ms);
            self.visible_hist.record(lat_ms.saturating_mul(1_000_000));
            if tracing && crate::trace::sampled(b.seq) {
                // The run-level apply + invalidate timings are attributed
                // to every sampled batch of the coalesced run, and the
                // sampled batch becomes the push→visible histogram's
                // exemplar for this replica.
                let id = crate::trace::trace_id(&b.model, &b.table, b.shard, b.seq);
                crate::trace::record_stage(
                    id,
                    "scatter_apply",
                    "slave",
                    self.trace_detail(),
                    apply_start,
                    apply_ns,
                    b.created_ms,
                    b.seq,
                    b.shard,
                );
                crate::trace::record_stage(
                    id,
                    "cache_invalidate",
                    "slave",
                    self.trace_detail(),
                    tap_start,
                    tap_ns,
                    b.created_ms,
                    b.seq,
                    b.shard,
                );
                crate::metrics::set_exemplar(
                    "weips_push_visible_latency_seconds",
                    &[
                        ("role", "slave".to_string()),
                        ("shard", self.slave.shard_id.to_string()),
                        ("replica", self.slave.replica_id.to_string()),
                    ],
                    id,
                    lat_ms as f64 / 1e3,
                );
            }
        }
        self.pending.clear();
        self.stats.batches_applied.fetch_add(applied as u64, Ordering::Relaxed);
        self.stats.coalesced_polls.fetch_add(1, Ordering::Relaxed);
        self.stats.lag_records.store(self.lag(), Ordering::Relaxed);
        outcome?;
        Ok(applied)
    }

    /// Span-detail locator for this replica's trace spans.
    fn trace_detail(&self) -> String {
        format!("shard={} replica={}", self.slave.shard_id, self.slave.replica_id)
    }

    /// Total lag (records behind log end) across subscribed partitions.
    pub fn lag(&self) -> u64 {
        self.cursors
            .iter()
            .map(|(p, cur)| {
                self.log
                    .latest_offset(*p)
                    .map(|end| end.saturating_sub(*cur))
                    .unwrap_or(0)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Ftrl, FtrlHyper, Optimizer};
    use crate::proto::{SparsePull, SyncEntry, SyncOp};
    use crate::queue::Queue;
    use crate::sync::pusher::Pusher;
    use crate::sync::router::Router;
    use crate::sync::transform::ServingWeights;
    use crate::util::clock::ManualClock;

    fn slave(shard: u32, shards: u32) -> Arc<SlaveShard> {
        let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
        Arc::new(SlaveShard::new(
            shard,
            0,
            "ctr",
            vec![("w".into(), 1)],
            vec![("bias".into(), 1)],
            Arc::new(ServingWeights::new(vec![("w".into(), ftrl, 1)])),
            Router::new(shards),
        ))
    }

    fn batch(shard: u32, ids: &[u64], ts: u64) -> SyncBatch {
        SyncBatch {
            model: "ctr".into(),
            table: "w".into(),
            shard,
            seq: 1,
            created_ms: ts,
            entries: ids
                .iter()
                .map(|&id| SyncEntry { id, op: SyncOp::Upsert(vec![2.0, 1.0, -0.3]) })
                .collect(),
            dense: vec![],
        }
    }

    #[test]
    fn end_to_end_push_scatter_apply() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("sync.ctr", 4).unwrap();
        let clock = Arc::new(ManualClock::new(100));
        // 4 master shards push; 2 slave shards consume subsets.
        let pushers: Vec<Pusher> = (0..4).map(|m| Pusher::new(topic.clone(), m)).collect();
        let s0 = slave(0, 2);
        let s1 = slave(1, 2);
        let mut sc0 = Scatter::new(topic.clone(), s0.clone(), 4, 2, clock.clone());
        let mut sc1 = Scatter::new(topic.clone(), s1.clone(), 4, 2, clock.clone());
        assert_eq!(sc0.partitions(), vec![0, 2]);
        assert_eq!(sc1.partitions(), vec![1, 3]);

        // Each master shard pushes the ids it owns.
        let master_router = Router::new(4);
        let mut per_shard: Vec<Vec<u64>> = vec![Vec::new(); 4];
        for id in 0..400u64 {
            per_shard[master_router.shard_of(id) as usize].push(id);
        }
        clock.advance(50); // sync latency = 50ms
        for (m, ids) in per_shard.iter().enumerate() {
            pushers[m].push(&batch(m as u32, ids, 100)).unwrap();
        }
        let a0 = sc0.poll(Duration::ZERO).unwrap();
        let a1 = sc1.poll(Duration::ZERO).unwrap();
        // Partition-subset subscription: each slave consumes only its two
        // partitions, so the four pushed batches split 2/2 — half the
        // bandwidth each (the §4.1.4 optimization).
        assert_eq!(a0, 2);
        assert_eq!(a1, 2);

        // Every id is served by exactly one slave shard.
        let slave_router = Router::new(2);
        let mut served = 0;
        for id in 0..400u64 {
            let s = if slave_router.shard_of(id) == 0 { &s0 } else { &s1 };
            let v = s
                .sparse_pull(&SparsePull {
                    model: "ctr".into(),
                    table: "w".into(),
                    ids: vec![id],
                    slot: "w".into(),
                })
                .unwrap();
            if v.values[0] != 0.0 {
                served += 1;
            }
        }
        assert_eq!(served, 400);
        assert_eq!(s0.total_rows() + s1.total_rows(), 400);
        // Latency recorded (~50ms).
        assert!(sc0.stats.latency_ms.mean() >= 49.0);
    }

    #[test]
    fn poll_is_incremental_and_lag_tracks() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("s", 1).unwrap();
        let clock = Arc::new(ManualClock::new(0));
        let s = slave(0, 1);
        let mut sc = Scatter::new(topic.clone(), s.clone(), 1, 1, clock.clone());
        let pusher = Pusher::new(topic.clone(), 0);
        pusher.push(&batch(0, &[1], 0)).unwrap();
        assert_eq!(sc.lag(), 1);
        assert_eq!(sc.poll(Duration::ZERO).unwrap(), 1);
        assert_eq!(sc.lag(), 0);
        assert_eq!(sc.poll(Duration::ZERO).unwrap(), 0); // nothing new
        pusher.push(&batch(0, &[2], 0)).unwrap();
        pusher.push(&batch(0, &[3], 0)).unwrap();
        assert_eq!(sc.poll(Duration::ZERO).unwrap(), 2);
        assert_eq!(s.total_rows(), 3);
        // Three batches landed in two applying polls: the second poll
        // coalesced its two queued batches into one apply run.
        assert_eq!(sc.stats.batches_applied.load(Ordering::Relaxed), 3);
        assert_eq!(sc.stats.coalesced_polls.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn subscribe_all_widens_from_log_end() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("sync", 4).unwrap();
        let clock = Arc::new(ManualClock::new(0));
        let s = slave(0, 2);
        let mut sc = Scatter::new(topic.clone(), s.clone(), 4, 2, clock);
        assert_eq!(sc.partitions(), vec![0, 2]); // reduced subset
        // History on an unsubscribed partition that must NOT replay.
        let p1 = Pusher::new(topic.clone(), 1);
        p1.push(&batch(1, &[2], 0)).unwrap();
        sc.subscribe_all().unwrap();
        assert_eq!(sc.partitions(), vec![0, 1, 2, 3]);
        assert_eq!(sc.offset_for(1), Some(1), "new partition must start at log end");
        assert_eq!(sc.offset_for(3), Some(0));
        sc.subscribe_all().unwrap(); // idempotent
        assert_eq!(sc.partitions(), vec![0, 1, 2, 3]);
        assert_eq!(sc.poll(Duration::ZERO).unwrap(), 0);
        // Post-widening records on the new partition are consumed.
        let router = Router::new(2);
        let mine: u64 = (0..100).find(|&i| router.shard_of(i) == 0).unwrap();
        p1.push(&batch(1, &[mine], 0)).unwrap();
        assert_eq!(sc.poll(Duration::ZERO).unwrap(), 1);
        assert_eq!(s.total_rows(), 1);
    }

    #[test]
    fn seek_replays_history() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("s", 1).unwrap();
        let clock = Arc::new(ManualClock::new(0));
        let s = slave(0, 1);
        let mut sc = Scatter::new(topic.clone(), s.clone(), 1, 1, clock.clone());
        let pusher = Pusher::new(topic.clone(), 0);
        for i in 0..5u64 {
            pusher.push(&batch(0, &[i], 0)).unwrap();
        }
        sc.poll(Duration::ZERO).unwrap();
        assert_eq!(s.total_rows(), 5);
        // Roll back: clear and replay from offset 2.
        s.clear();
        sc.seek(&[2]).unwrap();
        assert_eq!(sc.poll(Duration::ZERO).unwrap(), 3);
        assert_eq!(s.total_rows(), 3);
        assert!(sc.seek(&[1, 2]).is_err()); // wrong arity
    }

    #[test]
    fn seek_to_latest_skips_history() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("s", 1).unwrap();
        let clock = Arc::new(ManualClock::new(0));
        let s = slave(0, 1);
        let mut sc = Scatter::new(topic.clone(), s.clone(), 1, 1, clock.clone());
        let pusher = Pusher::new(topic.clone(), 0);
        for i in 0..5u64 {
            pusher.push(&batch(0, &[i], 0)).unwrap();
        }
        sc.seek_to_latest().unwrap();
        assert_eq!(sc.poll(Duration::ZERO).unwrap(), 0);
        pusher.push(&batch(0, &[99], 0)).unwrap();
        assert_eq!(sc.poll(Duration::ZERO).unwrap(), 1);
        assert_eq!(s.total_rows(), 1);
    }

    #[test]
    fn corrupt_records_counted_not_fatal() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("s", 1).unwrap();
        let clock = Arc::new(ManualClock::new(0));
        let s = slave(0, 1);
        let mut sc = Scatter::new(topic.clone(), s.clone(), 1, 1, clock.clone());
        topic.partition(0).unwrap().append(0, vec![0xde, 0xad, 0xbe]);
        let pusher = Pusher::new(topic.clone(), 0);
        pusher.push(&batch(0, &[1], 0)).unwrap();
        assert_eq!(sc.poll(Duration::ZERO).unwrap(), 1);
        assert_eq!(sc.stats.decode_errors.load(Ordering::Relaxed), 1);
        assert_eq!(s.total_rows(), 1);
    }

    #[test]
    fn retention_gap_recovers_to_earliest() {
        let q = Queue::new(600); // tiny retention
        let topic = q.create_topic("s", 1).unwrap();
        let clock = Arc::new(ManualClock::new(0));
        let s = slave(0, 1);
        let mut sc = Scatter::new(topic.clone(), s.clone(), 1, 1, clock.clone());
        let pusher = Pusher::new(topic.clone(), 0);
        for i in 0..100u64 {
            pusher.push(&batch(0, &[i], 0)).unwrap();
        }
        // Cursor 0 was trimmed away; poll must recover, not error.
        let applied = sc.poll(Duration::ZERO).unwrap();
        assert!(applied > 0);
        assert_eq!(sc.lag(), 0);
    }
}
