//! Model transforming: master-format rows → slave-format rows (§4.1.4b).
//!
//! "Real-time updates will face the problem of heterogeneous master-slave
//! data which requires real-time model conversion during the real-time
//! synchronization process." The master stores optimizer state (FTRL z, n
//! + cached w); a ranking slave stores only the serving weight; an
//! embedding-query slave keeps only the factor table. The transform runs
//! on the scatter path, per entry, and may also *screen* data (drop tables
//! the slave type does not serve).

use std::sync::Arc;

use crate::optim::Optimizer;
use crate::{Error, Result};

/// Converts one master row into the slave's serving representation.
/// `None` = this slave screens out the table entirely.
pub trait Transform: Send + Sync {
    /// Serving floats per id for `table`, or `None` to drop the table.
    fn serving_width(&self, table: &str) -> Option<usize>;

    /// Convert a full master row to the serving row.
    fn transform(&self, table: &str, row: &[f32]) -> Result<Option<Vec<f32>>>;
}

/// Extract the optimizer's `w` slot — the standard ranking-slave transform
/// (FTRL `(z, n, w) -> w`, Adam `(m, v, w) -> w`, SGD `w -> w`).
pub struct ServingWeights {
    /// (table name, optimizer, dim) for every table this slave serves.
    tables: Vec<(String, Arc<dyn Optimizer>, usize)>,
}

impl ServingWeights {
    /// Transform serving the given tables.
    pub fn new(tables: Vec<(String, Arc<dyn Optimizer>, usize)>) -> ServingWeights {
        ServingWeights { tables }
    }

    fn lookup(&self, table: &str) -> Option<&(String, Arc<dyn Optimizer>, usize)> {
        self.tables.iter().find(|(n, _, _)| n == table)
    }
}

impl Transform for ServingWeights {
    fn serving_width(&self, table: &str) -> Option<usize> {
        self.lookup(table).map(|(_, _, dim)| *dim)
    }

    fn transform(&self, table: &str, row: &[f32]) -> Result<Option<Vec<f32>>> {
        let Some((_, opt, dim)) = self.lookup(table) else {
            return Ok(None); // screened out
        };
        if row.len() != opt.row_width(*dim) {
            return Err(Error::Codec(format!(
                "transform {table}: row width {} != {}",
                row.len(),
                opt.row_width(*dim)
            )));
        }
        Ok(Some(opt.serving(row, *dim).to_vec()))
    }
}

/// Identity transform: the slave mirrors full master rows (model-evaluation
/// slaves that need optimizer state, or master→master replication).
pub struct FullRows {
    tables: Vec<(String, usize)>,
}

impl FullRows {
    /// Mirror `tables` (name, full row width).
    pub fn new(tables: Vec<(String, usize)>) -> FullRows {
        FullRows { tables }
    }
}

impl Transform for FullRows {
    fn serving_width(&self, table: &str) -> Option<usize> {
        self.tables.iter().find(|(n, _)| n == table).map(|(_, w)| *w)
    }

    fn transform(&self, table: &str, row: &[f32]) -> Result<Option<Vec<f32>>> {
        match self.serving_width(table) {
            Some(w) if row.len() == w => Ok(Some(row.to_vec())),
            Some(w) => Err(Error::Codec(format!(
                "full-row transform {table}: width {} != {w}",
                row.len()
            ))),
            None => Ok(None),
        }
    }
}

/// Embedding-query slave: keeps only the factor table's serving weights
/// ("some generate features based on the index input by the user", §1.2.1).
pub struct EmbeddingOnly {
    inner: ServingWeights,
    keep: String,
}

impl EmbeddingOnly {
    /// Serve only `keep` (e.g. "v") through the given optimizer layout.
    pub fn new(keep: &str, optimizer: Arc<dyn Optimizer>, dim: usize) -> EmbeddingOnly {
        EmbeddingOnly {
            inner: ServingWeights::new(vec![(keep.to_string(), optimizer, dim)]),
            keep: keep.to_string(),
        }
    }
}

impl Transform for EmbeddingOnly {
    fn serving_width(&self, table: &str) -> Option<usize> {
        (table == self.keep).then(|| self.inner.serving_width(table)).flatten()
    }

    fn transform(&self, table: &str, row: &[f32]) -> Result<Option<Vec<f32>>> {
        if table != self.keep {
            return Ok(None);
        }
        self.inner.transform(table, row)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Adagrad, Ftrl, FtrlHyper, Sgd};

    fn ftrl() -> Arc<dyn Optimizer> {
        Arc::new(Ftrl::new(FtrlHyper::default()))
    }

    #[test]
    fn serving_weights_extracts_w_slot() {
        let t = ServingWeights::new(vec![
            ("w".into(), ftrl(), 1),
            ("v".into(), ftrl(), 4),
        ]);
        assert_eq!(t.serving_width("w"), Some(1));
        assert_eq!(t.serving_width("v"), Some(4));
        assert_eq!(t.serving_width("junk"), None);

        // FTRL row (z, n, w) at dim 1: w = row[2].
        let out = t.transform("w", &[5.0, 2.0, -0.7]).unwrap().unwrap();
        assert_eq!(out, vec![-0.7]);
        // dim 4: w slot = last 4 of 12.
        let row: Vec<f32> = (0..12).map(|i| i as f32).collect();
        assert_eq!(t.transform("v", &row).unwrap().unwrap(), vec![8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    fn serving_weights_screens_unknown_tables() {
        let t = ServingWeights::new(vec![("w".into(), ftrl(), 1)]);
        assert_eq!(t.transform("other", &[1.0, 2.0, 3.0]).unwrap(), None);
    }

    #[test]
    fn width_mismatch_is_error_not_garbage() {
        let t = ServingWeights::new(vec![("w".into(), ftrl(), 2)]);
        assert!(t.transform("w", &[1.0, 2.0, 3.0]).is_err()); // needs 6
    }

    #[test]
    fn works_across_optimizer_layouts() {
        let t = ServingWeights::new(vec![
            ("sgd_t".into(), Arc::new(Sgd { lr: 0.1 }) as Arc<dyn Optimizer>, 2),
            ("ada_t".into(), Arc::new(Adagrad { lr: 0.1, eps: 1e-8 }) as Arc<dyn Optimizer>, 2),
        ]);
        // SGD row is already just w.
        assert_eq!(t.transform("sgd_t", &[0.1, 0.2]).unwrap().unwrap(), vec![0.1, 0.2]);
        // Adagrad (acc, w): w is the second half.
        assert_eq!(
            t.transform("ada_t", &[9.0, 9.0, 0.3, 0.4]).unwrap().unwrap(),
            vec![0.3, 0.4]
        );
    }

    #[test]
    fn full_rows_mirror() {
        let t = FullRows::new(vec![("w".into(), 3)]);
        assert_eq!(
            t.transform("w", &[1.0, 2.0, 3.0]).unwrap().unwrap(),
            vec![1.0, 2.0, 3.0]
        );
        assert_eq!(t.transform("x", &[1.0]).unwrap(), None);
        assert!(t.transform("w", &[1.0]).is_err());
    }

    #[test]
    fn embedding_only_keeps_one_table() {
        let t = EmbeddingOnly::new("v", ftrl(), 2);
        assert_eq!(t.serving_width("v"), Some(2));
        assert_eq!(t.serving_width("w"), None);
        assert_eq!(t.transform("w", &[1.0, 2.0, 3.0]).unwrap(), None);
        let row = [0.0, 0.0, 1.0, 1.0, 0.5, 0.6]; // z,n,w dim2
        assert_eq!(t.transform("v", &row).unwrap().unwrap(), vec![0.5, 0.6]);
    }
}
