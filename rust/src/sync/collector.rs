//! Collector (§4.1.1): lock-free capture of dirty parameter ids.
//!
//! "After receiving the push request from the client, the model collects
//! the parameters in real-time and then writes them to the internal
//! lock-free cache queue. To save memory space for the sparse model, the
//! data collected at this time only include the collection ids and the
//! operation type. This procedure does not retain the model increment."
//!
//! Exactly that: push handlers (any thread) record `(table, id, op)`
//! triples into lock-free queues; the gather thread drains and dedups.
//! Values are *not* captured here — gather reads the current row state at
//! flush time, which is what makes replay idempotent (§4.1d).
//!
//! The collector is **striped**: one [`LockFreeQueue`] per table lock
//! stripe, keyed by the same [`stripe_of_id`] hash as the parameter
//! tables. Push handlers working different stripes stop contending on a
//! single MPSC tail, and the gather thread receives events already
//! grouped by stripe ([`Collector::drain_grouped`]) — the flush-time
//! re-hash of deduped ids the single-queue design needed is gone, and the
//! per-stripe groups feed straight into the parallel snapshot
//! (`StripedSparseTable::read_rows_grouped`).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::table::stripe_of_id;
use crate::util::LockFreeQueue;

/// What happened to the id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DirtyOp {
    /// Row updated (gather will snapshot its full current value).
    Update,
    /// Row deleted (feature filter eviction).
    Delete,
}

/// One dirty event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DirtyEvent {
    /// Index into the model spec's sparse-table list.
    pub table: u16,
    pub id: u64,
    pub op: DirtyOp,
}

/// Lock-free, stripe-partitioned dirty-id collector for one master shard.
pub struct Collector {
    /// One MPSC queue per table lock stripe.
    queues: Vec<LockFreeQueue<DirtyEvent>>,
    recorded: AtomicU64,
}

impl Default for Collector {
    fn default() -> Collector {
        Collector::new()
    }
}

impl Collector {
    /// Empty collector with the default stripe count.
    pub fn new() -> Collector {
        Collector::with_stripes(crate::table::default_stripe_count())
    }

    /// Empty collector with one queue per table lock stripe (min 1). Must
    /// match the stripe count of the tables feeding it so the groups line
    /// up with the tables' lock stripes (the master shard constructs both
    /// from the same knob).
    pub fn with_stripes(stripes: usize) -> Collector {
        Collector {
            queues: (0..stripes.max(1)).map(|_| LockFreeQueue::new()).collect(),
            recorded: AtomicU64::new(0),
        }
    }

    /// Number of stripe queues.
    pub fn stripe_count(&self) -> usize {
        self.queues.len()
    }

    #[inline]
    fn record(&self, table: u16, ids: &[u64], op: DirtyOp) {
        for &id in ids {
            self.queues[stripe_of_id(id, self.queues.len())]
                .push(DirtyEvent { table, id, op });
        }
        self.recorded.fetch_add(ids.len() as u64, Ordering::Relaxed);
    }

    /// Record updated ids for a table (called from push handlers).
    pub fn record_updates(&self, table: u16, ids: &[u64]) {
        self.record(table, ids, DirtyOp::Update);
    }

    /// Record deleted ids for a table (feature expire).
    pub fn record_deletes(&self, table: u16, ids: &[u64]) {
        self.record(table, ids, DirtyOp::Delete);
    }

    /// Drain all pending events into `out`, stripe by stripe in stripe
    /// order (single consumer: the gather thread). Returns the number
    /// drained.
    pub fn drain(&self, out: &mut Vec<DirtyEvent>) -> usize {
        self.queues.iter().map(|q| q.drain_into(out)).sum()
    }

    /// Drain all pending events grouped by stripe: `out[s]` receives
    /// stripe `s`'s events in arrival order. `out` is resized to the
    /// stripe count; existing contents of its inner vectors are kept
    /// (callers clear between polls to reuse capacity). Returns the
    /// number drained.
    pub fn drain_grouped(&self, out: &mut Vec<Vec<DirtyEvent>>) -> usize {
        out.resize_with(self.queues.len(), Vec::new);
        self.queues
            .iter()
            .zip(out.iter_mut())
            .map(|(q, slot)| q.drain_into(slot))
            .sum()
    }

    /// Events currently queued (approximate).
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Total events ever recorded (the raw update stream size — numerator
    /// of the E2 repetition-rate measurement).
    pub fn total_recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn records_and_drains_everything() {
        let c = Collector::with_stripes(4);
        c.record_updates(0, &[1, 2]);
        c.record_deletes(1, &[3]);
        let mut out = Vec::new();
        assert_eq!(c.drain(&mut out), 3);
        assert_eq!(out.len(), 3);
        assert!(out.contains(&DirtyEvent { table: 0, id: 1, op: DirtyOp::Update }));
        assert!(out.contains(&DirtyEvent { table: 0, id: 2, op: DirtyOp::Update }));
        assert!(out.contains(&DirtyEvent { table: 1, id: 3, op: DirtyOp::Delete }));
        assert_eq!(c.total_recorded(), 3);
        assert_eq!(c.pending(), 0);
    }

    #[test]
    fn drain_grouped_routes_by_stripe_hash() {
        let c = Collector::with_stripes(8);
        let ids: Vec<u64> = (0..200).collect();
        c.record_updates(0, &ids);
        let mut out = Vec::new();
        assert_eq!(c.drain_grouped(&mut out), 200);
        assert_eq!(out.len(), 8);
        for (s, events) in out.iter().enumerate() {
            for ev in events {
                assert_eq!(stripe_of_id(ev.id, 8), s, "id {} in wrong stripe", ev.id);
            }
        }
        // Per-stripe arrival order is preserved (single producer here).
        for events in &out {
            let ids: Vec<u64> = events.iter().map(|e| e.id).collect();
            let mut sorted = ids.clone();
            sorted.sort_unstable();
            assert_eq!(ids, sorted);
        }
        assert_eq!(out.iter().map(Vec::len).sum::<usize>(), 200);
        // Reused buffers accumulate unless cleared by the caller.
        c.record_updates(0, &[7]);
        assert_eq!(c.drain_grouped(&mut out), 1);
        assert_eq!(out.iter().map(Vec::len).sum::<usize>(), 201);
    }

    #[test]
    fn concurrent_pushers_lose_nothing() {
        let c = Arc::new(Collector::with_stripes(8));
        let mut handles = Vec::new();
        for t in 0..4u16 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..5_000u64 {
                    c.record_updates(t, &[i]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut out = Vec::new();
        c.drain(&mut out);
        assert_eq!(out.len(), 20_000);
        assert_eq!(c.total_recorded(), 20_000);
    }
}
