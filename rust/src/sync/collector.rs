//! Collector (§4.1.1): lock-free capture of dirty parameter ids.
//!
//! "After receiving the push request from the client, the model collects
//! the parameters in real-time and then writes them to the internal
//! lock-free cache queue. To save memory space for the sparse model, the
//! data collected at this time only include the collection ids and the
//! operation type. This procedure does not retain the model increment."
//!
//! Exactly that: push handlers (any thread) record `(table, id, op)`
//! triples into a [`LockFreeQueue`]; the gather thread drains and dedups.
//! Values are *not* captured here — gather reads the current row state at
//! flush time, which is what makes replay idempotent (§4.1d). With the
//! lock-striped tables, push handlers on different stripes feed this
//! queue truly concurrently (the queue was always MPSC; the stripes make
//! the producers actually parallel), and the flush-time snapshot re-groups
//! the deduped ids by stripe on the read side.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::LockFreeQueue;

/// What happened to the id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DirtyOp {
    /// Row updated (gather will snapshot its full current value).
    Update,
    /// Row deleted (feature filter eviction).
    Delete,
}

/// One dirty event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DirtyEvent {
    /// Index into the model spec's sparse-table list.
    pub table: u16,
    pub id: u64,
    pub op: DirtyOp,
}

/// Lock-free dirty-id collector for one master shard.
#[derive(Default)]
pub struct Collector {
    queue: LockFreeQueue<DirtyEvent>,
    recorded: AtomicU64,
}

impl Collector {
    /// Empty collector.
    pub fn new() -> Collector {
        Collector { queue: LockFreeQueue::new(), recorded: AtomicU64::new(0) }
    }

    /// Record updated ids for a table (called from push handlers).
    pub fn record_updates(&self, table: u16, ids: &[u64]) {
        for &id in ids {
            self.queue.push(DirtyEvent { table, id, op: DirtyOp::Update });
        }
        self.recorded.fetch_add(ids.len() as u64, Ordering::Relaxed);
    }

    /// Record deleted ids for a table (feature expire).
    pub fn record_deletes(&self, table: u16, ids: &[u64]) {
        for &id in ids {
            self.queue.push(DirtyEvent { table, id, op: DirtyOp::Delete });
        }
        self.recorded.fetch_add(ids.len() as u64, Ordering::Relaxed);
    }

    /// Drain all pending events into `out` (single consumer: the gather
    /// thread). Returns the number drained.
    pub fn drain(&self, out: &mut Vec<DirtyEvent>) -> usize {
        self.queue.drain_into(out)
    }

    /// Events currently queued (approximate).
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Total events ever recorded (the raw update stream size — numerator
    /// of the E2 repetition-rate measurement).
    pub fn total_recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn records_and_drains_in_order() {
        let c = Collector::new();
        c.record_updates(0, &[1, 2]);
        c.record_deletes(1, &[3]);
        let mut out = Vec::new();
        assert_eq!(c.drain(&mut out), 3);
        assert_eq!(
            out,
            vec![
                DirtyEvent { table: 0, id: 1, op: DirtyOp::Update },
                DirtyEvent { table: 0, id: 2, op: DirtyOp::Update },
                DirtyEvent { table: 1, id: 3, op: DirtyOp::Delete },
            ]
        );
        assert_eq!(c.total_recorded(), 3);
        assert_eq!(c.pending(), 0);
    }

    #[test]
    fn concurrent_pushers_lose_nothing() {
        let c = Arc::new(Collector::new());
        let mut handles = Vec::new();
        for t in 0..4u16 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..5_000u64 {
                    c.record_updates(t, &[i]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut out = Vec::new();
        c.drain(&mut out);
        assert_eq!(out.len(), 20_000);
        assert_eq!(c.total_recorded(), 20_000);
    }
}
