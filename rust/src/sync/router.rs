//! Model routing: parameter-id → shard / partition mapping (§4.1.4a).
//!
//! Training traffic and inference traffic want different shard counts
//! ("the resource requirements of the two situations is inconsistent"), so
//! WeiPS lets every cluster pick its own count: ids hash-route onto M
//! master shards, the pusher maps master shards onto P queue partitions,
//! and each slave cluster with S shards routes the *same ids* onto its own
//! S. The router also powers heterogeneous-cluster migration (§4.2.1d:
//! "cluster A has 10 shards to cluster B has 20 shards").
//!
//! When `S` divides `M` and `P == M`, a slave shard only needs the
//! partition subset `{p : p mod S == s}` — the paper's "specify certain
//! partitions for consuming ... reducing bandwidth pressure"; otherwise it
//! falls back to consuming all partitions and filtering by id.

use crate::util::hash::fxhash64;

/// Stateless router over a cluster size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Router {
    shards: u32,
}

impl Router {
    /// Router for a cluster of `shards` (>= 1).
    pub fn new(shards: u32) -> Router {
        assert!(shards >= 1, "cluster needs at least one shard");
        Router { shards }
    }

    /// Shard count.
    pub fn shards(&self) -> u32 {
        self.shards
    }

    /// Owning shard for a parameter id.
    #[inline]
    pub fn shard_of(&self, id: u64) -> u32 {
        (fxhash64(id) % self.shards as u64) as u32
    }

    /// Split `ids` into per-shard buckets; returns `(shard -> (positions,
    /// ids))` so callers can reassemble responses in request order.
    pub fn split_ids(&self, ids: &[u64]) -> Vec<(Vec<usize>, Vec<u64>)> {
        let mut buckets: Vec<(Vec<usize>, Vec<u64>)> =
            (0..self.shards).map(|_| (Vec::new(), Vec::new())).collect();
        for (pos, &id) in ids.iter().enumerate() {
            let s = self.shard_of(id) as usize;
            buckets[s].0.push(pos);
            buckets[s].1.push(id);
        }
        buckets
    }
}

/// Master-shard → queue-partition mapping used by the pusher (§4.1.3:
/// "performing the partition mapping according to the server-id").
#[inline]
pub fn partition_of_shard(master_shard: u32, partitions: u32) -> u32 {
    master_shard % partitions
}

/// The partitions a slave shard must consume, given the master/partition/
/// slave topology. Returns the reduced subset when the modulo structure
/// allows it, else every partition (caller filters by id).
pub fn partitions_for_slave(
    master_shards: u32,
    partitions: u32,
    slave_shards: u32,
    slave_shard: u32,
) -> Vec<u32> {
    debug_assert!(slave_shard < slave_shards);
    if partitions == master_shards && master_shards % slave_shards == 0 {
        // h % M known per partition p (= p since P == M); slave s needs
        // ids with h % S == s, and S | M means h % S == (h % M) % S.
        (0..partitions).filter(|p| p % slave_shards == slave_shard).collect()
    } else {
        (0..partitions).collect()
    }
}

/// True when the reduced-subset optimization applies (used by metrics and
/// the gather-bandwidth bench).
pub fn partition_subset_applies(master_shards: u32, partitions: u32, slave_shards: u32) -> bool {
    partitions == master_shards && master_shards % slave_shards == 0
}

/// Remap plan for migrating a model between clusters of different sizes
/// (§4.2.1d). For each source shard, which destination shards its rows can
/// land on — destination is still decided per id, this is the coarse plan
/// used to parallelize the copy.
pub fn migration_plan(src_shards: u32, dst_shards: u32) -> Vec<Vec<u32>> {
    // Any src shard may contain ids for any dst shard in general; with the
    // fxhash modulo scheme the only exploitable structure is divisibility.
    let mut plan = Vec::with_capacity(src_shards as usize);
    for _src in 0..src_shards {
        if src_shards % dst_shards == 0 {
            // Coarsening (e.g. 20 -> 10): each src maps into exactly one dst
            // only when hashing is aligned, which per-id modulo does not
            // guarantee; keep full fanout for correctness.
            plan.push((0..dst_shards).collect());
        } else {
            plan.push((0..dst_shards).collect());
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PairOf, U64Range, VecOf};

    #[test]
    fn shard_of_is_stable_and_bounded() {
        let r = Router::new(8);
        for id in 0..1000u64 {
            let s = r.shard_of(id);
            assert!(s < 8);
            assert_eq!(s, r.shard_of(id));
        }
    }

    #[test]
    fn split_preserves_positions() {
        let r = Router::new(4);
        let ids = vec![10, 20, 30, 40, 50, 20];
        let buckets = r.split_ids(&ids);
        let mut seen = vec![false; ids.len()];
        for (shard, (positions, bids)) in buckets.iter().enumerate() {
            assert_eq!(positions.len(), bids.len());
            for (pos, id) in positions.iter().zip(bids) {
                assert_eq!(ids[*pos], *id);
                assert_eq!(r.shard_of(*id), shard as u32);
                seen[*pos] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every position routed exactly once");
    }

    #[test]
    fn balance_is_reasonable() {
        let r = Router::new(16);
        let mut counts = vec![0usize; 16];
        for id in 0..160_000u64 {
            counts[r.shard_of(id) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 1_500.0, "count {c}");
        }
    }

    #[test]
    fn partition_subset_when_compatible() {
        // M=8 masters, P=8 partitions, S=4 slaves: slave 1 reads {1, 5}.
        assert_eq!(partitions_for_slave(8, 8, 4, 1), vec![1, 5]);
        assert!(partition_subset_applies(8, 8, 4));
        // Every partition covered exactly once across slaves.
        let mut all: Vec<u32> = (0..4).flat_map(|s| partitions_for_slave(8, 8, 4, s)).collect();
        all.sort();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn partition_fallback_when_incompatible() {
        // S does not divide M -> read everything.
        assert_eq!(partitions_for_slave(8, 8, 3, 0), (0..8).collect::<Vec<_>>());
        assert!(!partition_subset_applies(8, 8, 3));
        // P != M -> read everything.
        assert_eq!(partitions_for_slave(8, 4, 4, 2), (0..4).collect::<Vec<_>>());
    }

    #[test]
    fn subset_routing_is_correct_not_just_covering() {
        // Ids routed to slave shard s must only appear in partitions the
        // subset rule assigns to s.
        let (m, p, s_cnt) = (12u32, 12u32, 4u32);
        let master = Router::new(m);
        let slave = Router::new(s_cnt);
        for id in 0..50_000u64 {
            let part = partition_of_shard(master.shard_of(id), p);
            let s = slave.shard_of(id);
            let subset = partitions_for_slave(m, p, s_cnt, s);
            assert!(
                subset.contains(&part),
                "id {id}: partition {part} not in slave {s}'s subset {subset:?}"
            );
        }
    }

    #[test]
    fn prop_routing_is_total_partition() {
        // Every id lands on exactly one shard for any cluster size.
        check(
            "routing-total",
            &PairOf(U64Range(1, 64), VecOf(U64Range(0, u64::MAX - 1), 128)),
            300,
            |(shards, ids)| {
                let r = Router::new(*shards as u32);
                let buckets = r.split_ids(ids);
                let total: usize = buckets.iter().map(|(p, _)| p.len()).sum();
                if total != ids.len() {
                    return Err(format!("{total} != {}", ids.len()));
                }
                let mut positions: Vec<usize> =
                    buckets.iter().flat_map(|(p, _)| p.iter().copied()).collect();
                positions.sort();
                positions.dedup();
                if positions.len() != ids.len() {
                    return Err("positions duplicated or lost".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_resharding_preserves_every_id() {
        // Migrating M -> N: re-routing all ids through the new router must
        // assign each id exactly one new shard; and ids that co-resided
        // stay findable (totality of migration_plan fanout).
        check(
            "resharding-total",
            &PairOf(PairOf(U64Range(1, 32), U64Range(1, 32)), VecOf(U64Range(0, 1 << 48), 200)),
            200,
            |((m, n), ids)| {
                let src = Router::new(*m as u32);
                let dst = Router::new(*n as u32);
                let plan = migration_plan(*m as u32, *n as u32);
                for &id in ids {
                    let s = src.shard_of(id);
                    let d = dst.shard_of(id);
                    if !plan[s as usize].contains(&d) {
                        return Err(format!("plan misses id {id}: {s} -> {d}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic]
    fn zero_shards_rejected() {
        Router::new(0);
    }
}
