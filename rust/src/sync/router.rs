//! Model routing: parameter-id → shard / partition mapping (§4.1.4a).
//!
//! Training traffic and inference traffic want different shard counts
//! ("the resource requirements of the two situations is inconsistent"), so
//! WeiPS lets every cluster pick its own count: ids route onto M master
//! shards, the pusher maps master shards onto P queue partitions, and each
//! slave cluster with S shards routes the *same ids* onto its own S. The
//! router also powers heterogeneous-cluster migration (§4.2.1d: "cluster A
//! has 10 shards to cluster B has 20 shards").
//!
//! Since the elastic-resharding subsystem ([`crate::reshard`]) the route
//! is **two-level**: ids hash onto a fixed universe of virtual slots, and
//! a versioned [`SlotMap`] assigns slots to shards. A [`Router`] is a
//! cheap-to-clone handle on a shared [`SlotMapCell`]; installing a bumped
//! map into the cell re-routes every holder (trainer clients, shard
//! guards, coordinators) mid-stream — the live-migration cutover.
//!
//! When the map is still the canonical uniform layout, `S` divides `M`
//! and `P == M`, a slave shard only needs the partition subset
//! `{p : p mod S == s}` — the paper's "specify certain partitions for
//! consuming ... reducing bandwidth pressure". Once a rebalance makes the
//! master map non-uniform, an id's updates can originate from any shard,
//! so scatters widen to every partition (`Scatter::subscribe_all`) before
//! the cutover and the slave filters by id — the fallback path that was
//! always there for incompatible topologies.

use std::sync::Arc;

use crate::reshard::{SlotMap, SlotMapCell, DEFAULT_SLOTS, HEAT_BUCKETS};
use crate::Result;

/// Shared-slot-map router over a cluster. Clones share the underlying
/// cell, so one epoch install re-routes every clone.
#[derive(Clone)]
pub struct Router {
    cell: Arc<SlotMapCell>,
}

impl Router {
    /// Router for a cluster of `shards` (>= 1) over the default slot
    /// universe ([`DEFAULT_SLOTS`]), starting from the canonical uniform
    /// map (epoch 0).
    pub fn new(shards: u32) -> Router {
        Router::with_slots(shards, DEFAULT_SLOTS)
    }

    /// Router with an explicit slot universe (the `reshard_slots` knob;
    /// clamped to at least the shard count so every shard owns a slot).
    pub fn with_slots(shards: u32, slots: usize) -> Router {
        assert!(shards >= 1, "cluster needs at least one shard");
        Router { cell: Arc::new(SlotMapCell::new(SlotMap::uniform(slots, shards))) }
    }

    /// Router over an existing shared cell (components wired by the
    /// coordinator all observe the same installs).
    pub fn shared(cell: Arc<SlotMapCell>) -> Router {
        Router { cell }
    }

    /// The shared cell.
    pub fn cell(&self) -> &Arc<SlotMapCell> {
        &self.cell
    }

    /// Current slot map (snapshot once per batch, then route through it —
    /// a snapshot is one `Arc` clone).
    pub fn snapshot(&self) -> Arc<SlotMap> {
        self.cell.snapshot()
    }

    /// Current routing epoch (0 = canonical uniform map).
    pub fn epoch(&self) -> u64 {
        self.cell.epoch()
    }

    /// Shard count under the current map.
    pub fn shards(&self) -> u32 {
        self.snapshot().shards
    }

    /// Slot universe size.
    pub fn slots(&self) -> usize {
        self.snapshot().slots()
    }

    /// Owning shard for a parameter id under the current map.
    #[inline]
    pub fn shard_of(&self, id: u64) -> u32 {
        self.snapshot().shard_of(id)
    }

    /// Owning virtual slot for a parameter id.
    #[inline]
    pub fn slot_of(&self, id: u64) -> u16 {
        self.snapshot().slot_of(id)
    }

    /// Install a bumped slot map (the migration cutover). Errors unless
    /// the epoch strictly advances over the installed one.
    pub fn install(&self, map: SlotMap) -> Result<Arc<SlotMap>> {
        self.cell.install(map)
    }

    /// Record one pushed row per id into the shared per-slot heat
    /// counters (routes through one snapshot of the map).
    pub fn record_push_heat(&self, ids: &[u64]) {
        let map = self.snapshot();
        let heat = self.cell.heat();
        for &id in ids {
            heat.record_push(map.slot_of(id));
        }
    }

    /// Record one pulled id per id into the shared per-slot heat counters.
    pub fn record_pull_heat(&self, ids: &[u64]) {
        let map = self.snapshot();
        let heat = self.cell.heat();
        for &id in ids {
            heat.record_pull(map.slot_of(id));
        }
    }

    /// Register this router's observability series under `role`: the
    /// routing-epoch gauge plus the bucketed per-slot push/pull heat
    /// counters (`slot_bucket` label, [`HEAT_BUCKETS`] buckets max) that
    /// feed the future load-aware rebalancer. Samplers hold a `Weak` on
    /// the cell, so a dropped cluster's series disappear from scrapes.
    pub fn register_metrics(&self, role: &str) {
        let cell = Arc::downgrade(&self.cell);
        crate::metrics::register_fn(
            "weips_routing_epoch",
            &[("role", role.to_string())],
            Box::new({
                let cell = cell.clone();
                move || cell.upgrade().map(|c| c.epoch() as f64)
            }),
        );
        let slots = self.slots();
        let buckets = HEAT_BUCKETS.min(slots.max(1));
        for b in 0..buckets {
            let labels = [("role", role.to_string()), ("slot_bucket", b.to_string())];
            crate::metrics::register_fn(
                "weips_slot_pushes_total",
                &labels,
                Box::new({
                    let cell = cell.clone();
                    move || cell.upgrade().map(|c| c.heat().bucket(b, buckets).0 as f64)
                }),
            );
            crate::metrics::register_fn(
                "weips_slot_pulls_total",
                &labels,
                Box::new({
                    let cell = cell.clone();
                    move || cell.upgrade().map(|c| c.heat().bucket(b, buckets).1 as f64)
                }),
            );
        }
    }

    /// Split `ids` into per-shard buckets; returns `(shard -> (positions,
    /// ids))` so callers can reassemble responses in request order. Routes
    /// through one consistent snapshot of the map.
    pub fn split_ids(&self, ids: &[u64]) -> Vec<(Vec<usize>, Vec<u64>)> {
        let map = self.snapshot();
        let mut buckets: Vec<(Vec<usize>, Vec<u64>)> =
            (0..map.shards).map(|_| (Vec::new(), Vec::new())).collect();
        for (pos, &id) in ids.iter().enumerate() {
            let s = map.shard_of(id) as usize;
            buckets[s].0.push(pos);
            buckets[s].1.push(id);
        }
        buckets
    }
}

impl std::fmt::Debug for Router {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let map = self.snapshot();
        write!(
            f,
            "Router {{ shards: {}, slots: {}, epoch: {} }}",
            map.shards,
            map.slots(),
            map.epoch
        )
    }
}

/// Master-shard → queue-partition mapping used by the pusher (§4.1.3:
/// "performing the partition mapping according to the server-id").
#[inline]
pub fn partition_of_shard(master_shard: u32, partitions: u32) -> u32 {
    master_shard % partitions
}

/// The partitions a slave shard must consume, given the master/partition/
/// slave topology. Returns the reduced subset when the modulo structure
/// allows it, else every partition (caller filters by id).
///
/// Sound only while both clusters run canonical uniform slot maps over
/// the same universe: id → slot k lands on master `k % M`, hence
/// partition `k % M` (P == M), and on slave `k % S`; `S | M` gives
/// `(k % M) % S == k % S`. A rebalanced master map breaks the structure —
/// scatters call `subscribe_all` before any cutover.
pub fn partitions_for_slave(
    master_shards: u32,
    partitions: u32,
    slave_shards: u32,
    slave_shard: u32,
) -> Vec<u32> {
    debug_assert!(slave_shard < slave_shards);
    if partitions == master_shards && master_shards % slave_shards == 0 {
        (0..partitions).filter(|p| p % slave_shards == slave_shard).collect()
    } else {
        (0..partitions).collect()
    }
}

/// True when the reduced-subset optimization applies (used by metrics and
/// the gather-bandwidth bench).
pub fn partition_subset_applies(master_shards: u32, partitions: u32, slave_shards: u32) -> bool {
    partitions == master_shards && master_shards % slave_shards == 0
}

/// Remap plan for migrating a model between clusters of different sizes
/// (§4.2.1d). For each source shard, which destination shards its rows can
/// land on — destination is still decided per id, this is the coarse plan
/// used to parallelize the copy.
pub fn migration_plan(src_shards: u32, dst_shards: u32) -> Vec<Vec<u32>> {
    // Any src shard may contain ids for any dst shard in general; with the
    // slot-modulo scheme the only exploitable structure is divisibility.
    let mut plan = Vec::with_capacity(src_shards as usize);
    for _src in 0..src_shards {
        plan.push((0..dst_shards).collect());
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PairOf, U64Range, VecOf};

    #[test]
    fn shard_of_is_stable_and_bounded() {
        let r = Router::new(8);
        for id in 0..1000u64 {
            let s = r.shard_of(id);
            assert!(s < 8);
            assert_eq!(s, r.shard_of(id));
        }
    }

    #[test]
    fn split_preserves_positions() {
        let r = Router::new(4);
        let ids = vec![10, 20, 30, 40, 50, 20];
        let buckets = r.split_ids(&ids);
        let mut seen = vec![false; ids.len()];
        for (shard, (positions, bids)) in buckets.iter().enumerate() {
            assert_eq!(positions.len(), bids.len());
            for (pos, id) in positions.iter().zip(bids) {
                assert_eq!(ids[*pos], *id);
                assert_eq!(r.shard_of(*id), shard as u32);
                seen[*pos] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every position routed exactly once");
    }

    #[test]
    fn balance_is_reasonable() {
        let r = Router::new(16);
        let mut counts = vec![0usize; 16];
        for id in 0..160_000u64 {
            counts[r.shard_of(id) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 1_500.0, "count {c}");
        }
    }

    #[test]
    fn partition_subset_when_compatible() {
        // M=8 masters, P=8 partitions, S=4 slaves: slave 1 reads {1, 5}.
        assert_eq!(partitions_for_slave(8, 8, 4, 1), vec![1, 5]);
        assert!(partition_subset_applies(8, 8, 4));
        // Every partition covered exactly once across slaves.
        let mut all: Vec<u32> = (0..4).flat_map(|s| partitions_for_slave(8, 8, 4, s)).collect();
        all.sort();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn partition_fallback_when_incompatible() {
        // S does not divide M -> read everything.
        assert_eq!(partitions_for_slave(8, 8, 3, 0), (0..8).collect::<Vec<_>>());
        assert!(!partition_subset_applies(8, 8, 3));
        // P != M -> read everything.
        assert_eq!(partitions_for_slave(8, 4, 4, 2), (0..4).collect::<Vec<_>>());
    }

    #[test]
    fn subset_routing_is_correct_not_just_covering() {
        // Ids routed to slave shard s must only appear in partitions the
        // subset rule assigns to s — including for shard counts that do
        // not divide the slot universe.
        let (m, p, s_cnt) = (12u32, 12u32, 4u32);
        let master = Router::new(m);
        let slave = Router::new(s_cnt);
        for id in 0..50_000u64 {
            let part = partition_of_shard(master.shard_of(id), p);
            let s = slave.shard_of(id);
            let subset = partitions_for_slave(m, p, s_cnt, s);
            assert!(
                subset.contains(&part),
                "id {id}: partition {part} not in slave {s}'s subset {subset:?}"
            );
        }
    }

    #[test]
    fn clones_share_the_map_and_installs_reroute() {
        let a = Router::with_slots(4, 64);
        let b = a.clone();
        let map = a.snapshot();
        let moved = map.slots_of(3);
        let bumped = map.rebalanced(&moved.iter().map(|&s| (s, 0)).collect::<Vec<_>>()).unwrap();
        a.install(bumped).unwrap();
        assert_eq!(b.epoch(), 1, "clone missed the install");
        for slot in moved {
            assert_eq!(b.snapshot().shard_of_slot(slot), 0);
        }
        // Stale install through any clone is rejected.
        assert!(b.install(SlotMap::uniform(64, 4)).is_err());
    }

    #[test]
    fn prop_routing_is_total_partition() {
        // Every id lands on exactly one shard for any cluster size.
        check(
            "routing-total",
            &PairOf(U64Range(1, 64), VecOf(U64Range(0, u64::MAX - 1), 128)),
            300,
            |(shards, ids)| {
                let r = Router::new(*shards as u32);
                let buckets = r.split_ids(ids);
                let total: usize = buckets.iter().map(|(p, _)| p.len()).sum();
                if total != ids.len() {
                    return Err(format!("{total} != {}", ids.len()));
                }
                let mut positions: Vec<usize> =
                    buckets.iter().flat_map(|(p, _)| p.iter().copied()).collect();
                positions.sort();
                positions.dedup();
                if positions.len() != ids.len() {
                    return Err("positions duplicated or lost".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_resharding_preserves_every_id() {
        // Migrating M -> N: re-routing all ids through the new router must
        // assign each id exactly one new shard; and ids that co-resided
        // stay findable (totality of migration_plan fanout).
        check(
            "resharding-total",
            &PairOf(PairOf(U64Range(1, 32), U64Range(1, 32)), VecOf(U64Range(0, 1 << 48), 200)),
            200,
            |((m, n), ids)| {
                let src = Router::new(*m as u32);
                let dst = Router::new(*n as u32);
                let plan = migration_plan(*m as u32, *n as u32);
                for &id in ids {
                    let s = src.shard_of(id);
                    let d = dst.shard_of(id);
                    if !plan[s as usize].contains(&d) {
                        return Err(format!("plan misses id {id}: {s} -> {d}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic]
    fn zero_shards_rejected() {
        Router::new(0);
    }
}
