//! Metadata / coordination store (the ZooKeeper / etcd substitute, §3.3).
//!
//! The scheduler keeps all global metadata here: node registry, shard
//! assignments, model version pointers, migration plans. Primitives match
//! what ZK/etcd give the paper's scheduler: versioned KV with compare-and-
//! swap, prefix listing, watches, ephemeral keys bound to heartbeat-kept
//! sessions, and leader election built on ephemerals.
//!
//! Single-process by design (the scheduler embeds one store and exposes it
//! over RPC); durability comes from the checkpoint store, matching the
//! paper's "scheduler ... maintains global metadata and is stateless".

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::util::clock::Clock;
use crate::{Error, Result};

/// Magic prefix distinguishing [`MetaStore::put_if_newer`] values from
/// plain puts — without it, any 8+-byte plain value would be silently
/// reinterpreted as an epoch tag.
const EPOCH_TAG: &[u8; 4] = b"EPv1";

/// A change notification delivered to watchers.
#[derive(Debug, Clone, PartialEq)]
pub enum WatchEvent {
    /// Key created or updated (new version attached).
    Put { key: String, version: u64 },
    /// Key removed (explicitly or via session expiry).
    Delete { key: String },
}

#[derive(Debug, Clone)]
struct Entry {
    value: Vec<u8>,
    version: u64,
    /// Session owning this ephemeral key (None = persistent).
    ephemeral: Option<u64>,
}

struct Watcher {
    prefix: String,
    tx: Sender<WatchEvent>,
}

#[derive(Debug, Clone)]
struct Session {
    last_seen_ms: u64,
    ttl_ms: u64,
}

struct State {
    entries: BTreeMap<String, Entry>,
    sessions: BTreeMap<u64, Session>,
    watchers: Vec<Watcher>,
    next_session: u64,
    next_version: u64,
}

/// The coordination store. Cheap to clone (shared state).
#[derive(Clone)]
pub struct MetaStore {
    state: Arc<Mutex<State>>,
    clock: Arc<dyn Clock>,
}

impl MetaStore {
    /// New empty store on `clock`.
    pub fn new(clock: Arc<dyn Clock>) -> MetaStore {
        MetaStore {
            state: Arc::new(Mutex::new(State {
                entries: BTreeMap::new(),
                sessions: BTreeMap::new(),
                watchers: Vec::new(),
                next_session: 1,
                next_version: 1,
            })),
            clock,
        }
    }

    fn notify(state: &mut State, event: WatchEvent) {
        let key = match &event {
            WatchEvent::Put { key, .. } => key.clone(),
            WatchEvent::Delete { key } => key.clone(),
        };
        state
            .watchers
            .retain(|w| !key.starts_with(&w.prefix) || w.tx.send(event.clone()).is_ok());
    }

    /// Unconditional put; returns the new version.
    pub fn put(&self, key: &str, value: impl Into<Vec<u8>>) -> u64 {
        let mut s = self.state.lock().unwrap();
        let version = s.next_version;
        s.next_version += 1;
        s.entries
            .insert(key.to_string(), Entry { value: value.into(), version, ephemeral: None });
        Self::notify(&mut s, WatchEvent::Put { key: key.to_string(), version });
        version
    }

    /// Compare-and-swap: succeeds only if the current version matches
    /// `expected` (0 = key must not exist). Returns the new version.
    pub fn cas(&self, key: &str, expected: u64, value: impl Into<Vec<u8>>) -> Result<u64> {
        let mut s = self.state.lock().unwrap();
        let current = s.entries.get(key).map(|e| e.version).unwrap_or(0);
        if current != expected {
            return Err(Error::MetaConflict(format!(
                "{key}: version {current} != expected {expected}"
            )));
        }
        let version = s.next_version;
        s.next_version += 1;
        let ephemeral = s.entries.get(key).and_then(|e| e.ephemeral);
        s.entries
            .insert(key.to_string(), Entry { value: value.into(), version, ephemeral });
        Self::notify(&mut s, WatchEvent::Put { key: key.to_string(), version });
        Ok(version)
    }

    /// Epoch-guarded publish (the slot-map install primitive): store
    /// `value` under `key` tagged with `epoch`, succeeding only when the
    /// key is absent or its stored epoch is **smaller** — racing
    /// publishers can never roll an assignment back. The stored value is
    /// framed `[magic 4][epoch 8 LE][payload]`; an existing value
    /// without the magic (e.g. written by a plain [`MetaStore::put`]) is
    /// a conflict, never a bypass. Read back with
    /// [`MetaStore::get_epochal`]. Returns the new store version.
    pub fn put_if_newer(&self, key: &str, epoch: u64, value: impl Into<Vec<u8>>) -> Result<u64> {
        let mut s = self.state.lock().unwrap();
        if let Some(e) = s.entries.get(key) {
            match Self::parse_epochal(&e.value) {
                Some((current, _)) if current >= epoch => {
                    return Err(Error::MetaConflict(format!(
                        "{key}: epoch {current} >= published {epoch}"
                    )));
                }
                Some(_) => {}
                None => {
                    return Err(Error::MetaConflict(format!(
                        "{key}: existing value is not epoch-tagged"
                    )));
                }
            }
        }
        let payload = value.into();
        let mut tagged = Vec::with_capacity(12 + payload.len());
        tagged.extend_from_slice(EPOCH_TAG);
        tagged.extend_from_slice(&epoch.to_le_bytes());
        tagged.extend(payload);
        let version = s.next_version;
        s.next_version += 1;
        s.entries
            .insert(key.to_string(), Entry { value: tagged, version, ephemeral: None });
        Self::notify(&mut s, WatchEvent::Put { key: key.to_string(), version });
        Ok(version)
    }

    /// Split an epoch-tagged value into `(epoch, payload)`; `None` when
    /// the magic is absent (a plain value).
    fn parse_epochal(value: &[u8]) -> Option<(u64, &[u8])> {
        if value.len() < 12 || &value[..4] != EPOCH_TAG {
            return None;
        }
        Some((u64::from_le_bytes(value[4..12].try_into().unwrap()), &value[12..]))
    }

    /// Read a key written by [`MetaStore::put_if_newer`]:
    /// `(epoch, value, version)`. `None` for absent keys and for plain
    /// (untagged) values.
    pub fn get_epochal(&self, key: &str) -> Option<(u64, Vec<u8>, u64)> {
        let s = self.state.lock().unwrap();
        let e = s.entries.get(key)?;
        let (epoch, payload) = Self::parse_epochal(&e.value)?;
        Some((epoch, payload.to_vec(), e.version))
    }

    /// Read a key: `(value, version)`.
    pub fn get(&self, key: &str) -> Option<(Vec<u8>, u64)> {
        let s = self.state.lock().unwrap();
        s.entries.get(key).map(|e| (e.value.clone(), e.version))
    }

    /// Delete a key; true if it existed.
    pub fn delete(&self, key: &str) -> bool {
        let mut s = self.state.lock().unwrap();
        let existed = s.entries.remove(key).is_some();
        if existed {
            Self::notify(&mut s, WatchEvent::Delete { key: key.to_string() });
        }
        existed
    }

    /// All keys with `prefix`, with values and versions.
    pub fn list(&self, prefix: &str) -> Vec<(String, Vec<u8>, u64)> {
        let s = self.state.lock().unwrap();
        s.entries
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, e)| (k.clone(), e.value.clone(), e.version))
            .collect()
    }

    /// Subscribe to changes under `prefix`. Events arrive on the receiver.
    pub fn watch(&self, prefix: &str) -> Receiver<WatchEvent> {
        let (tx, rx) = channel();
        let mut s = self.state.lock().unwrap();
        s.watchers.push(Watcher { prefix: prefix.to_string(), tx });
        rx
    }

    // -- sessions / ephemerals ------------------------------------------------

    /// Open a session with `ttl_ms`; keep alive via [`MetaStore::heartbeat`].
    pub fn open_session(&self, ttl_ms: u64) -> u64 {
        let mut s = self.state.lock().unwrap();
        let id = s.next_session;
        s.next_session += 1;
        let now = self.clock.now_ms();
        s.sessions.insert(id, Session { last_seen_ms: now, ttl_ms });
        id
    }

    /// Refresh a session; errors if it already expired.
    pub fn heartbeat(&self, session: u64) -> Result<()> {
        let mut s = self.state.lock().unwrap();
        let now = self.clock.now_ms();
        match s.sessions.get_mut(&session) {
            Some(sess) => {
                sess.last_seen_ms = now;
                Ok(())
            }
            None => Err(Error::State(format!("session {session} expired or unknown"))),
        }
    }

    /// Create a key bound to `session`; it is deleted when the session dies.
    pub fn put_ephemeral(&self, session: u64, key: &str, value: impl Into<Vec<u8>>) -> Result<u64> {
        let mut s = self.state.lock().unwrap();
        if !s.sessions.contains_key(&session) {
            return Err(Error::State(format!("session {session} expired or unknown")));
        }
        let version = s.next_version;
        s.next_version += 1;
        s.entries.insert(
            key.to_string(),
            Entry { value: value.into(), version, ephemeral: Some(session) },
        );
        Self::notify(&mut s, WatchEvent::Put { key: key.to_string(), version });
        Ok(version)
    }

    /// Expire overdue sessions, removing their ephemerals. Returns the list
    /// of expired session ids. Call periodically (the scheduler ticks this).
    pub fn expire_sessions(&self) -> Vec<u64> {
        let mut s = self.state.lock().unwrap();
        let now = self.clock.now_ms();
        let dead: Vec<u64> = s
            .sessions
            .iter()
            .filter(|(_, sess)| now.saturating_sub(sess.last_seen_ms) > sess.ttl_ms)
            .map(|(id, _)| *id)
            .collect();
        for id in &dead {
            s.sessions.remove(id);
            let keys: Vec<String> = s
                .entries
                .iter()
                .filter(|(_, e)| e.ephemeral == Some(*id))
                .map(|(k, _)| k.clone())
                .collect();
            for k in keys {
                s.entries.remove(&k);
                Self::notify(&mut s, WatchEvent::Delete { key: k });
            }
        }
        dead
    }

    /// Close a session explicitly (graceful shutdown), removing ephemerals.
    pub fn close_session(&self, session: u64) {
        let mut s = self.state.lock().unwrap();
        s.sessions.remove(&session);
        let keys: Vec<String> = s
            .entries
            .iter()
            .filter(|(_, e)| e.ephemeral == Some(session))
            .map(|(k, _)| k.clone())
            .collect();
        for k in keys {
            s.entries.remove(&k);
            Self::notify(&mut s, WatchEvent::Delete { key: k });
        }
    }

    // -- leader election -------------------------------------------------------

    /// Try to become leader for `role` using `session`'s lifetime as the
    /// lease. Returns true if this session now holds (or already held) the
    /// leadership key.
    pub fn try_lead(&self, role: &str, session: u64, node: &str) -> Result<bool> {
        let key = format!("/election/{role}");
        {
            let s = self.state.lock().unwrap();
            if !s.sessions.contains_key(&session) {
                return Err(Error::State(format!("session {session} expired or unknown")));
            }
            if let Some(e) = s.entries.get(&key) {
                return Ok(e.ephemeral == Some(session));
            }
        }
        // Vacant: race via ephemeral insert under the same lock.
        let mut s = self.state.lock().unwrap();
        if s.entries.contains_key(&key) {
            return Ok(s.entries.get(&key).unwrap().ephemeral == Some(session));
        }
        let version = s.next_version;
        s.next_version += 1;
        s.entries.insert(
            key.clone(),
            Entry { value: node.as_bytes().to_vec(), version, ephemeral: Some(session) },
        );
        Self::notify(&mut s, WatchEvent::Put { key, version });
        Ok(true)
    }

    /// Current leader node name for `role`, if any.
    pub fn leader(&self, role: &str) -> Option<String> {
        self.get(&format!("/election/{role}"))
            .map(|(v, _)| String::from_utf8_lossy(&v).into_owned())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;

    fn store() -> (MetaStore, ManualClock) {
        let clock = ManualClock::new(1_000);
        (MetaStore::new(Arc::new(clock.clone())), clock)
    }

    #[test]
    fn put_get_delete() {
        let (m, _) = store();
        let v1 = m.put("/a", b"1".to_vec());
        let (val, ver) = m.get("/a").unwrap();
        assert_eq!(val, b"1");
        assert_eq!(ver, v1);
        let v2 = m.put("/a", b"2".to_vec());
        assert!(v2 > v1);
        assert!(m.delete("/a"));
        assert!(!m.delete("/a"));
        assert!(m.get("/a").is_none());
    }

    #[test]
    fn cas_enforces_versions() {
        let (m, _) = store();
        // Create-if-absent via expected=0.
        let v1 = m.cas("/k", 0, b"x".to_vec()).unwrap();
        assert!(m.cas("/k", 0, b"y".to_vec()).is_err());
        let v2 = m.cas("/k", v1, b"y".to_vec()).unwrap();
        assert!(v2 > v1);
        assert!(m.cas("/k", v1, b"z".to_vec()).is_err());
        assert_eq!(m.get("/k").unwrap().0, b"y");
    }

    #[test]
    fn put_if_newer_is_epoch_guarded() {
        let (m, _) = store();
        m.put_if_newer("/map", 0, b"a".to_vec()).unwrap();
        assert!(m.put_if_newer("/map", 0, b"b".to_vec()).is_err(), "same epoch accepted");
        m.put_if_newer("/map", 3, b"c".to_vec()).unwrap();
        assert!(m.put_if_newer("/map", 2, b"d".to_vec()).is_err(), "rollback accepted");
        let (epoch, value, _) = m.get_epochal("/map").unwrap();
        assert_eq!((epoch, value), (3, b"c".to_vec()));
        assert!(m.get_epochal("/nope").is_none());
        // A plain (untagged) value on the key is a conflict, not an
        // unguarded overwrite — short or long.
        m.put("/raw", b"x".to_vec());
        assert!(m.put_if_newer("/raw", 5, b"y".to_vec()).is_err());
        m.put("/raw8", b"hello world, twelve+".to_vec());
        assert!(m.put_if_newer("/raw8", 5, b"y".to_vec()).is_err());
        assert!(m.get_epochal("/raw8").is_none());
        // Watchers see epochal puts like any other.
        let rx = m.watch("/map");
        m.put_if_newer("/map", 4, b"e".to_vec()).unwrap();
        assert!(matches!(rx.recv().unwrap(), WatchEvent::Put { ref key, .. } if key == "/map"));
    }

    #[test]
    fn list_by_prefix_sorted() {
        let (m, _) = store();
        m.put("/nodes/m1", b"".to_vec());
        m.put("/nodes/m0", b"".to_vec());
        m.put("/other", b"".to_vec());
        let keys: Vec<String> = m.list("/nodes/").into_iter().map(|(k, _, _)| k).collect();
        assert_eq!(keys, vec!["/nodes/m0".to_string(), "/nodes/m1".to_string()]);
    }

    #[test]
    fn watch_delivers_puts_and_deletes() {
        let (m, _) = store();
        let rx = m.watch("/models/");
        m.put("/models/ctr/version", b"1".to_vec());
        m.put("/nodes/x", b"".to_vec()); // outside prefix
        m.delete("/models/ctr/version");
        let e1 = rx.recv().unwrap();
        assert!(matches!(e1, WatchEvent::Put { ref key, .. } if key == "/models/ctr/version"));
        let e2 = rx.recv().unwrap();
        assert_eq!(e2, WatchEvent::Delete { key: "/models/ctr/version".into() });
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn dropped_watcher_is_pruned() {
        let (m, _) = store();
        drop(m.watch("/x/"));
        m.put("/x/1", b"".to_vec()); // must not panic / leak
        m.put("/x/2", b"".to_vec());
    }

    #[test]
    fn ephemeral_dies_with_session_expiry() {
        let (m, clock) = store();
        let s = m.open_session(500);
        m.put_ephemeral(s, "/nodes/w0", b"alive".to_vec()).unwrap();
        assert!(m.get("/nodes/w0").is_some());

        clock.advance(400);
        assert_eq!(m.expire_sessions(), Vec::<u64>::new());
        m.heartbeat(s).unwrap();
        clock.advance(400);
        assert_eq!(m.expire_sessions(), Vec::<u64>::new()); // refreshed
        clock.advance(600);
        assert_eq!(m.expire_sessions(), vec![s]);
        assert!(m.get("/nodes/w0").is_none());
        assert!(m.heartbeat(s).is_err());
        assert!(m.put_ephemeral(s, "/nodes/w0", b"".to_vec()).is_err());
    }

    #[test]
    fn close_session_removes_ephemerals() {
        let (m, _) = store();
        let s = m.open_session(10_000);
        m.put_ephemeral(s, "/nodes/a", b"".to_vec()).unwrap();
        m.put_ephemeral(s, "/nodes/b", b"".to_vec()).unwrap();
        m.put("/nodes/keep", b"".to_vec());
        m.close_session(s);
        assert!(m.get("/nodes/a").is_none());
        assert!(m.get("/nodes/b").is_none());
        assert!(m.get("/nodes/keep").is_some());
    }

    #[test]
    fn leader_election_failover() {
        let (m, clock) = store();
        let s1 = m.open_session(500);
        let s2 = m.open_session(10_000);
        assert!(m.try_lead("scheduler", s1, "node1").unwrap());
        assert!(!m.try_lead("scheduler", s2, "node2").unwrap());
        assert!(m.try_lead("scheduler", s1, "node1").unwrap()); // idempotent
        assert_eq!(m.leader("scheduler").unwrap(), "node1");
        // node1's session dies -> node2 can take over.
        clock.advance(1_000);
        m.expire_sessions();
        assert_eq!(m.leader("scheduler"), None);
        assert!(m.try_lead("scheduler", s2, "node2").unwrap());
        assert_eq!(m.leader("scheduler").unwrap(), "node2");
    }

    #[test]
    fn watch_sees_session_expiry_deletes() {
        let (m, clock) = store();
        let rx = m.watch("/nodes/");
        let s = m.open_session(100);
        m.put_ephemeral(s, "/nodes/w1", b"".to_vec()).unwrap();
        clock.advance(500);
        m.expire_sessions();
        let events: Vec<WatchEvent> = rx.try_iter().collect();
        assert!(events.contains(&WatchEvent::Delete { key: "/nodes/w1".into() }));
    }

    #[test]
    fn concurrent_cas_single_winner() {
        let (m, _) = store();
        m.put("/ctr", 0u64.to_le_bytes().to_vec());
        let (_, base) = m.get("/ctr").unwrap();
        let m = Arc::new(m);
        let wins = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            let wins = wins.clone();
            handles.push(std::thread::spawn(move || {
                if m.cas("/ctr", base, b"mine".to_vec()).is_ok() {
                    wins.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(wins.load(std::sync::atomic::Ordering::SeqCst), 1);
    }
}
