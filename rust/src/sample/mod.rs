//! Synthetic CTR workload (substitute for Weibo's production feed).
//!
//! Reproduces the two workload properties the paper's mechanisms exploit:
//!
//! 1. **Power-law feature popularity** — a Zipf-distributed id universe
//!    makes the same hot ids repeat within short windows, producing the
//!    "90 % repetition rate within 10 s" that justifies gather dedup (E2).
//! 2. **Interest drift** — the ground-truth model rotates slowly over
//!    time, so a model that stops updating decays (E8 freshness) and an
//!    abruptly corrupted model is detectable (E5 downgrade).
//!
//! Every sample is `fields` hashed feature ids + a Bernoulli click label
//! drawn from a deterministic latent model, so experiments are exactly
//! reproducible from a seed.

use crate::util::rng::{Rng, Zipf};
use crate::util::{fxhash64, hash::FxHashMap};

/// One joined training/serving sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Exposure timestamp (ms).
    pub ts_ms: u64,
    /// One feature id per field (already hashed into the id space).
    pub ids: Vec<u64>,
    /// Click label (0/1).
    pub label: f32,
}

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub fields: usize,
    /// Distinct base entities per field (id universe ≈ fields × this).
    pub ids_per_field: u64,
    /// Zipf exponent for id popularity.
    pub zipf_s: f64,
    /// Base CTR level (logit offset).
    pub base_logit: f32,
    /// Radians of ground-truth rotation per second (interest drift).
    pub drift_per_sec: f64,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            fields: 16,
            ids_per_field: 100_000,
            zipf_s: 1.1,
            base_logit: -1.0,
            drift_per_sec: 0.002,
            seed: 0xC7B_5EED,
        }
    }
}

/// Synthetic CTR stream.
pub struct Workload {
    cfg: WorkloadConfig,
    zipf: Zipf,
    rng: Rng,
}

impl Workload {
    /// New generator.
    pub fn new(cfg: WorkloadConfig) -> Workload {
        let zipf = Zipf::new(cfg.ids_per_field, cfg.zipf_s);
        let rng = Rng::new(cfg.seed);
        Workload { cfg, zipf, rng }
    }

    /// Feature id for (field, rank): stable hash into a shared id space.
    fn feature_id(&self, field: usize, rank: u64) -> u64 {
        fxhash64((field as u64) << 48 ^ rank.wrapping_add(1))
    }

    /// Deterministic latent weight of an id at time `t_ms`: a per-id base
    /// amplitude + phase, rotated by the drift rate. Mean ~0, |w| <= ~1.
    pub fn true_weight(&self, id: u64, t_ms: u64) -> f32 {
        let h = fxhash64(id ^ 0x7ea1_77e1);
        let amplitude = 0.3 + 0.7 * ((h >> 32) as f64 / u32::MAX as f64);
        let phase = (h & 0xFFFF_FFFF) as f64 / u32::MAX as f64 * std::f64::consts::TAU;
        let angle = phase + self.cfg.drift_per_sec * (t_ms as f64 / 1000.0);
        (amplitude * angle.cos()) as f32
    }

    /// True click probability of a sample at `t_ms`.
    pub fn true_ctr(&self, ids: &[u64], t_ms: u64) -> f32 {
        // Normalize by 2 (not sqrt(F)) so the latent signal dominates the
        // label noise: Bayes AUC ≈ 0.8 at F=16, giving the monitoring /
        // downgrade / freshness experiments a crisp detectable signal.
        let logit: f32 = self.cfg.base_logit
            + ids.iter().map(|id| self.true_weight(*id, t_ms)).sum::<f32>() / 2.0;
        1.0 / (1.0 + (-logit).exp())
    }

    /// Draw one sample at time `t_ms`.
    pub fn sample(&mut self, t_ms: u64) -> Sample {
        let mut ids = Vec::with_capacity(self.cfg.fields);
        for f in 0..self.cfg.fields {
            let rank = self.zipf.sample(&mut self.rng);
            ids.push(self.feature_id(f, rank));
        }
        let p = self.true_ctr(&ids, t_ms);
        let label = self.rng.gen_bool(p as f64) as u8 as f32;
        Sample { ts_ms: t_ms, ids, label }
    }

    /// Draw a batch at `t_ms`.
    pub fn batch(&mut self, t_ms: u64, n: usize) -> Vec<Sample> {
        (0..n).map(|_| self.sample(t_ms)).collect()
    }

    /// Fields per sample.
    pub fn fields(&self) -> usize {
        self.cfg.fields
    }
}

/// Measure the repetition rate of ids within a window of `n` samples —
/// the statistic behind the paper's 90 % observation (E2's oracle).
pub fn repetition_rate(samples: &[Sample]) -> f64 {
    let mut seen: FxHashMap<u64, ()> = FxHashMap::default();
    let mut total = 0u64;
    let mut repeats = 0u64;
    for s in samples {
        for id in &s.ids {
            total += 1;
            if seen.insert(*id, ()).is_some() {
                repeats += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        repeats as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(seed: u64) -> WorkloadConfig {
        WorkloadConfig { seed, ..Default::default() }
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Workload::new(cfg(1));
        let mut b = Workload::new(cfg(1));
        for t in 0..20 {
            assert_eq!(a.sample(t * 100), b.sample(t * 100));
        }
    }

    #[test]
    fn sample_shape_and_labels() {
        let mut w = Workload::new(cfg(2));
        let batch = w.batch(0, 500);
        assert_eq!(batch.len(), 500);
        let mut clicks = 0.0;
        for s in &batch {
            assert_eq!(s.ids.len(), 16);
            assert!(s.label == 0.0 || s.label == 1.0);
            clicks += s.label;
        }
        let ctr = clicks / 500.0;
        assert!(ctr > 0.05 && ctr < 0.8, "ctr {ctr}");
    }

    #[test]
    fn popularity_is_skewed_with_high_repetition() {
        // Repetition grows with window size (E2 sweeps this to the paper's
        // 90 % at production-scale windows / skews). At 20k samples and the
        // default skew it is already well above 70 %.
        let mut w = Workload::new(cfg(3));
        let small = repetition_rate(&w.batch(0, 1_000));
        let mut w2 = Workload::new(cfg(3));
        let large = repetition_rate(&w2.batch(0, 20_000));
        assert!(small > 0.4, "1k-window repetition {small}");
        assert!(large > 0.7, "20k-window repetition {large}");
        assert!(large > small, "repetition must grow with the window");
    }

    #[test]
    fn labels_correlate_with_true_ctr() {
        let mut w = Workload::new(cfg(4));
        let mut hi = (0.0, 0.0);
        let mut lo = (0.0, 0.0);
        for _ in 0..20_000 {
            let s = w.sample(0);
            let p = w.true_ctr(&s.ids, 0);
            if p > 0.4 {
                hi.0 += s.label as f64;
                hi.1 += 1.0;
            } else if p < 0.2 {
                lo.0 += s.label as f64;
                lo.1 += 1.0;
            }
        }
        if hi.1 > 50.0 && lo.1 > 50.0 {
            assert!(hi.0 / hi.1 > lo.0 / lo.1 + 0.1, "{} vs {}", hi.0 / hi.1, lo.0 / lo.1);
        }
    }

    #[test]
    fn drift_changes_ground_truth_slowly() {
        let w = Workload::new(cfg(5));
        let id = 1234u64;
        let w0 = w.true_weight(id, 0);
        let w1s = w.true_weight(id, 1_000);
        let w1h = w.true_weight(id, 3_600_000);
        assert!((w0 - w1s).abs() < 0.01, "1s drift too fast");
        assert!((w0 - w1h).abs() > 0.001, "1h should drift");
    }

    #[test]
    fn ids_disjoint_across_fields() {
        let mut w = Workload::new(cfg(6));
        let batch = w.batch(0, 200);
        // The same rank in different fields must map to different ids.
        let id_a = batch[0].ids[0];
        assert!(batch.iter().all(|s| s.ids[1] != id_a || s.ids[0] != s.ids[1]));
    }
}
