//! WeiPS launcher — role entrypoint (broker / master / slave / trainer /
//! predictor) plus an all-in-one `local` mode. Run `weips help`.

fn main() {
    if let Err(e) = weips::cli::run(std::env::args().skip(1).collect()) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
