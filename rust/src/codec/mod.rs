//! Binary wire/storage codec: bounds-checked reader/writer, varints,
//! CRC-framed envelopes and optional LZ compression.
//!
//! The paper's pusher "makes serialize and compress for the aggregated
//! updated data" before handing it to the external queue (§4.1.3); this
//! module is that serializer. It is also the checkpoint on-disk format and
//! the RPC frame codec. No serde in the offline build environment — every
//! message type implements [`Encode`]/[`Decode`] by hand against these
//! primitives.

mod compress;

pub use compress::{
    compress, decompress, decompress_into, maybe_compress, maybe_compress_into, CompressMode,
    LzState,
};

use crate::{Error, Result};

/// Append-only byte sink with primitive encoders.
#[derive(Debug, Default, Clone)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// Empty writer.
    pub fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    /// Writer with reserved capacity.
    pub fn with_capacity(cap: usize) -> Self {
        Writer { buf: Vec::with_capacity(cap) }
    }

    /// Finish and take the encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Borrow the encoded bytes (for reusable writers that survive the
    /// encode — the pusher's pooled buffers).
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Reset for reuse, keeping the allocated capacity.
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// LEB128 unsigned varint (1 byte for values < 128).
    pub fn put_varint(&mut self, mut v: u64) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(b);
                return;
            }
            self.buf.push(b | 0x80);
        }
    }

    /// Length-prefixed byte slice.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_varint(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    /// Raw bytes, no length prefix.
    pub fn put_raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed f32 slice (bulk memcpy on little-endian targets —
    /// the sync hot path moves megabytes of row values per second).
    pub fn put_f32_slice(&mut self, v: &[f32]) {
        self.put_varint(v.len() as u64);
        if cfg!(target_endian = "little") {
            // Safety: f32 has no invalid bit patterns; LE layout matches
            // the wire format exactly.
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
            self.buf.extend_from_slice(bytes);
        } else {
            self.buf.reserve(v.len() * 4);
            for x in v {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    /// Length-prefixed u64 slice, delta-encoded: each element is the
    /// zigzag varint of its (wrapping) difference from the previous one,
    /// the first diffing against 0. Sorted id lists — the common shape on
    /// the pull/sync paths — collapse to a byte or two per id; unsorted
    /// input still round-trips exactly (wrapping arithmetic + zigzag
    /// cover any jump, including to/from `u64::MAX`).
    pub fn put_u64_slice(&mut self, v: &[u64]) {
        self.put_varint(v.len() as u64);
        let mut prev = 0u64;
        for &x in v {
            let delta = x.wrapping_sub(prev) as i64;
            self.put_varint(((delta << 1) ^ (delta >> 63)) as u64);
            prev = x;
        }
    }
}

/// Bounds-checked reader over an encoded byte slice.
#[derive(Debug, Clone)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Reader over `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True when fully consumed.
    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Codec(format!(
                "short read: need {n} bytes at {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.get_u8()?;
            if shift >= 64 {
                return Err(Error::Codec("varint overflow".into()));
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_varint()? as usize;
        self.take(n)
    }

    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        String::from_utf8(b.to_vec()).map_err(|_| Error::Codec("invalid utf8".into()))
    }

    pub fn get_f32_slice(&mut self) -> Result<Vec<f32>> {
        let n = self.get_varint()? as usize;
        if n > self.remaining() / 4 + 1 {
            return Err(Error::Codec(format!("f32 slice length {n} exceeds buffer")));
        }
        let raw = self.take(n * 4)?;
        let mut out = vec![0.0f32; n];
        if cfg!(target_endian = "little") {
            // Safety: out has exactly n*4 bytes; any bit pattern is a
            // valid f32.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    n * 4,
                );
            }
        } else {
            for (i, c) in raw.chunks_exact(4).enumerate() {
                out[i] = f32::from_le_bytes(c.try_into().unwrap());
            }
        }
        Ok(out)
    }

    pub fn get_u64_slice(&mut self) -> Result<Vec<u64>> {
        let n = self.get_varint()? as usize;
        // Each zigzag delta takes at least one byte, so a declared length
        // beyond the remaining bytes is hostile — reject before reserving.
        if n > self.remaining() {
            return Err(Error::Codec(format!("u64 slice length {n} exceeds buffer")));
        }
        let mut out = Vec::with_capacity(n);
        let mut prev = 0u64;
        for _ in 0..n {
            let z = self.get_varint()?;
            let delta = ((z >> 1) as i64) ^ -((z & 1) as i64);
            prev = prev.wrapping_add(delta as u64);
            out.push(prev);
        }
        Ok(out)
    }
}

/// Types that serialize onto a [`Writer`].
pub trait Encode {
    /// Append this value's encoding.
    fn encode(&self, w: &mut Writer);

    /// Convenience: encode into a fresh byte vector.
    fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.encode(&mut w);
        w.into_bytes()
    }
}

/// Types that deserialize from a [`Reader`].
pub trait Decode: Sized {
    /// Parse one value, advancing the reader.
    fn decode(r: &mut Reader) -> Result<Self>;

    /// Convenience: decode from a full byte slice, requiring full consumption.
    fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = Reader::new(bytes);
        let v = Self::decode(&mut r)?;
        if !r.is_done() {
            return Err(Error::Codec(format!("{} trailing bytes", r.remaining())));
        }
        Ok(v)
    }
}

fn crc32_table() -> &'static [u32; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, entry) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *entry = c;
        }
        t
    })
}

/// CRC-32 (IEEE 802.3 polynomial, the `crc32fast::hash` contract) over a
/// byte slice. Table-driven; the table is built once per process.
pub fn crc32(data: &[u8]) -> u32 {
    crc32_parts(&[data])
}

/// CRC-32 over the logical concatenation of `parts`, without ever
/// materializing it. Exactly equals `crc32` of the joined bytes, so the
/// vectored RPC write path can checksum `[response head, body]` while the
/// receiver verifies the contiguous frame it reassembled.
pub fn crc32_parts(parts: &[&[u8]]) -> u32 {
    let table = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for part in parts {
        for &b in *part {
            c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
    }
    !c
}

/// Frame an encoded payload with `[len u32][crc32 u32]` for storage / wire
/// transport. Detects truncation and corruption.
pub fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 8);
    out.extend_from_slice(&[0u8; 8]);
    out.extend_from_slice(payload);
    finish_frame(&mut out);
    out
}

/// Finish a frame assembled in place: `buf` holds 8 reserved header bytes
/// followed by the payload; this writes `[len u32][crc32 u32]` into the
/// header. The in-buffer twin of [`frame`] — the RPC layer assembles
/// requests and responses directly in reusable per-connection buffers, so
/// steady-state framing performs zero heap allocations.
pub fn finish_frame(buf: &mut [u8]) {
    debug_assert!(buf.len() >= 8, "finish_frame needs the 8 reserved header bytes");
    let len = buf.len() - 8;
    let crc = crc32(&buf[8..]);
    buf[0..4].copy_from_slice(&(len as u32).to_le_bytes());
    buf[4..8].copy_from_slice(&crc.to_le_bytes());
}

/// Parse one frame from the front of `buf`: returns `(payload, consumed)`.
/// `Ok(None)` means more bytes are needed (partial frame).
pub fn unframe(buf: &[u8]) -> Result<Option<(&[u8], usize)>> {
    if buf.len() < 8 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    if len > crate::net::MAX_FRAME {
        return Err(Error::Codec(format!("frame length {len} exceeds max")));
    }
    if buf.len() < 8 + len {
        return Ok(None);
    }
    let crc = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let payload = &buf[8..8 + len];
    if crc32(payload) != crc {
        return Err(Error::Codec("frame crc mismatch".into()));
    }
    Ok(Some((payload, 8 + len)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Strategy, U64Range, VecOf};
    use crate::util::Rng;

    #[test]
    fn primitives_round_trip() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u16(300);
        w.put_u32(70_000);
        w.put_u64(u64::MAX);
        w.put_f32(1.5);
        w.put_f64(-2.25);
        w.put_str("weips");
        w.put_bytes(&[1, 2, 3]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u16().unwrap(), 300);
        assert_eq!(r.get_u32().unwrap(), 70_000);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_f32().unwrap(), 1.5);
        assert_eq!(r.get_f64().unwrap(), -2.25);
        assert_eq!(r.get_str().unwrap(), "weips");
        assert_eq!(r.get_bytes().unwrap(), &[1, 2, 3]);
        assert!(r.is_done());
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            let mut w = Writer::new();
            w.put_varint(v);
            let bytes = w.into_bytes();
            let mut r = Reader::new(&bytes);
            assert_eq!(r.get_varint().unwrap(), v);
            assert!(r.is_done());
        }
    }

    #[test]
    fn varint_sizes() {
        let size = |v: u64| {
            let mut w = Writer::new();
            w.put_varint(v);
            w.len()
        };
        assert_eq!(size(0), 1);
        assert_eq!(size(127), 1);
        assert_eq!(size(128), 2);
        assert_eq!(size(u64::MAX), 10);
    }

    #[test]
    fn short_reads_error_not_panic() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.get_u64().is_err());
        let mut r2 = Reader::new(&[0x85]); // unterminated varint
        assert!(r2.get_varint().is_err());
    }

    #[test]
    fn hostile_lengths_rejected() {
        // A declared slice length far beyond the buffer must not allocate.
        let mut w = Writer::new();
        w.put_varint(u64::MAX / 8);
        let bytes = w.into_bytes();
        assert!(Reader::new(&bytes).get_f32_slice().is_err());
        assert!(Reader::new(&bytes).get_u64_slice().is_err());
    }

    #[test]
    fn f32_slice_round_trip() {
        let vals: Vec<f32> = (0..1000).map(|i| i as f32 * 0.25 - 3.0).collect();
        let mut w = Writer::new();
        w.put_f32_slice(&vals);
        let bytes = w.into_bytes();
        assert_eq!(Reader::new(&bytes).get_f32_slice().unwrap(), vals);
    }

    #[test]
    fn crc32_matches_reference_vector() {
        // The canonical IEEE CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn prop_crc32_parts_equals_crc32_of_concatenation() {
        // The vectored write path checksums [head, body] as separate
        // segments; the receiver checksums the reassembled frame. Any
        // split of any buffer must agree, including empty segments.
        struct Splits;
        impl Strategy for Splits {
            type Value = (Vec<u8>, Vec<usize>);
            fn gen(&self, rng: &mut Rng) -> (Vec<u8>, Vec<usize>) {
                let n = rng.gen_range(256) as usize;
                let data: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
                let cuts = (0..rng.gen_range(5)).map(|_| rng.gen_range(n as u64 + 1) as usize);
                let mut cuts: Vec<usize> = cuts.collect();
                cuts.sort_unstable();
                (data, cuts)
            }
        }
        check("crc32-parts", &Splits, 300, |(data, cuts)| {
            let mut parts: Vec<&[u8]> = Vec::new();
            let mut at = 0usize;
            for &cut in cuts {
                parts.push(&data[at..cut]);
                at = cut;
            }
            parts.push(&data[at..]);
            let split = crc32_parts(&parts);
            let whole = crc32(data);
            if split != whole {
                return Err(format!("{split:#010x} != {whole:#010x} cuts={cuts:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn frames_detect_corruption() {
        let framed = frame(b"hello weips");
        let (payload, used) = unframe(&framed).unwrap().unwrap();
        assert_eq!(payload, b"hello weips");
        assert_eq!(used, framed.len());
        // Flip a payload bit.
        let mut bad = framed.clone();
        bad[10] ^= 1;
        assert!(unframe(&bad).is_err());
        // Truncated -> needs more bytes.
        assert!(unframe(&framed[..framed.len() - 1]).unwrap().is_none());
        assert!(unframe(&framed[..4]).unwrap().is_none());
    }

    #[test]
    fn varint_max_length_and_overflow() {
        // u64::MAX is exactly 10 bytes; an 11th continuation byte (or a
        // 10th byte carrying bits past 2^64) must error, not wrap.
        let mut w = Writer::new();
        w.put_varint(u64::MAX);
        let max = w.into_bytes();
        assert_eq!(max.len(), 10);
        assert_eq!(Reader::new(&max).get_varint().unwrap(), u64::MAX);
        // 10 continuation bytes then a terminator: 11-byte varint.
        let mut overlong = vec![0x80u8; 10];
        overlong.push(0x01);
        assert!(Reader::new(&overlong).get_varint().is_err());
        // Truncated max-length varint (all continuation, no terminator).
        assert!(Reader::new(&max[..9]).get_varint().is_err());
    }

    #[test]
    fn u64_slice_delta_round_trips_unsorted_and_extremes() {
        let cases: Vec<Vec<u64>> = vec![
            vec![],
            vec![0],
            vec![u64::MAX],
            vec![u64::MAX, 0, u64::MAX, 1, u64::MAX - 1],
            vec![5, 4, 3, 2, 1, 0],
            vec![7; 16],
            (0..500u64).map(|i| i * 37 + 3).collect(),
        ];
        for ids in &cases {
            let mut w = Writer::new();
            w.put_u64_slice(ids);
            let bytes = w.into_bytes();
            let mut r = Reader::new(&bytes);
            assert_eq!(&r.get_u64_slice().unwrap(), ids);
            assert!(r.is_done());
        }
        // Sorted ids are the dense case the delta encoding exists for:
        // consecutive small deltas take ~1 byte each versus up to 10.
        let sorted: Vec<u64> = (1_000_000_000..1_000_001_000u64).collect();
        let mut w = Writer::new();
        w.put_u64_slice(&sorted);
        let delta_len = w.len();
        assert!(
            delta_len < 1 + 5 + 2 * sorted.len(),
            "sorted ids encoded poorly: {delta_len} bytes"
        );
    }

    #[test]
    fn prop_u64_slice_delta_round_trips() {
        check("u64-slice-delta", &VecOf(U64Range(0, u64::MAX - 1), 64), 300, |ids| {
            let mut w = Writer::new();
            w.put_u64_slice(ids);
            let bytes = w.into_bytes();
            let mut r = Reader::new(&bytes);
            let got = r.get_u64_slice().map_err(|e| e.to_string())?;
            if &got != ids {
                return Err(format!("{got:?} != {ids:?}"));
            }
            if !r.is_done() {
                return Err("trailing bytes".into());
            }
            Ok(())
        });
    }

    #[test]
    fn finish_frame_matches_frame() {
        let payload = b"in-place framing";
        let boxed = frame(payload);
        let mut inplace = vec![0u8; 8];
        inplace.extend_from_slice(payload);
        finish_frame(&mut inplace);
        assert_eq!(inplace, boxed);
        let (p, used) = unframe(&inplace).unwrap().unwrap();
        assert_eq!(p, payload);
        assert_eq!(used, inplace.len());
    }

    #[test]
    fn prop_varint_round_trips() {
        check("varint-roundtrip", &VecOf(U64Range(0, u64::MAX - 1), 64), 300, |vals| {
            let mut w = Writer::new();
            for v in vals {
                w.put_varint(*v);
            }
            let bytes = w.into_bytes();
            let mut r = Reader::new(&bytes);
            for v in vals {
                let got = r.get_varint().map_err(|e| e.to_string())?;
                if got != *v {
                    return Err(format!("{got} != {v}"));
                }
            }
            if !r.is_done() {
                return Err("trailing bytes".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_frames_split_at_any_boundary() {
        // Streaming reassembly: any prefix is either Ok(None) or the frame.
        struct Payload;
        impl Strategy for Payload {
            type Value = Vec<u8>;
            fn gen(&self, rng: &mut Rng) -> Vec<u8> {
                let n = rng.gen_range(64) as usize;
                (0..n).map(|_| rng.next_u64() as u8).collect()
            }
        }
        check("frame-prefix", &Payload, 200, |payload| {
            let framed = frame(payload);
            for cut in 0..framed.len() {
                match unframe(&framed[..cut]) {
                    Ok(None) => {}
                    Ok(Some(_)) => return Err(format!("complete at cut {cut}")),
                    Err(e) => return Err(format!("error at cut {cut}: {e}")),
                }
            }
            let (p, used) = unframe(&framed).map_err(|e| e.to_string())?.ok_or("incomplete")?;
            if p != payload.as_slice() || used != framed.len() {
                return Err("mismatch".into());
            }
            Ok(())
        });
    }
}
