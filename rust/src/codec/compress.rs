//! LZ compression for sync payloads and checkpoints (§4.1.3).
//!
//! The pusher compresses aggregated update batches before queueing them;
//! whether that pays depends on payload entropy, so [`maybe_compress`]
//! keeps the raw bytes when compression does not help (a 1-byte header
//! records the choice). No flate2 in the offline build environment, so the
//! codec is an in-repo LZSS: greedy hash-chain matching over a 64 KiB
//! window, literal runs and `(length, distance)` copies. Sync batches
//! interleave small varint ids with low-entropy f32 state, which this
//! scheme typically shrinks 25–60 %.
//!
//! Wire format (after the 1-byte [`maybe_compress`] envelope):
//!
//! ```text
//!   varint uncompressed_len
//!   token*:  0x00..=0x7F  -> literal run of (token + 1) bytes
//!            0x80..=0xFF  -> match: len = (token & 0x7F) + 4,
//!                            then u16 LE distance in [1, 65535]
//! ```

use crate::{Error, Result};

/// How a payload was encoded (first byte of the envelope).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressMode {
    /// Stored raw.
    None = 0,
    /// LZSS-compressed.
    Lz = 1,
}

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = 131; // MIN_MATCH + 0x7F
const MAX_DIST: usize = 65_535;
const MAX_LITERAL_RUN: usize = 128;
/// Hash-chain probes per position; bounds worst-case encode cost while
/// still finding the long-period matches sync payloads are full of.
const MAX_CHAIN: usize = 256;
const HASH_BITS: u32 = 15;

#[inline]
fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

#[inline]
fn get_varint(data: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&b) = data.get(*pos) else {
            return Err(Error::Codec("lz: truncated varint".into()));
        };
        *pos += 1;
        if shift >= 64 {
            return Err(Error::Codec("lz: varint overflow".into()));
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

#[inline]
fn hash4(data: &[u8], pos: usize) -> usize {
    let v = u32::from_le_bytes([data[pos], data[pos + 1], data[pos + 2], data[pos + 3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

fn flush_literals(out: &mut Vec<u8>, data: &[u8], start: usize, end: usize) {
    let mut at = start;
    while at < end {
        let take = (end - at).min(MAX_LITERAL_RUN);
        out.push((take - 1) as u8);
        out.extend_from_slice(&data[at..at + take]);
        at += take;
    }
}

/// Reusable hash-chain tables for [`compress_into`]: ~768 KiB that the
/// pusher keeps warm across batches, so steady-state compression
/// performs zero heap allocations.
#[derive(Default)]
pub struct LzState {
    head: Vec<usize>,
    prev: Vec<usize>,
}

impl LzState {
    /// Empty state (tables materialize on first use).
    pub fn new() -> LzState {
        LzState::default()
    }
}

/// LZSS-compress `data` (no envelope).
pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    compress_into(data, &mut out, &mut LzState::new());
    out
}

/// LZSS-compress `data`, appending to `out` (not cleared — envelope
/// writers put their mode byte first) and reusing `state`'s tables.
///
/// Memory is constant regardless of input size: the chain table is a
/// 64 Ki ring keyed by `pos & (MAX_DIST)` — safe because any candidate
/// whose ring slot has been overwritten is necessarily more than
/// `MAX_DIST` behind the cursor and thus outside the match window anyway.
pub fn compress_into(data: &[u8], out: &mut Vec<u8>, state: &mut LzState) {
    const RING: usize = MAX_DIST + 1; // 64 Ki, power of two
    out.reserve(data.len() / 2 + 16);
    put_varint(out, data.len() as u64);
    if data.is_empty() {
        return;
    }
    state.head.clear();
    state.head.resize(1 << HASH_BITS, usize::MAX);
    state.prev.clear();
    state.prev.resize(RING, usize::MAX);
    let head = &mut state.head;
    let prev = &mut state.prev;
    let mut literal_start = 0usize;
    let mut pos = 0usize;
    while pos < data.len() {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if pos + MIN_MATCH <= data.len() {
            let h = hash4(data, pos);
            let mut candidate = head[h];
            let mut probes = 0;
            let limit = (data.len() - pos).min(MAX_MATCH);
            while candidate != usize::MAX && probes < MAX_CHAIN {
                let dist = pos - candidate;
                if dist > MAX_DIST {
                    break;
                }
                let mut len = 0usize;
                while len < limit && data[candidate + len] == data[pos + len] {
                    len += 1;
                }
                if len > best_len {
                    best_len = len;
                    best_dist = dist;
                    if len >= limit {
                        break;
                    }
                }
                let next = prev[candidate % RING];
                // Ring entries must walk strictly backwards; anything else
                // is a stale slot from a position that aged out.
                if next == usize::MAX || next >= candidate {
                    break;
                }
                candidate = next;
                probes += 1;
            }
        }
        if best_len >= MIN_MATCH {
            flush_literals(out, data, literal_start, pos);
            out.push(0x80 | (best_len - MIN_MATCH) as u8);
            out.extend_from_slice(&(best_dist as u16).to_le_bytes());
            // Index every covered position so future matches can land here.
            let end = pos + best_len;
            while pos < end {
                if pos + MIN_MATCH <= data.len() {
                    let h = hash4(data, pos);
                    prev[pos % RING] = head[h];
                    head[h] = pos;
                }
                pos += 1;
            }
            literal_start = pos;
        } else {
            if pos + MIN_MATCH <= data.len() {
                let h = hash4(data, pos);
                prev[pos % RING] = head[h];
                head[h] = pos;
            }
            pos += 1;
        }
    }
    flush_literals(out, data, literal_start, data.len());
}

/// Inverse of [`compress`].
pub fn decompress_raw(data: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    decompress_raw_into(data, &mut out)?;
    Ok(out)
}

/// Inverse of [`compress_into`]: decode into `out` (cleared first, so the
/// scatter worker reuses one buffer across every record it consumes).
pub fn decompress_raw_into(data: &[u8], out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    let mut pos = 0usize;
    let declared = get_varint(data, &mut pos)? as usize;
    // Guard hostile lengths: output can never exceed what literal runs and
    // max-rate matches could produce from the remaining input.
    if declared > (data.len().saturating_sub(pos)) * (MAX_MATCH + 1) {
        return Err(Error::Codec(format!("lz: declared length {declared} exceeds input budget")));
    }
    // Cap the up-front reservation: `declared` is attacker-controlled up
    // to ~132x the input, so reserve modestly and let decoding grow the
    // vec as tokens actually validate.
    out.reserve(declared.min(1 << 20));
    while pos < data.len() {
        let token = data[pos];
        pos += 1;
        if token < 0x80 {
            let run = token as usize + 1;
            if pos + run > data.len() {
                return Err(Error::Codec("lz: truncated literal run".into()));
            }
            out.extend_from_slice(&data[pos..pos + run]);
            pos += run;
        } else {
            let len = (token & 0x7F) as usize + MIN_MATCH;
            if pos + 2 > data.len() {
                return Err(Error::Codec("lz: truncated match".into()));
            }
            let dist = u16::from_le_bytes([data[pos], data[pos + 1]]) as usize;
            pos += 2;
            if dist == 0 || dist > out.len() {
                return Err(Error::Codec(format!(
                    "lz: match distance {dist} outside window of {}",
                    out.len()
                )));
            }
            // Byte-by-byte copy: distances shorter than the length overlap
            // (run-length style) on purpose.
            let from = out.len() - dist;
            for i in 0..len {
                let b = out[from + i];
                out.push(b);
            }
        }
        if out.len() > declared {
            return Err(Error::Codec(format!(
                "lz: output {} exceeds declared length {declared}",
                out.len()
            )));
        }
    }
    if out.len() != declared {
        return Err(Error::Codec(format!(
            "lz: output {} != declared length {declared}",
            out.len()
        )));
    }
    Ok(())
}

/// Envelope-encode: compress if it actually shrinks the payload, else store.
pub fn maybe_compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    maybe_compress_into(data, &mut out, &mut LzState::new());
    out
}

/// [`maybe_compress`] into a reusable buffer with reusable LZ tables —
/// the pusher's zero-allocation steady state. `out` is cleared first and
/// receives the 1-byte mode envelope + payload; the choice of mode is
/// identical to [`maybe_compress`].
pub fn maybe_compress_into(data: &[u8], out: &mut Vec<u8>, state: &mut LzState) {
    out.clear();
    out.push(CompressMode::Lz as u8);
    compress_into(data, out, state);
    // Keep LZ only when the envelope actually shrank: out.len() is
    // packed + 1, so this is the original `packed + 1 < data.len()` test.
    if out.len() >= data.len() {
        out.clear();
        out.push(CompressMode::None as u8);
        out.extend_from_slice(data);
    }
}

/// Decode a [`maybe_compress`] envelope.
pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    decompress_into(data, &mut out)?;
    Ok(out)
}

/// Decode a [`maybe_compress`] envelope into a reusable buffer (cleared
/// first) — the scatter worker's per-record decode path allocates nothing
/// once the buffer has grown to the working set.
pub fn decompress_into(data: &[u8], out: &mut Vec<u8>) -> Result<()> {
    let (&mode, rest) = data
        .split_first()
        .ok_or_else(|| Error::Codec("empty compressed envelope".into()))?;
    match mode {
        m if m == CompressMode::None as u8 => {
            out.clear();
            out.extend_from_slice(rest);
            Ok(())
        }
        m if m == CompressMode::Lz as u8 => decompress_raw_into(rest, out),
        m => Err(Error::Codec(format!("unknown compress mode {m}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_compressible() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 16) as u8).collect();
        let env = maybe_compress(&data);
        assert!(env.len() < data.len(), "should compress: {} vs {}", env.len(), data.len());
        assert_eq!(env[0], CompressMode::Lz as u8);
        assert_eq!(decompress(&env).unwrap(), data);
    }

    #[test]
    fn round_trip_incompressible() {
        // Pseudo-random bytes don't compress; envelope must fall back to raw.
        let mut state = 0x12345u64;
        let data: Vec<u8> = (0..4096)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                (state >> 33) as u8
            })
            .collect();
        let env = maybe_compress(&data);
        assert_eq!(env[0], CompressMode::None as u8);
        assert_eq!(env.len(), data.len() + 1);
        assert_eq!(decompress(&env).unwrap(), data);
    }

    #[test]
    fn empty_payload() {
        let env = maybe_compress(&[]);
        assert_eq!(decompress(&env).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn rejects_bad_envelope() {
        assert!(decompress(&[]).is_err());
        assert!(decompress(&[9, 1, 2]).is_err());
        // Mode=lz with garbage body.
        assert!(decompress(&[1, 0xde, 0xad]).is_err());
    }

    #[test]
    fn raw_round_trips_overlapping_matches() {
        // Long single-byte runs force dist < len overlapped copies.
        let mut data = vec![7u8; 1000];
        data.extend_from_slice(b"tail-entropy-0123456789");
        let packed = compress(&data);
        assert!(packed.len() < 64, "run-length case stayed large: {}", packed.len());
        assert_eq!(decompress_raw(&packed).unwrap(), data);
    }

    #[test]
    fn truncated_streams_error_not_panic() {
        let data: Vec<u8> = (0..500u32).map(|i| (i % 7) as u8).collect();
        let packed = compress(&data);
        for cut in 0..packed.len() {
            let _ = decompress_raw(&packed[..cut]); // must not panic
        }
        assert!(decompress_raw(&packed[..packed.len() - 1]).is_err());
    }

    #[test]
    fn into_variants_match_allocating_paths_and_reuse_buffers() {
        let data: Vec<u8> = (0..30_000u32).map(|i| (i % 23) as u8).collect();
        let mut state = LzState::new();
        let mut wire = Vec::new();
        let mut raw = Vec::new();
        // Same buffers + state across payloads of shrinking size: stale
        // content must never leak into a later (shorter) result.
        for cut in [data.len(), 10_000, 257, 16, 1, 0] {
            let payload = &data[..cut];
            maybe_compress_into(payload, &mut wire, &mut state);
            assert_eq!(wire, maybe_compress(payload), "envelope diverged at cut {cut}");
            decompress_into(&wire, &mut raw).unwrap();
            assert_eq!(&raw, payload, "round trip diverged at cut {cut}");
        }
    }

    #[test]
    fn prop_decompress_into_rejects_truncation_and_garbage() {
        use crate::util::prop::{check, Strategy};
        use crate::util::Rng;
        struct Payload;
        impl Strategy for Payload {
            type Value = Vec<u8>;
            fn gen(&self, rng: &mut Rng) -> Vec<u8> {
                let n = rng.gen_range(2_000) as usize;
                // Mildly repetitive so the Lz arm is actually exercised.
                (0..n).map(|i| ((rng.next_u64() >> 7) as u8) % 7 + (i % 3) as u8).collect()
            }
        }
        let mut scratch = Vec::new();
        check("decompress-into-hostile", &Payload, 60, |payload| {
            let env = maybe_compress(payload);
            // Every strict prefix must error (or, for the stored mode,
            // yield a shorter payload — never panic or over-read).
            for cut in 1..env.len() {
                match decompress_into(&env[..cut], &mut scratch) {
                    Ok(()) => {
                        if env[0] == CompressMode::Lz as u8 {
                            return Err(format!("lz prefix {cut} decoded"));
                        }
                    }
                    Err(_) => {}
                }
            }
            // Bit flips in the body must never panic; flips in the Lz
            // stream may decode to garbage only if lengths still agree.
            let mut bad = env.clone();
            if bad.len() > 1 {
                let at = 1 + (payload.len() % (bad.len() - 1));
                bad[at] ^= 0x40;
                let _ = decompress_into(&bad, &mut scratch);
            }
            // Unknown envelope modes are rejected outright.
            if decompress_into(&[9, 1, 2, 3], &mut scratch).is_ok() {
                return Err("unknown mode accepted".into());
            }
            // And the buffer still round-trips clean input afterwards.
            decompress_into(&env, &mut scratch).map_err(|e| e.to_string())?;
            if &scratch != payload {
                return Err("reused buffer corrupted a clean decode".into());
            }
            Ok(())
        });
    }

    #[test]
    fn sync_record_like_payload_shrinks() {
        // A realistic sync batch interleaves ids (small varints / zeros in
        // the high bytes) with f32 state; the id structure alone should
        // give the LZ window a clear win.
        let mut bytes = Vec::new();
        for i in 0..2048u64 {
            bytes.extend_from_slice(&(i * 37).to_le_bytes());
            let g = ((i % 97) as f32) * 0.01;
            bytes.extend_from_slice(&g.to_le_bytes());
        }
        let env = maybe_compress(&bytes);
        assert!(
            env.len() < bytes.len() * 3 / 4,
            "sync payload compressed poorly: {} / {}",
            env.len(),
            bytes.len()
        );
    }
}
