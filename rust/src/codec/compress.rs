//! Deflate compression for sync payloads and checkpoints (§4.1.3).
//!
//! The pusher compresses aggregated update batches before queueing them;
//! whether that pays depends on payload entropy, so [`maybe_compress`]
//! keeps the raw bytes when deflate does not help (a 1-byte header records
//! the choice). Gradients/weights are low-entropy enough in the exponent
//! bits that real batches typically shrink 25–60 %.

use std::io::{Read, Write};

use crate::{Error, Result};

/// How a payload was encoded (first byte of the envelope).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressMode {
    /// Stored raw.
    None = 0,
    /// Deflate-compressed.
    Deflate = 1,
}

/// Deflate-compress `data` (no envelope).
pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut enc = flate2::write::DeflateEncoder::new(
        Vec::with_capacity(data.len() / 2 + 16),
        flate2::Compression::fast(),
    );
    enc.write_all(data).expect("vec write");
    enc.finish().expect("deflate finish")
}

/// Inverse of [`compress`].
pub fn decompress_raw(data: &[u8]) -> Result<Vec<u8>> {
    let mut dec = flate2::read::DeflateDecoder::new(data);
    let mut out = Vec::with_capacity(data.len() * 2 + 16);
    dec.read_to_end(&mut out)
        .map_err(|e| Error::Codec(format!("deflate: {e}")))?;
    Ok(out)
}

/// Envelope-encode: compress if it actually shrinks the payload, else store.
pub fn maybe_compress(data: &[u8]) -> Vec<u8> {
    let packed = compress(data);
    if packed.len() + 1 < data.len() {
        let mut out = Vec::with_capacity(packed.len() + 1);
        out.push(CompressMode::Deflate as u8);
        out.extend_from_slice(&packed);
        out
    } else {
        let mut out = Vec::with_capacity(data.len() + 1);
        out.push(CompressMode::None as u8);
        out.extend_from_slice(data);
        out
    }
}

/// Decode a [`maybe_compress`] envelope.
pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    let (&mode, rest) = data
        .split_first()
        .ok_or_else(|| Error::Codec("empty compressed envelope".into()))?;
    match mode {
        m if m == CompressMode::None as u8 => Ok(rest.to_vec()),
        m if m == CompressMode::Deflate as u8 => decompress_raw(rest),
        m => Err(Error::Codec(format!("unknown compress mode {m}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_compressible() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 16) as u8).collect();
        let env = maybe_compress(&data);
        assert!(env.len() < data.len(), "should compress: {} vs {}", env.len(), data.len());
        assert_eq!(env[0], CompressMode::Deflate as u8);
        assert_eq!(decompress(&env).unwrap(), data);
    }

    #[test]
    fn round_trip_incompressible() {
        // Pseudo-random bytes don't deflate; envelope must fall back to raw.
        let mut state = 0x12345u64;
        let data: Vec<u8> = (0..4096)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                (state >> 33) as u8
            })
            .collect();
        let env = maybe_compress(&data);
        assert_eq!(env[0], CompressMode::None as u8);
        assert_eq!(env.len(), data.len() + 1);
        assert_eq!(decompress(&env).unwrap(), data);
    }

    #[test]
    fn empty_payload() {
        let env = maybe_compress(&[]);
        assert_eq!(decompress(&env).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn rejects_bad_envelope() {
        assert!(decompress(&[]).is_err());
        assert!(decompress(&[9, 1, 2]).is_err());
        // Mode=deflate with garbage body.
        assert!(decompress(&[1, 0xde, 0xad]).is_err());
    }

    #[test]
    fn sync_record_like_payload_shrinks() {
        // A realistic sync batch interleaves ids (small varints / zeros in
        // the high bytes) with f32 state; the id structure alone should
        // give deflate a clear win.
        let mut bytes = Vec::new();
        for i in 0..2048u64 {
            bytes.extend_from_slice(&(i * 37).to_le_bytes());
            let g = ((i % 97) as f32) * 0.01;
            bytes.extend_from_slice(&g.to_le_bytes());
        }
        let env = maybe_compress(&bytes);
        assert!(
            env.len() < bytes.len() * 3 / 4,
            "sync payload compressed poorly: {} / {}",
            env.len(),
            bytes.len()
        );
    }
}
