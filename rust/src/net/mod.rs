//! RPC substrate: length-prefixed, CRC-checked frames over TCP, with an
//! in-process fast path.
//!
//! No async runtime is available offline, so the server is thread-per-
//! connection on top of a [`crate::util::ThreadPool`]-less accept loop
//! (connections are long-lived in a PS deployment: every worker keeps one
//! connection per server shard, so thread-per-conn matches the topology).
//!
//! Wire format per request:  `frame( [req_id u64][method u16][payload] )`
//! and per response:          `frame( [req_id u64][status u8][payload] )`
//! where `frame` adds `[len u32][crc32 u32]` (see [`crate::codec`]).
//!
//! [`Channel`] abstracts "how do I reach this service": `Local` dispatches
//! straight into the service object (the all-in-one `LocalCluster` mode and
//! most tests), `Remote` talks TCP. Components only ever hold `Channel`s,
//! so the same coordinator code runs single-process or distributed.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::codec::{frame, unframe};
use crate::{Error, Result};

/// Maximum frame payload (guards allocation on hostile/corrupt input).
pub const MAX_FRAME: usize = 256 << 20;

/// Status byte on responses.
const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;

/// A dispatchable service: maps (method, payload) -> payload.
pub trait Service: Send + Sync {
    /// Handle one request.
    fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>>;
}

impl<F> Service for F
where
    F: Fn(u16, &[u8]) -> Result<Vec<u8>> + Send + Sync,
{
    fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        self(method, payload)
    }
}

// ---------------------------------------------------------------------------
// Framed stream I/O
// ---------------------------------------------------------------------------

/// Read exactly one frame from a stream (blocking).
fn read_frame(stream: &mut TcpStream, scratch: &mut Vec<u8>) -> Result<Vec<u8>> {
    let mut header = [0u8; 8];
    stream.read_exact(&mut header)?;
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(Error::Codec(format!("frame length {len} exceeds max")));
    }
    scratch.clear();
    scratch.resize(8 + len, 0);
    scratch[..8].copy_from_slice(&header);
    stream.read_exact(&mut scratch[8..])?;
    match unframe(scratch)? {
        Some((payload, _)) => Ok(payload.to_vec()),
        None => Err(Error::Codec("incomplete frame after read".into())),
    }
}

fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    let framed = frame(payload);
    stream.write_all(&framed)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Running RPC server; dropping it stops the accept loop.
pub struct RpcServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl RpcServer {
    /// Bind `addr` (use port 0 for ephemeral) and serve `service`.
    pub fn serve(addr: &str, service: Arc<dyn Service>) -> Result<RpcServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        listener.set_nonblocking(true)?;
        let accept_thread = std::thread::Builder::new()
            .name(format!("rpc-accept-{local}"))
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let svc = service.clone();
                            let stop3 = stop2.clone();
                            let _ = std::thread::Builder::new()
                                .name("rpc-conn".into())
                                .spawn(move || Self::conn_loop(stream, svc, stop3));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn accept loop");
        Ok(RpcServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// Bound address (resolves ephemeral ports).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting; existing connections close on their next poll.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
    }

    fn conn_loop(mut stream: TcpStream, service: Arc<dyn Service>, stop: Arc<AtomicBool>) {
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(200)));
        let mut scratch = Vec::new();
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            let req = match read_frame(&mut stream, &mut scratch) {
                Ok(r) => r,
                Err(Error::Io(e))
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    continue; // poll for shutdown, then keep reading
                }
                Err(_) => return, // disconnect or corrupt stream
            };
            if req.len() < 10 {
                return;
            }
            let req_id = u64::from_le_bytes(req[0..8].try_into().unwrap());
            let method = u16::from_le_bytes(req[8..10].try_into().unwrap());
            let payload = &req[10..];
            let mut resp = Vec::with_capacity(32);
            resp.extend_from_slice(&req_id.to_le_bytes());
            match service.call(method, payload) {
                Ok(body) => {
                    resp.push(STATUS_OK);
                    resp.extend_from_slice(&body);
                }
                Err(e) => {
                    resp.push(STATUS_ERR);
                    resp.extend_from_slice(e.to_string().as_bytes());
                }
            }
            if write_frame(&mut stream, &resp).is_err() {
                return;
            }
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

struct ClientInner {
    stream: Option<TcpStream>,
    scratch: Vec<u8>,
}

/// Blocking RPC client with automatic reconnect. One in-flight request per
/// client; callers needing concurrency hold a pool of clients (the
/// WeiPS-client does exactly that, see `worker::client`).
pub struct RpcClient {
    addr: String,
    timeout: std::time::Duration,
    next_id: AtomicU64,
    inner: Mutex<ClientInner>,
}

impl RpcClient {
    /// Create a client for `addr` (connection is established lazily).
    pub fn new(addr: &str, timeout: std::time::Duration) -> RpcClient {
        RpcClient {
            addr: addr.to_string(),
            timeout,
            next_id: AtomicU64::new(1),
            inner: Mutex::new(ClientInner { stream: None, scratch: Vec::new() }),
        }
    }

    fn ensure_conn(&self, inner: &mut ClientInner) -> Result<()> {
        if inner.stream.is_none() {
            let stream = TcpStream::connect(&self.addr)
                .map_err(|e| Error::Rpc(format!("connect {}: {e}", self.addr)))?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(self.timeout))?;
            stream.set_write_timeout(Some(self.timeout))?;
            inner.stream = Some(stream);
        }
        Ok(())
    }

    /// Issue one request and wait for its response.
    pub fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        let req_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        self.ensure_conn(&mut inner)?;

        let mut req = Vec::with_capacity(payload.len() + 10);
        req.extend_from_slice(&req_id.to_le_bytes());
        req.extend_from_slice(&method.to_le_bytes());
        req.extend_from_slice(payload);

        let outcome = (|| -> Result<Vec<u8>> {
            let stream = inner.stream.as_mut().unwrap();
            write_frame(stream, &req)?;
            // A slow server may interleave read timeouts; retry until the
            // client-level deadline elapses.
            let deadline = std::time::Instant::now() + self.timeout;
            loop {
                let mut scratch = std::mem::take(&mut inner.scratch);
                let stream = inner.stream.as_mut().unwrap();
                let r = read_frame(stream, &mut scratch);
                inner.scratch = scratch;
                match r {
                    Ok(resp) => {
                        if resp.len() < 9 {
                            return Err(Error::Rpc("short response".into()));
                        }
                        let rid = u64::from_le_bytes(resp[0..8].try_into().unwrap());
                        if rid != req_id {
                            return Err(Error::Rpc(format!("response id {rid} != {req_id}")));
                        }
                        let status = resp[8];
                        let body = resp[9..].to_vec();
                        return if status == STATUS_OK {
                            Ok(body)
                        } else {
                            Err(Error::Rpc(String::from_utf8_lossy(&body).into_owned()))
                        };
                    }
                    Err(Error::Io(e))
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) && std::time::Instant::now() < deadline =>
                    {
                        continue;
                    }
                    Err(e) => return Err(e),
                }
            }
        })();

        if outcome.is_err() {
            // Drop the (possibly desynchronized) connection; next call dials.
            inner.stream = None;
        }
        outcome
    }
}

// ---------------------------------------------------------------------------
// Channel: local or remote
// ---------------------------------------------------------------------------

/// How to reach a service: in-process or over TCP.
#[derive(Clone)]
pub enum Channel {
    /// Direct dispatch into the service object.
    Local(Arc<dyn Service>),
    /// TCP RPC.
    Remote(Arc<RpcClient>),
}

impl Channel {
    /// Local channel to `svc`.
    pub fn local(svc: Arc<dyn Service>) -> Channel {
        Channel::Local(svc)
    }

    /// Remote channel to `addr`.
    pub fn remote(addr: &str, timeout: std::time::Duration) -> Channel {
        Channel::Remote(Arc::new(RpcClient::new(addr, timeout)))
    }

    /// Issue a request.
    pub fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        match self {
            Channel::Local(svc) => svc.call(method, payload),
            Channel::Remote(client) => client.call(method, payload),
        }
    }
}

impl std::fmt::Debug for Channel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Channel::Local(_) => write!(f, "Channel::Local"),
            Channel::Remote(_) => write!(f, "Channel::Remote"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;

    impl Service for Echo {
        fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
            match method {
                0 => Ok(payload.to_vec()),
                1 => Ok(payload.iter().rev().copied().collect()),
                9 => Err(Error::Unavailable("degraded".into())),
                _ => Err(Error::Rpc(format!("no method {method}"))),
            }
        }
    }

    fn timeout() -> std::time::Duration {
        std::time::Duration::from_secs(5)
    }

    #[test]
    fn local_channel_dispatches() {
        let ch = Channel::local(Arc::new(Echo));
        assert_eq!(ch.call(0, b"hi").unwrap(), b"hi");
        assert_eq!(ch.call(1, b"abc").unwrap(), b"cba");
        assert!(ch.call(9, b"").is_err());
    }

    #[test]
    fn tcp_round_trip() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let ch = Channel::remote(&server.addr().to_string(), timeout());
        assert_eq!(ch.call(0, b"hello").unwrap(), b"hello");
        assert_eq!(ch.call(1, b"xyz").unwrap(), b"zyx");
    }

    #[test]
    fn tcp_error_propagates_and_connection_survives() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let ch = Channel::remote(&server.addr().to_string(), timeout());
        let err = ch.call(9, b"").unwrap_err();
        assert!(err.to_string().contains("degraded"), "{err}");
        // Same connection still usable after an application error.
        assert_eq!(ch.call(0, b"ok").unwrap(), b"ok");
    }

    #[test]
    fn tcp_large_payload() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let ch = Channel::remote(&server.addr().to_string(), timeout());
        let big: Vec<u8> = (0..2_000_000u32).map(|i| i as u8).collect();
        assert_eq!(ch.call(0, &big).unwrap(), big);
    }

    #[test]
    fn many_sequential_calls() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let client = RpcClient::new(&server.addr().to_string(), timeout());
        for i in 0..200u32 {
            let payload = i.to_le_bytes();
            assert_eq!(client.call(0, &payload).unwrap(), payload);
        }
    }

    #[test]
    fn concurrent_clients() {
        let server = Arc::new(RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap());
        let addr = server.addr().to_string();
        let mut handles = Vec::new();
        for t in 0..4u8 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let client = RpcClient::new(&addr, timeout());
                for i in 0..50u32 {
                    let payload = [t, i as u8];
                    assert_eq!(client.call(1, &payload).unwrap(), [i as u8, t]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn connect_refused_is_error_then_reconnects() {
        // Pick a port by binding+dropping a listener.
        let tmp = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = tmp.local_addr().unwrap().to_string();
        drop(tmp);
        let client = RpcClient::new(&addr, timeout());
        assert!(client.call(0, b"x").is_err());
        // Now start a real server on that address; client should reconnect.
        let _server = match RpcServer::serve(&addr, Arc::new(Echo)) {
            Ok(s) => s,
            Err(_) => return, // port grabbed by another process; skip rest
        };
        assert_eq!(client.call(0, b"x").unwrap(), b"x");
    }

    #[test]
    fn shutdown_stops_accepting() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let addr = server.addr().to_string();
        server.shutdown();
        std::thread::sleep(std::time::Duration::from_millis(30));
        let client = RpcClient::new(&addr, std::time::Duration::from_millis(300));
        // Either connect fails or the read times out — must error out.
        assert!(client.call(0, b"x").is_err());
    }
}
