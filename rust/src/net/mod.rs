//! RPC substrate: length-prefixed, CRC-checked frames over TCP, with an
//! in-process fast path.
//!
//! No async runtime is available offline, so the server runs a fixed
//! [`crate::util::ThreadPool`] behind a readiness-polling connection loop:
//! the accept thread keeps every idle connection in a parked set and
//! sweeps it with non-blocking peeks; a connection with bytes pending is
//! handed to a pool worker, which drains the requests already queued on
//! it and parks it again. A fleet of workers fanning into one shard
//! therefore costs `rpc_threads` handler threads total (plus the accept/
//! poll thread) instead of one thread per connection
//! (`WEIPS_RPC_THREADS` / the cluster config's `rpc_threads` knob).
//!
//! Wire format per request:  `frame( [req_id u64][method u16][payload] )`
//! and per response:          `frame( [req_id u64][status u8][payload] )`
//! where `frame` adds `[len u32][crc32 u32]` (see [`crate::codec`]).
//!
//! [`Channel`] abstracts "how do I reach this service": `Local` dispatches
//! straight into the service object (the all-in-one `LocalCluster` mode and
//! most tests), `Remote` talks TCP. Components only ever hold `Channel`s,
//! so the same coordinator code runs single-process or distributed.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::codec::{frame, unframe};
use crate::util::ThreadPool;
use crate::{Error, Result};

/// Maximum frame payload (guards allocation on hostile/corrupt input).
pub const MAX_FRAME: usize = 256 << 20;

/// Status byte on responses.
const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;

/// Handler threads per RPC server when no explicit count is given
/// (`WEIPS_RPC_THREADS` overrides; the cluster config's `rpc_threads`
/// knob wins where a config is present).
pub fn default_rpc_threads() -> usize {
    use std::sync::OnceLock;
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("WEIPS_RPC_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(8)
    })
}

/// A dispatchable service: maps (method, payload) -> payload.
pub trait Service: Send + Sync {
    /// Handle one request.
    fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>>;
}

impl<F> Service for F
where
    F: Fn(u16, &[u8]) -> Result<Vec<u8>> + Send + Sync,
{
    fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        self(method, payload)
    }
}

// ---------------------------------------------------------------------------
// Framed stream I/O
// ---------------------------------------------------------------------------

/// Read exactly one frame from a stream (blocking). The payload is left in
/// `scratch` and its byte range returned — no intermediate copy; callers
/// borrow `&scratch[range]` (and copy only what they keep).
fn read_frame(
    stream: &mut TcpStream,
    scratch: &mut Vec<u8>,
) -> Result<std::ops::Range<usize>> {
    let mut header = [0u8; 8];
    stream.read_exact(&mut header)?;
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(Error::Codec(format!("frame length {len} exceeds max")));
    }
    scratch.clear();
    scratch.resize(8 + len, 0);
    scratch[..8].copy_from_slice(&header);
    stream.read_exact(&mut scratch[8..])?;
    match unframe(scratch)? {
        Some((_, consumed)) => Ok(8..consumed),
        None => Err(Error::Codec("incomplete frame after read".into())),
    }
}

fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    let framed = frame(payload);
    stream.write_all(&framed)?;
    Ok(())
}

/// A handler-pool worker never waits on one peer's socket longer than
/// this: a connection that stalls mid-frame (or refuses our writes) is
/// dropped and its worker reclaimed, so slow/hung clients cannot pin the
/// fixed pool. Generous next to a healthy peer's packet gaps (micro- to
/// milliseconds) — tripping it means the peer is effectively gone.
const IO_STALL_LIMIT: std::time::Duration = std::time::Duration::from_secs(10);

/// Nap between non-blocking I/O retries; abort on shutdown or when the
/// peer has stalled past `deadline`.
fn nap_or_abort(stop: &AtomicBool, deadline: std::time::Instant, what: &str) -> Result<()> {
    if stop.load(Ordering::Acquire) {
        return Err(Error::Rpc("server shutting down".into()));
    }
    if std::time::Instant::now() >= deadline {
        return Err(Error::Rpc(format!("peer stalled {what}")));
    }
    std::thread::sleep(std::time::Duration::from_micros(200));
    Ok(())
}

/// Read one frame from a non-blocking stream. `Ok(None)` means no request
/// has started (first header byte would block) — the caller parks the
/// connection back into the poll set. Once a frame is underway, short
/// naps bridge the gaps between the peer's packets, bounded by
/// [`IO_STALL_LIMIT`]; `stop` aborts.
fn read_frame_nonblocking(
    stream: &mut TcpStream,
    scratch: &mut Vec<u8>,
    stop: &AtomicBool,
) -> Result<Option<std::ops::Range<usize>>> {
    let deadline = std::time::Instant::now() + IO_STALL_LIMIT;
    let mut header = [0u8; 8];
    let mut got = 0usize;
    while got < 8 {
        match stream.read(&mut header[got..]) {
            Ok(0) => return Err(Error::Rpc("peer closed".into())),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if got == 0 {
                    return Ok(None); // idle connection: no request pending
                }
                nap_or_abort(stop, deadline, "mid-header")?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(Error::Codec(format!("frame length {len} exceeds max")));
    }
    scratch.clear();
    scratch.resize(8 + len, 0);
    scratch[..8].copy_from_slice(&header);
    let mut got = 8;
    while got < 8 + len {
        match stream.read(&mut scratch[got..]) {
            Ok(0) => return Err(Error::Rpc("peer closed mid-frame".into())),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                nap_or_abort(stop, deadline, "mid-frame")?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    match unframe(scratch)? {
        Some((_, consumed)) => Ok(Some(8..consumed)),
        None => Err(Error::Codec("incomplete frame after read".into())),
    }
}

/// Write all of `bytes` to a non-blocking stream (napping through a full
/// socket buffer, bounded by [`IO_STALL_LIMIT`]; `stop` aborts).
fn write_all_nonblocking(stream: &mut TcpStream, bytes: &[u8], stop: &AtomicBool) -> Result<()> {
    let deadline = std::time::Instant::now() + IO_STALL_LIMIT;
    let mut off = 0usize;
    while off < bytes.len() {
        match stream.write(&bytes[off..]) {
            Ok(0) => return Err(Error::Rpc("peer closed on write".into())),
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                nap_or_abort(stop, deadline, "on write")?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Running RPC server: a fixed handler pool fed by a readiness-polling
/// accept/poll thread. Dropping it stops the loop, joins the accept
/// thread and drains the pool ([`Drop`] below — tests cannot leak accept
/// loops or handler threads).
pub struct RpcServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Handler pool; `Some` until drop. Dropped after the accept thread
    /// joins so no task can be submitted to a dead pool.
    pool: Option<Arc<ThreadPool>>,
    /// Parked (idle) connections awaiting readiness.
    parked: Arc<Mutex<Vec<TcpStream>>>,
}

impl RpcServer {
    /// Bind `addr` (use port 0 for ephemeral) and serve `service` on
    /// [`default_rpc_threads`] handler threads.
    pub fn serve(addr: &str, service: Arc<dyn Service>) -> Result<RpcServer> {
        Self::serve_pooled(addr, service, default_rpc_threads())
    }

    /// Bind `addr` and serve `service` on a fixed pool of `threads`
    /// handler threads (the cluster config's `rpc_threads` knob).
    pub fn serve_pooled(
        addr: &str,
        service: Arc<dyn Service>,
        threads: usize,
    ) -> Result<RpcServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let pool = Arc::new(ThreadPool::new(threads, &format!("rpc-{}", local.port())));
        let parked: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept_thread = {
            let stop = stop.clone();
            let pool = pool.clone();
            let parked = parked.clone();
            std::thread::Builder::new()
                .name(format!("rpc-accept-{local}"))
                .spawn(move || Self::accept_poll_loop(listener, service, stop, pool, parked))
                .expect("spawn accept loop")
        };
        Ok(RpcServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            pool: Some(pool),
            parked,
        })
    }

    /// Bound address (resolves ephemeral ports).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Idle connections currently parked (excludes ones being serviced).
    pub fn parked_connections(&self) -> usize {
        self.parked.lock().unwrap().len()
    }

    /// Stop accepting and polling; parked connections close when the
    /// server drops, in-flight handlers abort on their next I/O nap.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
    }

    /// Accept new connections and sweep parked ones for readiness; ready
    /// connections move onto the handler pool and park themselves again
    /// once they have drained the requests queued on them.
    fn accept_poll_loop(
        listener: TcpListener,
        service: Arc<dyn Service>,
        stop: Arc<AtomicBool>,
        pool: Arc<ThreadPool>,
        parked: Arc<Mutex<Vec<TcpStream>>>,
    ) {
        // Adaptive sweep pacing: an idle server backs its sweep interval
        // off (1ms -> 10ms) so a large parked fleet doesn't burn a core
        // on peek() syscalls; any progress snaps it back for latency.
        let mut idle_sweeps = 0u32;
        while !stop.load(Ordering::Acquire) {
            let mut progressed = false;
            // Admit every connection waiting in the backlog.
            loop {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let _ = stream.set_nodelay(true);
                        if stream.set_nonblocking(true).is_ok() {
                            parked.lock().unwrap().push(stream);
                        }
                        progressed = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(_) => return,
                }
            }
            // Sweep parked connections; dispatch the readable ones.
            let mut ready = Vec::new();
            {
                let mut guard = parked.lock().unwrap();
                let mut i = 0;
                while i < guard.len() {
                    let mut probe = [0u8; 1];
                    match guard[i].peek(&mut probe) {
                        Ok(0) => {
                            guard.swap_remove(i); // peer closed
                        }
                        Ok(_) => ready.push(guard.swap_remove(i)),
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => i += 1,
                        Err(_) => {
                            guard.swap_remove(i); // broken socket
                        }
                    }
                }
            }
            for stream in ready {
                progressed = true;
                let service = service.clone();
                let stop = stop.clone();
                let parked = parked.clone();
                pool.execute(move || Self::serve_ready(stream, service, stop, parked));
            }
            if progressed {
                idle_sweeps = 0;
            } else {
                idle_sweeps = idle_sweeps.saturating_add(1);
                let ms = match idle_sweeps {
                    0..=10 => 1,
                    11..=100 => 2,
                    _ => 10,
                };
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }
    }

    /// Drain the requests already queued on a readable connection, then
    /// park it again. Runs on a pool worker; the worker is released once
    /// the connection goes quiet, so a worker fleet holding many
    /// mostly-idle connections shares `rpc_threads` handlers. A short
    /// post-response linger bridges a request/response-cycling client's
    /// think time, keeping sequential call latency at microseconds
    /// instead of a full poller sweep.
    fn serve_ready(
        mut stream: TcpStream,
        service: Arc<dyn Service>,
        stop: Arc<AtomicBool>,
        parked: Arc<Mutex<Vec<TcpStream>>>,
    ) {
        const LINGER: std::time::Duration = std::time::Duration::from_micros(300);
        // Fairness bound: a connection streaming back-to-back requests is
        // re-parked after this many responses so the poller can
        // round-robin workers across more saturating clients than
        // `rpc_threads` — one hot peer cannot pin a worker indefinitely.
        const MAX_REQUESTS_PER_DISPATCH: u32 = 128;
        let mut scratch = Vec::new();
        let mut idle_since = std::time::Instant::now();
        let mut served = 0u32;
        loop {
            if stop.load(Ordering::Acquire) {
                return; // drop the connection on shutdown
            }
            if served >= MAX_REQUESTS_PER_DISPATCH {
                parked.lock().unwrap().push(stream);
                return; // yield the worker; the poller re-dispatches
            }
            let range = match read_frame_nonblocking(&mut stream, &mut scratch, &stop) {
                Ok(Some(range)) => range,
                Ok(None) => {
                    if idle_since.elapsed() >= LINGER {
                        // Connection went quiet: hand it to the poller.
                        parked.lock().unwrap().push(stream);
                        return;
                    }
                    std::thread::sleep(std::time::Duration::from_micros(20));
                    continue;
                }
                Err(_) => return, // disconnect or corrupt stream
            };
            let req = &scratch[range];
            if req.len() < 10 {
                return;
            }
            let req_id = u64::from_le_bytes(req[0..8].try_into().unwrap());
            let method = u16::from_le_bytes(req[8..10].try_into().unwrap());
            let payload = &req[10..];
            let mut resp = Vec::with_capacity(32);
            resp.extend_from_slice(&req_id.to_le_bytes());
            match service.call(method, payload) {
                Ok(body) => {
                    resp.push(STATUS_OK);
                    resp.extend_from_slice(&body);
                }
                Err(e) => {
                    resp.push(STATUS_ERR);
                    resp.extend_from_slice(e.to_string().as_bytes());
                }
            }
            let framed = frame(&resp);
            if write_all_nonblocking(&mut stream, &framed, &stop).is_err() {
                return;
            }
            served += 1;
            idle_since = std::time::Instant::now();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Join handler workers (in-flight tasks abort on their next nap,
        // then the pool's Drop drains and joins). After this, no thread
        // of this server remains.
        self.pool.take();
        self.parked.lock().unwrap().clear();
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

struct ClientInner {
    stream: Option<TcpStream>,
    scratch: Vec<u8>,
}

/// Blocking RPC client with automatic reconnect. One in-flight request per
/// client; callers needing concurrency hold a pool of clients (the
/// WeiPS-client does exactly that, see `worker::client`).
pub struct RpcClient {
    addr: String,
    timeout: std::time::Duration,
    next_id: AtomicU64,
    inner: Mutex<ClientInner>,
}

impl RpcClient {
    /// Create a client for `addr` (connection is established lazily).
    pub fn new(addr: &str, timeout: std::time::Duration) -> RpcClient {
        RpcClient {
            addr: addr.to_string(),
            timeout,
            next_id: AtomicU64::new(1),
            inner: Mutex::new(ClientInner { stream: None, scratch: Vec::new() }),
        }
    }

    fn ensure_conn(&self, inner: &mut ClientInner) -> Result<()> {
        if inner.stream.is_none() {
            let stream = TcpStream::connect(&self.addr)
                .map_err(|e| Error::Rpc(format!("connect {}: {e}", self.addr)))?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(self.timeout))?;
            stream.set_write_timeout(Some(self.timeout))?;
            inner.stream = Some(stream);
        }
        Ok(())
    }

    /// Issue one request and wait for its response.
    pub fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        let req_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        self.ensure_conn(&mut inner)?;

        let mut req = Vec::with_capacity(payload.len() + 10);
        req.extend_from_slice(&req_id.to_le_bytes());
        req.extend_from_slice(&method.to_le_bytes());
        req.extend_from_slice(payload);

        let outcome = (|| -> Result<Vec<u8>> {
            // Disjoint borrows of the stream and the reusable scratch
            // buffer; the response payload is parsed in place and only
            // the body is copied out.
            let ClientInner { stream, scratch } = &mut *inner;
            let stream = stream.as_mut().unwrap();
            write_frame(stream, &req)?;
            // A slow server may interleave read timeouts; retry until the
            // client-level deadline elapses.
            let deadline = std::time::Instant::now() + self.timeout;
            loop {
                match read_frame(stream, scratch) {
                    Ok(range) => {
                        let resp = &scratch[range];
                        if resp.len() < 9 {
                            return Err(Error::Rpc("short response".into()));
                        }
                        let rid = u64::from_le_bytes(resp[0..8].try_into().unwrap());
                        if rid != req_id {
                            return Err(Error::Rpc(format!("response id {rid} != {req_id}")));
                        }
                        let status = resp[8];
                        let body = resp[9..].to_vec();
                        return if status == STATUS_OK {
                            Ok(body)
                        } else {
                            Err(Error::Rpc(String::from_utf8_lossy(&body).into_owned()))
                        };
                    }
                    Err(Error::Io(e))
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) && std::time::Instant::now() < deadline =>
                    {
                        continue;
                    }
                    Err(e) => return Err(e),
                }
            }
        })();

        if outcome.is_err() {
            // Drop the (possibly desynchronized) connection; next call dials.
            inner.stream = None;
        }
        outcome
    }
}

// ---------------------------------------------------------------------------
// Channel: local or remote
// ---------------------------------------------------------------------------

/// How to reach a service: in-process or over TCP.
#[derive(Clone)]
pub enum Channel {
    /// Direct dispatch into the service object.
    Local(Arc<dyn Service>),
    /// TCP RPC.
    Remote(Arc<RpcClient>),
}

impl Channel {
    /// Local channel to `svc`.
    pub fn local(svc: Arc<dyn Service>) -> Channel {
        Channel::Local(svc)
    }

    /// Remote channel to `addr`.
    pub fn remote(addr: &str, timeout: std::time::Duration) -> Channel {
        Channel::Remote(Arc::new(RpcClient::new(addr, timeout)))
    }

    /// Issue a request.
    pub fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        match self {
            Channel::Local(svc) => svc.call(method, payload),
            Channel::Remote(client) => client.call(method, payload),
        }
    }
}

impl std::fmt::Debug for Channel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Channel::Local(_) => write!(f, "Channel::Local"),
            Channel::Remote(_) => write!(f, "Channel::Remote"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;

    impl Service for Echo {
        fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
            match method {
                0 => Ok(payload.to_vec()),
                1 => Ok(payload.iter().rev().copied().collect()),
                9 => Err(Error::Unavailable("degraded".into())),
                _ => Err(Error::Rpc(format!("no method {method}"))),
            }
        }
    }

    fn timeout() -> std::time::Duration {
        std::time::Duration::from_secs(5)
    }

    #[test]
    fn local_channel_dispatches() {
        let ch = Channel::local(Arc::new(Echo));
        assert_eq!(ch.call(0, b"hi").unwrap(), b"hi");
        assert_eq!(ch.call(1, b"abc").unwrap(), b"cba");
        assert!(ch.call(9, b"").is_err());
    }

    #[test]
    fn tcp_round_trip() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let ch = Channel::remote(&server.addr().to_string(), timeout());
        assert_eq!(ch.call(0, b"hello").unwrap(), b"hello");
        assert_eq!(ch.call(1, b"xyz").unwrap(), b"zyx");
    }

    #[test]
    fn tcp_error_propagates_and_connection_survives() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let ch = Channel::remote(&server.addr().to_string(), timeout());
        let err = ch.call(9, b"").unwrap_err();
        assert!(err.to_string().contains("degraded"), "{err}");
        // Same connection still usable after an application error.
        assert_eq!(ch.call(0, b"ok").unwrap(), b"ok");
    }

    #[test]
    fn tcp_large_payload() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let ch = Channel::remote(&server.addr().to_string(), timeout());
        let big: Vec<u8> = (0..2_000_000u32).map(|i| i as u8).collect();
        assert_eq!(ch.call(0, &big).unwrap(), big);
    }

    #[test]
    fn many_sequential_calls() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let client = RpcClient::new(&server.addr().to_string(), timeout());
        for i in 0..200u32 {
            let payload = i.to_le_bytes();
            assert_eq!(client.call(0, &payload).unwrap(), payload);
        }
    }

    #[test]
    fn concurrent_clients() {
        let server = Arc::new(RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap());
        let addr = server.addr().to_string();
        let mut handles = Vec::new();
        for t in 0..4u8 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let client = RpcClient::new(&addr, timeout());
                for i in 0..50u32 {
                    let payload = [t, i as u8];
                    assert_eq!(client.call(1, &payload).unwrap(), [i as u8, t]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn connect_refused_is_error_then_reconnects() {
        // Pick a port by binding+dropping a listener.
        let tmp = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = tmp.local_addr().unwrap().to_string();
        drop(tmp);
        let client = RpcClient::new(&addr, timeout());
        assert!(client.call(0, b"x").is_err());
        // Now start a real server on that address; client should reconnect.
        let _server = match RpcServer::serve(&addr, Arc::new(Echo)) {
            Ok(s) => s,
            Err(_) => return, // port grabbed by another process; skip rest
        };
        assert_eq!(client.call(0, b"x").unwrap(), b"x");
    }

    #[test]
    fn pool_smaller_than_connection_fleet_still_serves() {
        // 8 concurrent long-lived connections share 2 handler threads —
        // the high fan-in shape the pooled server exists for.
        let server = RpcServer::serve_pooled("127.0.0.1:0", Arc::new(Echo), 2).unwrap();
        let addr = server.addr().to_string();
        let mut handles = Vec::new();
        for t in 0..8u8 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let client = RpcClient::new(&addr, timeout());
                for i in 0..25u32 {
                    let payload = [t, i as u8];
                    assert_eq!(client.call(1, &payload).unwrap(), [i as u8, t]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn drop_joins_threads_and_closes_connections() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let addr = server.addr().to_string();
        let client = RpcClient::new(&addr, std::time::Duration::from_millis(500));
        assert_eq!(client.call(0, b"x").unwrap(), b"x");
        // Drop joins the accept thread and the handler pool and closes
        // the parked connection; the client then fails fast.
        drop(server);
        assert!(client.call(0, b"y").is_err());
    }

    #[test]
    fn shutdown_stops_accepting() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let addr = server.addr().to_string();
        server.shutdown();
        std::thread::sleep(std::time::Duration::from_millis(30));
        let client = RpcClient::new(&addr, std::time::Duration::from_millis(300));
        // Either connect fails or the read times out — must error out.
        assert!(client.call(0, b"x").is_err());
    }
}
