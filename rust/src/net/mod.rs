//! RPC substrate: length-prefixed, CRC-checked frames over TCP, with an
//! in-process fast path.
//!
//! No async runtime is available offline, so the server runs a fixed
//! [`crate::util::ThreadPool`] behind an event-driven connection loop:
//! the poll thread keeps every idle connection in a parked set and sleeps
//! on a tiny in-tree epoll binding ([`crate::util::sys`]) until the
//! kernel reports one readable — idle fleets cost zero CPU and a wakeup
//! is O(ready), not O(parked). A ready connection is handed to a pool
//! worker, which drains the requests already queued on it and parks it
//! again (through the repark queue + eventfd waker, so the parked set has
//! exactly one owner). On targets without the epoll binding — or with
//! `WEIPS_RPC_POLL=peek` / the config's `rpc_poll_mode` knob — the loop
//! falls back to the portable peek sweep with configurable back-off
//! bounds. A fleet of workers fanning into one shard therefore costs
//! `rpc_threads` handler threads total (plus the poll thread) instead of
//! one thread per connection.
//!
//! Steady-state request handling performs **zero heap allocations** in
//! the frame path: each connection carries its own read-scratch and
//! response buffers (capped + shrunk when parked, so one huge frame never
//! pins memory), requests are parsed in place from the scratch range, and
//! responses leave as a `writev` iovec chain — a 17-byte head checksummed
//! against the body in place ([`crate::codec::crc32_parts`]), so the body
//! is never copied into a scratch buffer. Frame reads scatter the header
//! and a speculative body window into place with one `readv`. On targets
//! without the syscall bindings both paths fall back to the portable
//! buffer assembly ([`crate::codec::finish_frame`]) with identical bytes
//! on the wire. An io_uring readiness backend (`rpc_poll_mode=uring`)
//! rides the same dispatch machinery, degrading to epoll then peek when
//! the kernel lacks it.
//!
//! Wire format per request:  `frame( [req_id u64][method u16][payload] )`
//! and per response:          `frame( [req_id u64][status u8][payload] )`
//! where `frame` adds `[len u32][crc32 u32]` (see [`crate::codec`]).
//!
//! [`Channel`] abstracts "how do I reach this service": `Local` dispatches
//! straight into the service object (the all-in-one `LocalCluster` mode and
//! most tests), `Remote` talks TCP. Components only ever hold `Channel`s,
//! so the same coordinator code runs single-process or distributed.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::codec::{finish_frame, unframe};
use crate::util::sys;
use crate::util::ThreadPool;
use crate::{Error, Result};

/// Maximum frame payload (guards allocation on hostile/corrupt input).
pub const MAX_FRAME: usize = 256 << 20;

/// Status byte on responses.
const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;
/// Routing-epoch rejection ([`Error::StaleRoute`]): carried as its own
/// status so remote clients get the typed error back and can re-split by
/// the current slot map and retry, instead of failing a stringly RPC
/// error upward.
const STATUS_STALE_ROUTE: u8 = 2;
/// QoS admission-control shed ([`Error::Overloaded`]): its own status so
/// remote bulk callers can back off and retry while predict callers fail
/// over to a replica, instead of treating a deliberate shed as a fault.
const STATUS_OVERLOADED: u8 = 3;

/// Handler threads per RPC server when no explicit count is given
/// (`WEIPS_RPC_THREADS` overrides; the cluster config's `rpc_threads`
/// knob wins where a config is present).
pub fn default_rpc_threads() -> usize {
    use std::sync::OnceLock;
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("WEIPS_RPC_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(8)
    })
}

/// Stalled-peer drop timeout default in ms (`WEIPS_RPC_STALL_MS`
/// overrides; the cluster config's `rpc_stall_ms` knob wins where a
/// config is present). A handler never waits on one peer's socket longer
/// than this mid-frame or mid-write — generous next to a healthy peer's
/// packet gaps, so tripping it means the peer is effectively gone.
pub fn default_stall_ms() -> u64 {
    use std::sync::OnceLock;
    static N: OnceLock<u64> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("WEIPS_RPC_STALL_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(10_000)
    })
}

/// Per-connection scratch-buffer cap default in bytes
/// (`WEIPS_RPC_SCRATCH_CAP` overrides): buffers grown past this by a
/// large frame are shrunk back when the connection parks.
pub fn default_scratch_cap() -> usize {
    use std::sync::OnceLock;
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("WEIPS_RPC_SCRATCH_CAP")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&n| n >= 4096)
            .unwrap_or(1 << 20)
    })
}

/// How the poll thread learns a parked connection is readable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PollMode {
    /// Resolve at serve time: [`PollMode::Event`] where the platform
    /// supports the epoll binding, else [`PollMode::Peek`].
    Auto,
    /// Kernel readiness notification (epoll via [`crate::util::sys`]):
    /// zero idle CPU, O(ready) wakeups.
    Event,
    /// io_uring readiness notification (one-shot poll ops through the
    /// in-tree ring binding). Falls back to [`PollMode::Event`] — and
    /// from there to [`PollMode::Peek`] — when the kernel or sandbox
    /// lacks io_uring.
    Uring,
    /// Portable fallback: sweep parked connections with non-blocking
    /// `peek` at an adaptive interval.
    Peek,
}

impl PollMode {
    /// Parse "auto" | "epoll"/"event" | "uring" | "peek".
    pub fn parse(s: &str) -> Result<PollMode> {
        match s {
            "auto" => Ok(PollMode::Auto),
            "epoll" | "event" => Ok(PollMode::Event),
            "uring" => Ok(PollMode::Uring),
            "peek" => Ok(PollMode::Peek),
            other => Err(Error::Config(format!("unknown rpc poll mode {other}"))),
        }
    }

    /// Stable label value for the `weips_rpc_engaged_poll_mode` info
    /// gauge (and `weips top`'s engaged line).
    pub fn name(self) -> &'static str {
        match self {
            PollMode::Auto => "auto",
            PollMode::Event => "event",
            PollMode::Uring => "uring",
            PollMode::Peek => "peek",
        }
    }

    fn resolve(self) -> PollMode {
        match self {
            PollMode::Auto => {
                if sys::supported() {
                    PollMode::Event
                } else {
                    PollMode::Peek
                }
            }
            // Uring survives resolution; `serve_with` downgrades it at
            // setup time if the ring constructor fails on this kernel.
            m => m,
        }
    }
}

/// Poll-mode default (`WEIPS_RPC_POLL` = auto|epoll|peek; the cluster
/// config's `rpc_poll_mode` knob wins where a config is present).
pub fn default_poll_mode() -> PollMode {
    use std::sync::OnceLock;
    static M: OnceLock<PollMode> = OnceLock::new();
    *M.get_or_init(|| {
        std::env::var("WEIPS_RPC_POLL")
            .ok()
            .and_then(|v| PollMode::parse(&v).ok())
            .unwrap_or(PollMode::Auto)
    })
}

/// QoS class a request is admitted under. Classification is by method id
/// (see [`QosPolicy`]); the class decides which in-flight cap applies and
/// which dispatch/shed counters move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QosClass {
    /// Latency-sensitive serving reads (sparse/dense pulls, pings).
    /// Never shed: this is the class the caps exist to protect.
    Predict = 0,
    /// Throughput bulk transfers (migration pulls/applies, checkpoint
    /// save/load) — capped so a burst cannot occupy every handler.
    Bulk = 1,
    /// Everything else (training pushes, admin, routing control).
    Control = 2,
}

impl QosClass {
    /// All classes, in counter-index order.
    pub const ALL: [QosClass; 3] = [QosClass::Predict, QosClass::Bulk, QosClass::Control];

    /// Stable label value for metrics and NACK messages.
    pub fn name(self) -> &'static str {
        match self {
            QosClass::Predict => "predict",
            QosClass::Bulk => "bulk",
            QosClass::Control => "control",
        }
    }
}

/// Admission-control policy for one RPC server: which method ids belong
/// to which class, and the per-class in-flight caps. The substrate stays
/// protocol-agnostic — the WeiPS method-id classification lives with the
/// method table (`server::default_qos_policy`).
#[derive(Debug, Clone)]
pub struct QosPolicy {
    /// Method ids in the predict class (uncapped, protected).
    pub predict_methods: Vec<u16>,
    /// Method ids in the bulk class.
    pub bulk_methods: Vec<u16>,
    /// In-flight cap for bulk requests; 0 resolves to
    /// `max(1, threads / 2)` so at least half the handler pool always
    /// remains available to predict/control traffic.
    pub bulk_inflight_max: usize,
    /// In-flight cap for control requests; 0 = unlimited.
    pub control_inflight_max: usize,
}

/// Runtime admission state for one server (shared through the park queue
/// so pool workers and metrics samplers see the same counters).
struct QosGate {
    policy: QosPolicy,
    /// Resolved caps, indexed by class (u64::MAX = unlimited).
    caps: [u64; 3],
    inflight: [AtomicU64; 3],
    dispatched: [AtomicU64; 3],
    shed: [AtomicU64; 3],
}

impl QosGate {
    fn new(policy: QosPolicy, threads: usize) -> QosGate {
        let bulk = if policy.bulk_inflight_max == 0 {
            (threads / 2).max(1) as u64
        } else {
            policy.bulk_inflight_max as u64
        };
        let control = if policy.control_inflight_max == 0 {
            u64::MAX
        } else {
            policy.control_inflight_max as u64
        };
        QosGate {
            policy,
            caps: [u64::MAX, bulk, control],
            inflight: Default::default(),
            dispatched: Default::default(),
            shed: Default::default(),
        }
    }

    fn class_of(&self, method: u16) -> QosClass {
        if self.policy.predict_methods.contains(&method) {
            QosClass::Predict
        } else if self.policy.bulk_methods.contains(&method) {
            QosClass::Bulk
        } else {
            QosClass::Control
        }
    }

    /// Admit or shed. `Ok(class)` reserves an in-flight slot the caller
    /// must [`QosGate::release`]; `Err(class)` means the class is at its
    /// cap and the request must be NACKed without touching the service.
    fn admit(&self, method: u16) -> std::result::Result<QosClass, QosClass> {
        let class = self.class_of(method);
        let i = class as usize;
        let cap = self.caps[i];
        let mut cur = self.inflight[i].load(Ordering::Relaxed);
        loop {
            if cur >= cap {
                // Journal the *first* shed per class per gate: the event log
                // marks "this server started shedding", the rate lives in
                // `weips_rpc_class_shed_total` (and the qos alert rule).
                if self.shed[i].fetch_add(1, Ordering::Relaxed) == 0 {
                    crate::alerts::journal(
                        "degradation",
                        "qos_shed_engaged",
                        &format!("class {} hit inflight cap {cap}", class.name()),
                        0,
                    );
                }
                return Err(class);
            }
            match self.inflight[i].compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        self.dispatched[i].fetch_add(1, Ordering::Relaxed);
        Ok(class)
    }

    fn release(&self, class: QosClass) {
        self.inflight[class as usize].fetch_sub(1, Ordering::AcqRel);
    }
}

/// Tunables for one RPC server (the cluster config's RPC knobs resolve to
/// this — see `ClusterConfig::rpc_options`).
#[derive(Debug, Clone)]
pub struct RpcOptions {
    /// Handler pool size.
    pub threads: usize,
    /// Stalled-peer drop timeout (mid-frame / blocked-write gaps beyond
    /// this drop the connection and reclaim the worker).
    pub stall: Duration,
    /// Peek-mode sweep back-off lower bound (ms) — the sweep interval
    /// while traffic is flowing.
    pub poll_min_ms: u64,
    /// Peek-mode sweep back-off upper bound (ms) — the idle interval a
    /// quiet server backs off to.
    pub poll_max_ms: u64,
    /// Per-connection scratch buffers are shrunk back under this many
    /// bytes when the connection parks.
    pub scratch_cap: usize,
    /// Readiness mechanism.
    pub mode: PollMode,
    /// QoS admission control; `None` disables classification and caps.
    pub qos: Option<QosPolicy>,
}

impl Default for RpcOptions {
    fn default() -> RpcOptions {
        RpcOptions {
            threads: default_rpc_threads(),
            stall: Duration::from_millis(default_stall_ms()),
            poll_min_ms: 1,
            poll_max_ms: 10,
            scratch_cap: default_scratch_cap(),
            mode: default_poll_mode(),
            qos: None,
        }
    }
}

/// A dispatchable service: maps (method, payload) -> payload.
pub trait Service: Send + Sync {
    /// Handle one request.
    fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>>;
}

impl<F> Service for F
where
    F: Fn(u16, &[u8]) -> Result<Vec<u8>> + Send + Sync,
{
    fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        self(method, payload)
    }
}

// ---------------------------------------------------------------------------
// Framed stream I/O
// ---------------------------------------------------------------------------

/// Read exactly one frame from a stream (blocking). The payload is left in
/// `scratch` and its byte range returned — no intermediate copy; callers
/// borrow `&scratch[range]` (and copy only what they keep).
///
/// Where the raw-syscall bindings exist the header and a speculative body
/// window are scatter-read with one `readv` — a small response (the
/// common case) costs one syscall instead of two. Elsewhere it streams
/// through two `read_exact` calls.
fn read_frame(stream: &mut TcpStream, scratch: &mut Vec<u8>) -> Result<std::ops::Range<usize>> {
    #[cfg(unix)]
    if sys::supported() {
        return read_frame_readv(stream, scratch);
    }
    read_frame_streamed(stream, scratch)
}

/// Portable twin of [`read_frame_readv`].
fn read_frame_streamed(
    stream: &mut TcpStream,
    scratch: &mut Vec<u8>,
) -> Result<std::ops::Range<usize>> {
    let mut header = [0u8; 8];
    stream.read_exact(&mut header)?;
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(Error::Codec(format!("frame length {len} exceeds max")));
    }
    scratch.clear();
    scratch.resize(8 + len, 0);
    scratch[..8].copy_from_slice(&header);
    stream.read_exact(&mut scratch[8..])?;
    match unframe(scratch)? {
        Some((_, consumed)) => Ok(8..consumed),
        None => Err(Error::Codec("incomplete frame after read".into())),
    }
}

/// Body bytes gathered alongside the header on the first `readv`: enough
/// that a typical response arrives in one syscall, small enough that
/// (re)growing the scratch buffer to it costs nothing noticeable.
#[cfg(unix)]
const SPECULATIVE_BODY: usize = 4096;

/// Vectored read of one frame: `readv` scatters the first transfer into
/// the 8-byte header and the front of the body region, so the header
/// parse costs no dedicated syscall and small frames complete in one.
#[cfg(unix)]
fn read_frame_readv(stream: &mut TcpStream, scratch: &mut Vec<u8>) -> Result<std::ops::Range<usize>> {
    use std::os::unix::io::AsRawFd;
    let fd = stream.as_raw_fd();
    if scratch.len() < 8 + SPECULATIVE_BODY {
        scratch.resize(8 + SPECULATIVE_BODY, 0);
    }
    let mut header = [0u8; 8];
    let mut got = 0usize;
    while got < 8 {
        // Body bytes can only follow a complete header in the stream, so
        // while the header is short the body window is still empty.
        let iovs = [
            sys::IoVec::from_mut_slice(&mut header[got..]),
            sys::IoVec::from_mut_slice(&mut scratch[8..8 + SPECULATIVE_BODY]),
        ];
        match sys::readv(fd, &iovs) {
            Ok(0) => return Err(Error::Rpc("peer closed mid-frame".into())),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(Error::Codec(format!("frame length {len} exceeds max")));
    }
    let mut body_got = got - 8;
    if body_got > len {
        // One-request-in-flight framing never pipelines bytes past the
        // frame boundary; seeing them means the stream is corrupt.
        return Err(Error::Codec("bytes beyond frame boundary".into()));
    }
    if scratch.len() < 8 + len {
        scratch.resize(8 + len, 0);
    }
    scratch[..8].copy_from_slice(&header);
    while body_got < len {
        match stream.read(&mut scratch[8 + body_got..8 + len]) {
            Ok(0) => return Err(Error::Rpc("peer closed mid-frame".into())),
            Ok(n) => body_got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    match unframe(scratch)? {
        Some((_, consumed)) => Ok(8..consumed),
        None => Err(Error::Codec("incomplete frame after read".into())),
    }
}

/// Nap between non-blocking I/O retries; abort on shutdown or when the
/// peer has stalled past `deadline`.
fn nap_or_abort(stop: &AtomicBool, deadline: std::time::Instant, what: &str) -> Result<()> {
    if stop.load(Ordering::Acquire) {
        return Err(Error::Rpc("server shutting down".into()));
    }
    if std::time::Instant::now() >= deadline {
        return Err(Error::Rpc(format!("peer stalled {what}")));
    }
    std::thread::sleep(std::time::Duration::from_micros(200));
    Ok(())
}

/// Read one frame from a non-blocking stream. `Ok(None)` means no request
/// has started (first header byte would block) — the caller parks the
/// connection back into the poll set. Once a frame is underway, short
/// naps bridge the gaps between the peer's packets, bounded by `stall`;
/// `stop` aborts.
fn read_frame_nonblocking(
    stream: &mut TcpStream,
    scratch: &mut Vec<u8>,
    stop: &AtomicBool,
    stall: Duration,
) -> Result<Option<std::ops::Range<usize>>> {
    let deadline = std::time::Instant::now() + stall;
    let mut header = [0u8; 8];
    let mut got = 0usize;
    while got < 8 {
        match stream.read(&mut header[got..]) {
            Ok(0) => return Err(Error::Rpc("peer closed".into())),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if got == 0 {
                    return Ok(None); // idle connection: no request pending
                }
                nap_or_abort(stop, deadline, "mid-header")?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(Error::Codec(format!("frame length {len} exceeds max")));
    }
    scratch.clear();
    scratch.resize(8 + len, 0);
    scratch[..8].copy_from_slice(&header);
    let mut got = 8;
    while got < 8 + len {
        match stream.read(&mut scratch[got..]) {
            Ok(0) => return Err(Error::Rpc("peer closed mid-frame".into())),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                nap_or_abort(stop, deadline, "mid-frame")?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    match unframe(scratch)? {
        Some((_, consumed)) => Ok(Some(8..consumed)),
        None => Err(Error::Codec("incomplete frame after read".into())),
    }
}

/// Write all of `bytes` to a non-blocking stream (napping through a full
/// socket buffer, bounded by `stall`; `stop` aborts).
fn write_all_nonblocking(
    stream: &mut TcpStream,
    bytes: &[u8],
    stop: &AtomicBool,
    stall: Duration,
) -> Result<()> {
    let deadline = std::time::Instant::now() + stall;
    let mut off = 0usize;
    while off < bytes.len() {
        match stream.write(&bytes[off..]) {
            Ok(0) => return Err(Error::Rpc("peer closed on write".into())),
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                nap_or_abort(stop, deadline, "on write")?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Gather-write `head` then `body` to a non-blocking stream with `writev`,
/// advancing the iovec chain across partial transfers (napping through a
/// full socket buffer, bounded by `stall`; `stop` aborts).
#[cfg(unix)]
fn write_vectored_nonblocking(
    stream: &mut TcpStream,
    head: &[u8],
    body: &[u8],
    stop: &AtomicBool,
    stall: Duration,
) -> Result<()> {
    use std::os::unix::io::AsRawFd;
    let fd = stream.as_raw_fd();
    let deadline = std::time::Instant::now() + stall;
    let mut iovs = [sys::IoVec::from_slice(head), sys::IoVec::from_slice(body)];
    let mut at = 0usize; // first segment with bytes left
    loop {
        while at < iovs.len() && iovs[at].is_empty() {
            at += 1;
        }
        if at == iovs.len() {
            return Ok(());
        }
        match sys::writev(fd, &iovs[at..]) {
            Ok(0) => return Err(Error::Rpc("peer closed on write".into())),
            Ok(mut n) => {
                let mut i = at;
                while n > 0 {
                    let take = n.min(iovs[i].len());
                    if take == 0 {
                        i += 1;
                        continue;
                    }
                    iovs[i].advance(take);
                    n -= take;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                nap_or_abort(stop, deadline, "on write")?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// Send one framed response `[len][crc][req_id][status][body]`.
///
/// Where the raw-syscall bindings exist, the 17-byte head and the body
/// leave as an iovec chain: the body is checksummed in place
/// ([`crate::codec::crc32_parts`]) and handed to `writev` without ever
/// being copied into the connection's scratch buffer. The portable
/// fallback assembles the whole frame in `wbuf` via [`finish_frame`].
/// Both paths put identical bytes on the wire.
fn write_response(
    stream: &mut TcpStream,
    wbuf: &mut Vec<u8>,
    req_id: u64,
    status: u8,
    body: &[u8],
    stop: &AtomicBool,
    stall: Duration,
) -> Result<()> {
    #[cfg(unix)]
    if sys::supported() {
        let head = response_head(req_id, status, body);
        return write_vectored_nonblocking(stream, &head, body, stop, stall);
    }
    wbuf.clear();
    wbuf.extend_from_slice(&[0u8; 8]);
    wbuf.extend_from_slice(&req_id.to_le_bytes());
    wbuf.push(status);
    wbuf.extend_from_slice(body);
    finish_frame(wbuf);
    write_all_nonblocking(stream, wbuf, stop, stall)
}

/// Build the 17-byte response head `[len u32][crc u32][req_id u64]
/// [status u8]` for a response whose body follows as a separate segment.
/// The CRC spans `[req_id][status][body]` — exactly what [`finish_frame`]
/// would compute over the concatenated frame.
fn response_head(req_id: u64, status: u8, body: &[u8]) -> [u8; 17] {
    let mut head = [0u8; 17];
    head[0..4].copy_from_slice(&((9 + body.len()) as u32).to_le_bytes());
    head[8..16].copy_from_slice(&req_id.to_le_bytes());
    head[16] = status;
    let crc = crate::codec::crc32_parts(&[&head[8..], body]);
    head[4..8].copy_from_slice(&crc.to_le_bytes());
    head
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// One connection plus its reusable buffers. The buffers travel with the
/// connection between the poll thread and pool workers, so steady-state
/// request handling allocates nothing; [`Conn::shrink`] caps what an
/// oversized frame can pin once the connection goes idle.
struct Conn {
    stream: TcpStream,
    /// Frame read scratch — handlers borrow payload ranges in place.
    rbuf: Vec<u8>,
    /// Response assembly + framing buffer.
    wbuf: Vec<u8>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn { stream, rbuf: Vec::new(), wbuf: Vec::new() }
    }

    /// Release buffer memory beyond `cap` (called whenever the connection
    /// parks, so a single huge frame cannot pin memory for the
    /// connection's lifetime).
    fn shrink(&mut self, cap: usize) {
        if self.rbuf.capacity() > cap {
            self.rbuf.clear();
            self.rbuf.shrink_to(cap);
        }
        if self.wbuf.capacity() > cap {
            self.wbuf.clear();
            self.wbuf.shrink_to(cap);
        }
    }
}

/// Hand-off point between pool workers and the poll thread, which is the
/// sole owner of the parked set: workers push drained connections here
/// and (in event mode) ring the waker; the poll thread absorbs the queue
/// and re-registers the fds.
struct ParkQueue {
    queue: Mutex<Vec<Conn>>,
    /// Idle connections: parked-set size plus queued re-parks.
    count: AtomicUsize,
    /// Event-mode waker (`None` in peek mode — the sweep notices).
    waker: Option<sys::EventFd>,
    /// Worker dispatches submitted to the pool (ready-set batching makes
    /// this grow slower than `dispatched_conns` under small ready sets).
    dispatches: AtomicU64,
    /// Ready connections handed to workers.
    dispatched_conns: AtomicU64,
    /// QoS admission state (`None` when the server runs without caps).
    qos: Option<QosGate>,
}

impl ParkQueue {
    fn park(&self, conn: Conn) {
        self.count.fetch_add(1, Ordering::AcqRel);
        self.queue.lock().unwrap().push(conn);
        if let Some(w) = &self.waker {
            w.signal();
        }
    }

    fn take_queued(&self) -> Vec<Conn> {
        std::mem::take(&mut *self.queue.lock().unwrap())
    }
}

/// Running RPC server: a fixed handler pool fed by an event-driven (or
/// peek-sweeping) poll thread. Dropping it stops the loop, joins the poll
/// thread and drains the pool ([`Drop`] below — tests cannot leak accept
/// loops or handler threads).
pub struct RpcServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Handler pool; `Some` until drop. Dropped after the poll thread
    /// joins so no task can be submitted to a dead pool.
    pool: Option<Arc<ThreadPool>>,
    park: Arc<ParkQueue>,
    /// Readiness mechanism actually in use (after `Auto` resolution and
    /// epoll-availability fallback).
    mode: PollMode,
}

impl RpcServer {
    /// Bind `addr` (use port 0 for ephemeral) and serve `service` with
    /// default options ([`default_rpc_threads`] handlers, env-tunable
    /// stall/poll knobs).
    pub fn serve(addr: &str, service: Arc<dyn Service>) -> Result<RpcServer> {
        Self::serve_with(addr, service, RpcOptions::default())
    }

    /// Bind `addr` and serve `service` on a fixed pool of `threads`
    /// handler threads (the cluster config's `rpc_threads` knob).
    pub fn serve_pooled(
        addr: &str,
        service: Arc<dyn Service>,
        threads: usize,
    ) -> Result<RpcServer> {
        Self::serve_with(addr, service, RpcOptions { threads, ..RpcOptions::default() })
    }

    /// Bind `addr` and serve `service` with explicit [`RpcOptions`].
    pub fn serve_with(
        addr: &str,
        service: Arc<dyn Service>,
        opts: RpcOptions,
    ) -> Result<RpcServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let pool =
            Arc::new(ThreadPool::new(opts.threads.max(1), &format!("rpc-{}", local.port())));
        let requested = opts.mode.resolve();
        let mut mode = requested;
        // Uring mode needs a live ring and a waker; a kernel or sandbox
        // without io_uring downgrades to the epoll path.
        let mut uring = None;
        let mut waker = None;
        if mode == PollMode::Uring {
            match (sys::Uring::new(Self::URING_ENTRIES), sys::EventFd::new()) {
                (Ok(r), Ok(w)) => {
                    uring = Some(r);
                    waker = Some(w);
                }
                _ => mode = PollMode::Event,
            }
        }
        // Event mode needs a live epoll instance and a waker; anything
        // short of that falls back to the portable sweep.
        let mut epoll = None;
        if mode == PollMode::Event {
            match (sys::Epoll::new(), sys::EventFd::new()) {
                (Ok(e), Ok(w)) => {
                    epoll = Some(e);
                    waker = Some(w);
                }
                _ => mode = PollMode::Peek,
            }
        }
        if mode != requested {
            // The uring→event→peek ladder silently degrades at bind time;
            // journal it so the event log explains the engaged-mode gauge.
            crate::alerts::journal(
                "degradation",
                "poll_mode_fallback",
                &format!("{addr}: requested {} engaged {}", requested.name(), mode.name()),
                0,
            );
        }
        let park = Arc::new(ParkQueue {
            queue: Mutex::new(Vec::new()),
            count: AtomicUsize::new(0),
            waker,
            dispatches: AtomicU64::new(0),
            dispatched_conns: AtomicU64::new(0),
            qos: opts.qos.clone().map(|p| QosGate::new(p, opts.threads.max(1))),
        });
        // Dispatch stats surface on /metrics keyed by the bound address;
        // samplers hold a Weak so a dropped server vanishes from scrapes.
        {
            let labels = [("server", local.to_string())];
            let weak = Arc::downgrade(&park);
            crate::metrics::register_fn(
                "weips_rpc_dispatches_total",
                &labels,
                Box::new(move || {
                    weak.upgrade().map(|p| p.dispatches.load(Ordering::Relaxed) as f64)
                }),
            );
            let weak = Arc::downgrade(&park);
            crate::metrics::register_fn(
                "weips_rpc_dispatched_connections_total",
                &labels,
                Box::new(move || {
                    weak.upgrade().map(|p| p.dispatched_conns.load(Ordering::Relaxed) as f64)
                }),
            );
            let weak = Arc::downgrade(&park);
            crate::metrics::register_fn(
                "weips_rpc_parked_connections",
                &labels,
                Box::new(move || {
                    weak.upgrade().map(|p| p.count.load(Ordering::Acquire) as f64)
                }),
            );
            // Info-style gauge: the *engaged* readiness mechanism after
            // the uring→event→peek degradation resolved, not the
            // configured one — what the domino-degradation story needs a
            // scrape to see.
            let weak = Arc::downgrade(&park);
            crate::metrics::register_fn(
                "weips_rpc_engaged_poll_mode",
                &[("server", local.to_string()), ("mode", mode.name().to_string())],
                Box::new(move || weak.upgrade().map(|_| 1.0)),
            );
            if park.qos.is_some() {
                for class in QosClass::ALL {
                    let labels =
                        [("server", local.to_string()), ("class", class.name().to_string())];
                    let weak = Arc::downgrade(&park);
                    crate::metrics::register_fn(
                        "weips_rpc_class_dispatches_total",
                        &labels,
                        Box::new(move || {
                            weak.upgrade().and_then(|p| {
                                p.qos.as_ref().map(|g| {
                                    g.dispatched[class as usize].load(Ordering::Relaxed) as f64
                                })
                            })
                        }),
                    );
                    let weak = Arc::downgrade(&park);
                    crate::metrics::register_fn(
                        "weips_rpc_class_shed_total",
                        &labels,
                        Box::new(move || {
                            weak.upgrade().and_then(|p| {
                                p.qos.as_ref().map(|g| {
                                    g.shed[class as usize].load(Ordering::Relaxed) as f64
                                })
                            })
                        }),
                    );
                }
            }
        }
        let opts = Arc::new(RpcOptions { mode, ..opts });
        let accept_thread = {
            let stop = stop.clone();
            let pool = pool.clone();
            let park = park.clone();
            std::thread::Builder::new()
                .name(format!("rpc-poll-{local}"))
                .spawn(move || match (uring, epoll) {
                    (Some(ring), _) => {
                        Self::uring_loop(listener, service, stop, pool, park, opts, ring)
                    }
                    (None, Some(epoll)) => {
                        Self::event_loop(listener, service, stop, pool, park, opts, epoll)
                    }
                    (None, None) => Self::peek_loop(listener, service, stop, pool, park, opts),
                })
                .expect("spawn poll loop")
        };
        Ok(RpcServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            pool: Some(pool),
            park,
            mode,
        })
    }

    /// Bound address (resolves ephemeral ports).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Readiness mechanism in use.
    pub fn poll_mode(&self) -> PollMode {
        self.mode
    }

    /// Idle connections currently parked (excludes ones being serviced).
    pub fn parked_connections(&self) -> usize {
        self.park.count.load(Ordering::Acquire)
    }

    /// Per-class `(dispatched, shed)` counters in [`QosClass::ALL`] order,
    /// or `None` when the server runs without admission control.
    pub fn qos_stats(&self) -> Option<[(u64, u64); 3]> {
        self.park.qos.as_ref().map(|g| {
            let mut out = [(0u64, 0u64); 3];
            for class in QosClass::ALL {
                let i = class as usize;
                out[i] = (
                    g.dispatched[i].load(Ordering::Relaxed),
                    g.shed[i].load(Ordering::Relaxed),
                );
            }
            out
        })
    }

    /// (worker dispatches, ready connections handed over). With ready-set
    /// batching, dispatches <= connections: small epoll ready sets share
    /// one pool wakeup.
    pub fn dispatch_stats(&self) -> (u64, u64) {
        (
            self.park.dispatches.load(Ordering::Relaxed),
            self.park.dispatched_conns.load(Ordering::Relaxed),
        )
    }

    /// Stop accepting and polling; parked connections close when the
    /// server drops, in-flight handlers abort on their next I/O nap.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        if let Some(w) = &self.park.waker {
            w.signal();
        }
    }

    fn dispatch(
        conn: Conn,
        service: &Arc<dyn Service>,
        stop: &Arc<AtomicBool>,
        pool: &Arc<ThreadPool>,
        park: &Arc<ParkQueue>,
        opts: &Arc<RpcOptions>,
    ) {
        park.dispatches.fetch_add(1, Ordering::Relaxed);
        park.dispatched_conns.fetch_add(1, Ordering::Relaxed);
        let service = service.clone();
        let stop = stop.clone();
        let park = park.clone();
        let opts = opts.clone();
        pool.execute(move || Self::serve_ready(conn, service, stop, park, opts));
    }

    /// Ready sets this small ride a single worker dispatch **when the
    /// pool already has queued work**: the tasks would serialize behind
    /// the backlog anyway, so collapsing them saves the per-connection
    /// pool hand-off (queue lock + worker wake) with zero added latency.
    /// With idle workers available, or for larger sets, connections fan
    /// out one task each for handler parallelism — batching there would
    /// head-of-line-block concurrent requests.
    const READY_BATCH_MAX: usize = 4;

    fn dispatch_ready(
        ready: &mut Vec<Conn>,
        service: &Arc<dyn Service>,
        stop: &Arc<AtomicBool>,
        pool: &Arc<ThreadPool>,
        park: &Arc<ParkQueue>,
        opts: &Arc<RpcOptions>,
    ) {
        match ready.len() {
            0 => {}
            1 => Self::dispatch(ready.pop().unwrap(), service, stop, pool, park, opts),
            n if n <= Self::READY_BATCH_MAX && pool.pending() > 0 => {
                park.dispatches.fetch_add(1, Ordering::Relaxed);
                park.dispatched_conns.fetch_add(n as u64, Ordering::Relaxed);
                let batch: Vec<Conn> = ready.drain(..).collect();
                let service = service.clone();
                let stop = stop.clone();
                let park = park.clone();
                let opts = opts.clone();
                pool.execute(move || {
                    for conn in batch {
                        Self::serve_ready(
                            conn,
                            service.clone(),
                            stop.clone(),
                            park.clone(),
                            opts.clone(),
                        );
                    }
                });
            }
            _ => {
                for conn in ready.drain(..) {
                    Self::dispatch(conn, service, stop, pool, park, opts);
                }
            }
        }
    }

    /// Event-driven poll loop: the listener, the waker and every parked
    /// connection are registered with epoll; the thread sleeps until the
    /// kernel reports readiness. Idle servers burn no CPU regardless of
    /// fleet size, and each wakeup touches only the ready fds.
    fn event_loop(
        listener: TcpListener,
        service: Arc<dyn Service>,
        stop: Arc<AtomicBool>,
        pool: Arc<ThreadPool>,
        park: Arc<ParkQueue>,
        opts: Arc<RpcOptions>,
        epoll: sys::Epoll,
    ) {
        const TOKEN_WAKE: u64 = u64::MAX;
        const TOKEN_ACCEPT: u64 = u64::MAX - 1;
        // fd-keyed parked set (fds are process-unique while open and never
        // collide with the reserved tokens).
        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut events = vec![sys::EpollEvent::default(); 64];
        // Reused across wakeups like the event buffer — the dispatch path
        // stays allocation-free except when a small set batches into one
        // task (which must own its connections).
        let mut ready: Vec<Conn> = Vec::new();
        if epoll.add(listener.as_raw_fd(), TOKEN_ACCEPT).is_err() {
            // Registration failure at startup: fall back to sweeping.
            return Self::peek_loop(listener, service, stop, pool, park, opts);
        }
        if let Some(w) = &park.waker {
            let _ = epoll.add(w.raw_fd(), TOKEN_WAKE);
        }
        while !stop.load(Ordering::Acquire) {
            // Re-register connections the workers handed back before
            // sleeping (the waker guarantees we woke for them).
            for conn in park.take_queued() {
                let fd = conn.stream.as_raw_fd();
                if epoll.add(fd, fd as u64).is_ok() {
                    conns.insert(fd as u64, conn);
                } else {
                    park.count.fetch_sub(1, Ordering::AcqRel); // broken socket
                }
            }
            // The 1 s timeout is a belt-and-braces stop check; shutdown
            // rings the waker so teardown never waits on it.
            let n = match epoll.wait(&mut events, 1_000) {
                Ok(n) => n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            };
            // Collect this wakeup's ready connections, then hand the set
            // to workers in as few pool dispatches as sensible.
            for ev in events.iter().take(n) {
                match ev.token() {
                    TOKEN_WAKE => {
                        if let Some(w) = &park.waker {
                            w.drain();
                        }
                    }
                    TOKEN_ACCEPT => loop {
                        match listener.accept() {
                            Ok((stream, _peer)) => {
                                let _ = stream.set_nodelay(true);
                                if stream.set_nonblocking(true).is_err() {
                                    continue;
                                }
                                let fd = stream.as_raw_fd();
                                if epoll.add(fd, fd as u64).is_ok() {
                                    conns.insert(fd as u64, Conn::new(stream));
                                    park.count.fetch_add(1, Ordering::AcqRel);
                                }
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                            Err(_) => return,
                        }
                    },
                    token => {
                        // Readable or hung up — the worker's first read
                        // tells them apart; either way it leaves the set.
                        if let Some(conn) = conns.remove(&token) {
                            let _ = epoll.delete(conn.stream.as_raw_fd());
                            park.count.fetch_sub(1, Ordering::AcqRel);
                            ready.push(conn);
                        }
                    }
                }
            }
            Self::dispatch_ready(&mut ready, &service, &stop, &pool, &park, &opts);
        }
    }

    /// Submission-queue depth for the uring poll loop. Registrations in
    /// flight are unbounded (the kernel tracks them); this only bounds
    /// how many submissions queue between two `wait` calls before an
    /// intermediate flush.
    const URING_ENTRIES: u32 = 256;

    /// io_uring poll loop: the same shape as [`Self::event_loop`], with
    /// one-shot `POLL_ADD` ops standing in for epoll registration. A
    /// completion both reports readiness and consumes the registration,
    /// which is exactly the `wait` + `delete` pair of the epoll path —
    /// ready fds leave the watched set in zero extra syscalls, and the
    /// listener/waker re-arm as they fire.
    fn uring_loop(
        listener: TcpListener,
        service: Arc<dyn Service>,
        stop: Arc<AtomicBool>,
        pool: Arc<ThreadPool>,
        park: Arc<ParkQueue>,
        opts: Arc<RpcOptions>,
        mut ring: sys::Uring,
    ) {
        const TOKEN_WAKE: u64 = u64::MAX;
        const TOKEN_ACCEPT: u64 = u64::MAX - 1;
        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut events = vec![sys::UringCompletion::default(); 64];
        let mut ready: Vec<Conn> = Vec::new();
        if ring.poll_add(listener.as_raw_fd(), TOKEN_ACCEPT).is_err() {
            return Self::peek_loop(listener, service, stop, pool, park, opts);
        }
        if let Some(w) = &park.waker {
            let _ = ring.poll_add(w.raw_fd(), TOKEN_WAKE);
        }
        while !stop.load(Ordering::Acquire) {
            // Re-register connections the workers handed back before
            // sleeping (the waker guarantees we woke for them).
            for conn in park.take_queued() {
                let fd = conn.stream.as_raw_fd();
                if ring.poll_add(fd, fd as u64).is_ok() {
                    conns.insert(fd as u64, conn);
                } else {
                    park.count.fetch_sub(1, Ordering::AcqRel); // broken socket
                }
            }
            // The 1 s timeout is a belt-and-braces stop check; shutdown
            // rings the waker so teardown never waits on it.
            let n = match ring.wait(&mut events, 1_000) {
                Ok(n) => n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            };
            for ev in events.iter().take(n) {
                match ev.token {
                    TOKEN_WAKE => {
                        if let Some(w) = &park.waker {
                            w.drain();
                            // One-shot registration: re-arm the waker.
                            let _ = ring.poll_add(w.raw_fd(), TOKEN_WAKE);
                        }
                    }
                    TOKEN_ACCEPT => {
                        loop {
                            match listener.accept() {
                                Ok((stream, _peer)) => {
                                    let _ = stream.set_nodelay(true);
                                    if stream.set_nonblocking(true).is_err() {
                                        continue;
                                    }
                                    let fd = stream.as_raw_fd();
                                    if ring.poll_add(fd, fd as u64).is_ok() {
                                        conns.insert(fd as u64, Conn::new(stream));
                                        park.count.fetch_add(1, Ordering::AcqRel);
                                    }
                                }
                                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                                Err(_) => return,
                            }
                        }
                        if ring.poll_add(listener.as_raw_fd(), TOKEN_ACCEPT).is_err() {
                            return;
                        }
                    }
                    token => {
                        // Readable or hung up — the worker's first read
                        // tells them apart. The one-shot poll already
                        // removed the fd from the watched set.
                        if let Some(conn) = conns.remove(&token) {
                            park.count.fetch_sub(1, Ordering::AcqRel);
                            ready.push(conn);
                        }
                    }
                }
            }
            Self::dispatch_ready(&mut ready, &service, &stop, &pool, &park, &opts);
        }
    }

    /// Portable fallback: accept new connections and sweep parked ones
    /// for readiness with non-blocking peeks, backing the sweep interval
    /// off between `poll_min_ms` and `poll_max_ms` while idle.
    fn peek_loop(
        listener: TcpListener,
        service: Arc<dyn Service>,
        stop: Arc<AtomicBool>,
        pool: Arc<ThreadPool>,
        park: Arc<ParkQueue>,
        opts: Arc<RpcOptions>,
    ) {
        let mut conns: Vec<Conn> = Vec::new();
        let mut idle_sweeps = 0u32;
        while !stop.load(Ordering::Acquire) {
            let mut progressed = false;
            conns.append(&mut park.take_queued());
            // Admit every connection waiting in the backlog.
            loop {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let _ = stream.set_nodelay(true);
                        if stream.set_nonblocking(true).is_ok() {
                            conns.push(Conn::new(stream));
                            park.count.fetch_add(1, Ordering::AcqRel);
                        }
                        progressed = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(_) => return,
                }
            }
            // Sweep parked connections; dispatch the readable ones.
            let mut i = 0;
            while i < conns.len() {
                let mut probe = [0u8; 1];
                match conns[i].stream.peek(&mut probe) {
                    Ok(0) => {
                        conns.swap_remove(i); // peer closed
                        park.count.fetch_sub(1, Ordering::AcqRel);
                    }
                    Ok(_) => {
                        let conn = conns.swap_remove(i);
                        park.count.fetch_sub(1, Ordering::AcqRel);
                        progressed = true;
                        Self::dispatch(conn, &service, &stop, &pool, &park, &opts);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => i += 1,
                    Err(_) => {
                        conns.swap_remove(i); // broken socket
                        park.count.fetch_sub(1, Ordering::AcqRel);
                    }
                }
            }
            if progressed {
                idle_sweeps = 0;
            } else {
                idle_sweeps = idle_sweeps.saturating_add(1);
                let ms = match idle_sweeps {
                    0..=10 => opts.poll_min_ms,
                    11..=100 => (opts.poll_min_ms * 2).min(opts.poll_max_ms.max(opts.poll_min_ms)),
                    _ => opts.poll_max_ms.max(opts.poll_min_ms),
                };
                // Nap in short slices so a large configured back-off never
                // delays shutdown (drop joins this thread).
                let mut left = ms.max(1);
                while left > 0 && !stop.load(Ordering::Acquire) {
                    let slice = left.min(10);
                    std::thread::sleep(std::time::Duration::from_millis(slice));
                    left -= slice;
                }
            }
        }
    }

    /// Drain the requests already queued on a readable connection, then
    /// park it again. Runs on a pool worker; the worker is released once
    /// the connection goes quiet, so a worker fleet holding many
    /// mostly-idle connections shares `rpc_threads` handlers. A short
    /// post-response linger bridges a request/response-cycling client's
    /// think time, keeping sequential call latency at microseconds
    /// instead of a full poller round-trip. The frame path reuses the
    /// connection's own buffers — no allocation per request.
    fn serve_ready(
        mut conn: Conn,
        service: Arc<dyn Service>,
        stop: Arc<AtomicBool>,
        park: Arc<ParkQueue>,
        opts: Arc<RpcOptions>,
    ) {
        const LINGER: Duration = Duration::from_micros(300);
        // Fairness bound: a connection streaming back-to-back requests is
        // re-parked after this many responses so the poller can
        // round-robin workers across more saturating clients than
        // `rpc_threads` — one hot peer cannot pin a worker indefinitely.
        const MAX_REQUESTS_PER_DISPATCH: u32 = 128;
        let mut idle_since = std::time::Instant::now();
        let mut served = 0u32;
        loop {
            if stop.load(Ordering::Acquire) {
                return; // drop the connection on shutdown
            }
            if served >= MAX_REQUESTS_PER_DISPATCH {
                conn.shrink(opts.scratch_cap);
                park.park(conn);
                return; // yield the worker; the poller re-dispatches
            }
            // Disjoint borrows of the stream and the two buffers.
            let Conn { stream, rbuf, wbuf } = &mut conn;
            let range = match read_frame_nonblocking(stream, rbuf, &stop, opts.stall) {
                Ok(Some(range)) => range,
                Ok(None) => {
                    if idle_since.elapsed() >= LINGER {
                        // Connection went quiet: hand it to the poller.
                        conn.shrink(opts.scratch_cap);
                        park.park(conn);
                        return;
                    }
                    std::thread::sleep(std::time::Duration::from_micros(20));
                    continue;
                }
                Err(_) => return, // disconnect or corrupt stream
            };
            let req = &rbuf[range];
            if req.len() < 10 {
                return;
            }
            let req_id = u64::from_le_bytes(req[0..8].try_into().unwrap());
            let method = u16::from_le_bytes(req[8..10].try_into().unwrap());
            let payload = &req[10..];
            // QoS admission: classify by method and, when the class is at
            // its in-flight cap, shed with the typed overload NACK before
            // the service sees the request — a shed costs one response
            // frame, never a handler-occupying service call.
            let admitted = match &park.qos {
                Some(gate) => gate.admit(method).map(Some),
                None => Ok(None),
            };
            let (status, body) = match admitted {
                Err(class) => {
                    let msg = format!("{} class at in-flight cap, request shed", class.name());
                    (STATUS_OVERLOADED, msg.into_bytes())
                }
                Ok(class) => {
                    let out = service.call(method, payload);
                    if let (Some(gate), Some(class)) = (&park.qos, class) {
                        gate.release(class);
                    }
                    match out {
                        Ok(body) => (STATUS_OK, body),
                        Err(e) => {
                            let status = if e.is_stale_route() {
                                STATUS_STALE_ROUTE
                            } else if e.is_overloaded() {
                                STATUS_OVERLOADED
                            } else {
                                STATUS_ERR
                            };
                            (status, e.to_string().into_bytes())
                        }
                    }
                }
            };
            // The head + service body go out as an iovec chain where the
            // platform has writev; the portable path assembles the frame
            // in `wbuf` — identical bytes either way.
            if write_response(stream, wbuf, req_id, status, &body, &stop, opts.stall).is_err() {
                return;
            }
            served += 1;
            idle_since = std::time::Instant::now();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Join handler workers (in-flight tasks abort on their next nap,
        // then the pool's Drop drains and joins). After this, no thread
        // of this server remains.
        self.pool.take();
        self.park.queue.lock().unwrap().clear();
        self.park.count.store(0, Ordering::Release);
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

struct ClientInner {
    stream: Option<TcpStream>,
    /// Response frame scratch (payload parsed in place).
    scratch: Vec<u8>,
    /// Request assembly + framing buffer.
    wbuf: Vec<u8>,
}

/// Blocking RPC client with automatic reconnect. One in-flight request per
/// client; callers needing concurrency hold a pool of clients (the
/// WeiPS-client does exactly that, see `worker::client`). Request and
/// response frames are assembled/parsed in reusable buffers.
pub struct RpcClient {
    addr: String,
    timeout: std::time::Duration,
    next_id: AtomicU64,
    inner: Mutex<ClientInner>,
}

impl RpcClient {
    /// Create a client for `addr` (connection is established lazily).
    pub fn new(addr: &str, timeout: std::time::Duration) -> RpcClient {
        RpcClient {
            addr: addr.to_string(),
            timeout,
            next_id: AtomicU64::new(1),
            inner: Mutex::new(ClientInner {
                stream: None,
                scratch: Vec::new(),
                wbuf: Vec::new(),
            }),
        }
    }

    /// Best-effort "no request in flight" probe, used by [`ClientPool`]
    /// to prefer a warm idle connection. Racy by design: a stale answer
    /// only means the caller blocks on this client's mutex, exactly like
    /// the unpooled path always did.
    pub fn is_idle(&self) -> bool {
        !matches!(self.inner.try_lock(), Err(std::sync::TryLockError::WouldBlock))
    }

    fn ensure_conn(&self, inner: &mut ClientInner) -> Result<()> {
        if inner.stream.is_none() {
            let stream = TcpStream::connect(&self.addr)
                .map_err(|e| Error::Rpc(format!("connect {}: {e}", self.addr)))?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(self.timeout))?;
            stream.set_write_timeout(Some(self.timeout))?;
            inner.stream = Some(stream);
        }
        Ok(())
    }

    /// Issue one request and wait for its response.
    pub fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        let req_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        self.ensure_conn(&mut inner)?;

        let outcome = (|| -> Result<Vec<u8>> {
            // Disjoint borrows of the stream and the reusable buffers;
            // the request frame is assembled in place and the response
            // payload parsed in place — only the body is copied out.
            let ClientInner { stream, scratch, wbuf } = &mut *inner;
            let stream = stream.as_mut().unwrap();
            wbuf.clear();
            wbuf.extend_from_slice(&[0u8; 8]);
            wbuf.extend_from_slice(&req_id.to_le_bytes());
            wbuf.extend_from_slice(&method.to_le_bytes());
            wbuf.extend_from_slice(payload);
            finish_frame(wbuf);
            stream.write_all(wbuf)?;
            // A slow server may interleave read timeouts; retry until the
            // client-level deadline elapses.
            let deadline = std::time::Instant::now() + self.timeout;
            loop {
                match read_frame(stream, scratch) {
                    Ok(range) => {
                        let resp = &scratch[range];
                        if resp.len() < 9 {
                            return Err(Error::Rpc("short response".into()));
                        }
                        let rid = u64::from_le_bytes(resp[0..8].try_into().unwrap());
                        if rid != req_id {
                            return Err(Error::Rpc(format!("response id {rid} != {req_id}")));
                        }
                        let status = resp[8];
                        let body = resp[9..].to_vec();
                        return match status {
                            STATUS_OK => Ok(body),
                            STATUS_STALE_ROUTE => Err(Error::StaleRoute(
                                String::from_utf8_lossy(&body).into_owned(),
                            )),
                            STATUS_OVERLOADED => Err(Error::Overloaded(
                                String::from_utf8_lossy(&body).into_owned(),
                            )),
                            _ => Err(Error::Rpc(String::from_utf8_lossy(&body).into_owned())),
                        };
                    }
                    Err(Error::Io(e))
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) && std::time::Instant::now() < deadline =>
                    {
                        continue;
                    }
                    Err(e) => return Err(e),
                }
            }
        })();

        if outcome.is_err() {
            // Drop the (possibly desynchronized) connection; next call dials.
            inner.stream = None;
        }
        // Same cap as server-side connections: one huge response must not
        // pin the client's buffers for its lifetime.
        let cap = default_scratch_cap();
        if inner.scratch.capacity() > cap {
            inner.scratch.clear();
            inner.scratch.shrink_to(cap);
        }
        if inner.wbuf.capacity() > cap {
            inner.wbuf.clear();
            inner.wbuf.shrink_to(cap);
        }
        outcome
    }
}

/// Warm connection pool to one endpoint: `size` persistent clients, one
/// TCP connection each, picked idle-first from a rotating start index. Up
/// to `size` requests to the endpoint proceed in parallel with no per-call
/// dial, and a caller never head-of-line-blocks behind another caller's
/// in-flight request while an idle warm connection exists.
pub struct ClientPool {
    clients: Vec<RpcClient>,
    next: AtomicUsize,
}

impl ClientPool {
    /// Pool of `size` (min 1) lazily-connected clients for `addr`.
    pub fn new(addr: &str, timeout: std::time::Duration, size: usize) -> ClientPool {
        ClientPool {
            clients: (0..size.max(1)).map(|_| RpcClient::new(addr, timeout)).collect(),
            next: AtomicUsize::new(0),
        }
    }

    /// Issue one request on an idle pooled connection, falling back to
    /// round-robin blocking when every connection is busy.
    pub fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        let n = self.clients.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        for i in 0..n {
            let c = &self.clients[(start + i) % n];
            if c.is_idle() {
                return c.call(method, payload);
            }
        }
        self.clients[start % n].call(method, payload)
    }

    /// Number of pooled connections.
    pub fn size(&self) -> usize {
        self.clients.len()
    }
}

// ---------------------------------------------------------------------------
// Channel: local or remote
// ---------------------------------------------------------------------------

/// How to reach a service: in-process or over TCP.
#[derive(Clone)]
pub enum Channel {
    /// Direct dispatch into the service object.
    Local(Arc<dyn Service>),
    /// TCP RPC, one connection.
    Remote(Arc<RpcClient>),
    /// TCP RPC over a warm connection pool (concurrent callers to one
    /// endpoint — the serving read path).
    Pooled(Arc<ClientPool>),
}

impl Channel {
    /// Local channel to `svc`.
    pub fn local(svc: Arc<dyn Service>) -> Channel {
        Channel::Local(svc)
    }

    /// Remote channel to `addr`.
    pub fn remote(addr: &str, timeout: std::time::Duration) -> Channel {
        Channel::Remote(Arc::new(RpcClient::new(addr, timeout)))
    }

    /// Pooled remote channel to `addr` with `size` warm connections.
    pub fn pooled(addr: &str, timeout: std::time::Duration, size: usize) -> Channel {
        Channel::Pooled(Arc::new(ClientPool::new(addr, timeout, size)))
    }

    /// Issue a request.
    pub fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        match self {
            Channel::Local(svc) => svc.call(method, payload),
            Channel::Remote(client) => client.call(method, payload),
            Channel::Pooled(pool) => pool.call(method, payload),
        }
    }
}

impl std::fmt::Debug for Channel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Channel::Local(_) => write!(f, "Channel::Local"),
            Channel::Remote(_) => write!(f, "Channel::Remote"),
            Channel::Pooled(p) => write!(f, "Channel::Pooled({})", p.size()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;

    impl Service for Echo {
        fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
            match method {
                0 => Ok(payload.to_vec()),
                1 => Ok(payload.iter().rev().copied().collect()),
                5 => Err(Error::StaleRoute("slot 7 moved to shard 2".into())),
                9 => Err(Error::Unavailable("degraded".into())),
                _ => Err(Error::Rpc(format!("no method {method}"))),
            }
        }
    }

    #[test]
    fn stale_route_errors_stay_typed_over_tcp() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let ch = Channel::remote(&server.addr().to_string(), timeout());
        let err = ch.call(5, b"").unwrap_err();
        assert!(err.is_stale_route(), "lost the typed status: {err}");
        assert!(err.to_string().contains("slot 7 moved"), "{err}");
        // Ordinary errors stay ordinary; the connection survives both.
        assert!(!ch.call(9, b"").unwrap_err().is_stale_route());
        assert_eq!(ch.call(0, b"still-up").unwrap(), b"still-up");
    }

    fn timeout() -> std::time::Duration {
        std::time::Duration::from_secs(5)
    }

    fn serve_mode(mode: PollMode) -> RpcServer {
        RpcServer::serve_with(
            "127.0.0.1:0",
            Arc::new(Echo),
            RpcOptions { mode, ..RpcOptions::default() },
        )
        .unwrap()
    }

    #[test]
    fn local_channel_dispatches() {
        let ch = Channel::local(Arc::new(Echo));
        assert_eq!(ch.call(0, b"hi").unwrap(), b"hi");
        assert_eq!(ch.call(1, b"abc").unwrap(), b"cba");
        assert!(ch.call(9, b"").is_err());
    }

    #[test]
    fn tcp_round_trip() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let ch = Channel::remote(&server.addr().to_string(), timeout());
        assert_eq!(ch.call(0, b"hello").unwrap(), b"hello");
        assert_eq!(ch.call(1, b"xyz").unwrap(), b"zyx");
    }

    #[test]
    fn tcp_round_trip_in_all_poll_modes() {
        for mode in [PollMode::Peek, PollMode::Event, PollMode::Uring] {
            let server = serve_mode(mode);
            if mode != PollMode::Peek && server.poll_mode() != mode {
                continue; // platform without this binding (fallback took over)
            }
            let ch = Channel::remote(&server.addr().to_string(), timeout());
            for i in 0..40u32 {
                let payload = i.to_le_bytes();
                assert_eq!(ch.call(0, &payload).unwrap(), payload, "mode {mode:?}");
            }
            let err = ch.call(9, b"").unwrap_err();
            assert!(err.to_string().contains("degraded"), "{err}");
            assert_eq!(ch.call(0, b"still-up").unwrap(), b"still-up");
        }
    }

    /// Raw framed call over a fresh socket: returns the exact response
    /// bytes as they appeared on the wire (header included).
    fn raw_call(addr: &str, req_id: u64, method: u16, payload: &[u8]) -> Vec<u8> {
        let mut req = Vec::new();
        req.extend_from_slice(&req_id.to_le_bytes());
        req.extend_from_slice(&method.to_le_bytes());
        req.extend_from_slice(payload);
        let framed = crate::codec::frame(&req);
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream.set_read_timeout(Some(timeout())).unwrap();
        stream.write_all(&framed).unwrap();
        let mut header = [0u8; 8];
        stream.read_exact(&mut header).unwrap();
        let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
        let mut out = header.to_vec();
        out.resize(8 + len, 0);
        stream.read_exact(&mut out[8..]).unwrap();
        out
    }

    #[test]
    fn uring_and_epoll_responses_are_byte_identical() {
        // Same request (same req_id) against a server in each poll mode:
        // the wire bytes of the response must be identical — the uring
        // loop and the vectored write path change how bytes move, never
        // which bytes.
        let uring = serve_mode(PollMode::Uring);
        let epoll = serve_mode(PollMode::Event);
        let peek = serve_mode(PollMode::Peek);
        for (method, payload) in
            [(0u16, &b"identity-check"[..]), (1, &b"reverse-me"[..]), (9, &b""[..])]
        {
            let reference = raw_call(&peek.addr().to_string(), 7700, method, payload);
            if epoll.poll_mode() == PollMode::Event {
                let got = raw_call(&epoll.addr().to_string(), 7700, method, payload);
                assert_eq!(got, reference, "epoll bytes diverge (method {method})");
            }
            if uring.poll_mode() == PollMode::Uring {
                let got = raw_call(&uring.addr().to_string(), 7700, method, payload);
                assert_eq!(got, reference, "uring bytes diverge (method {method})");
            }
        }
    }

    #[test]
    fn vectored_response_head_matches_scratch_framing() {
        // The 17-byte head + separate body must serialize to exactly the
        // frame `finish_frame` builds in the scratch buffer — the wire
        // contract of the vectored fast path.
        for body_len in [0usize, 1, 9, 257, 70_000] {
            let body: Vec<u8> = (0..body_len).map(|i| (i * 31) as u8).collect();
            let req_id = 0xDEAD_BEEF_u64 + body_len as u64;
            let head = response_head(req_id, STATUS_OK, &body);
            let mut scratch = vec![0u8; 8];
            scratch.extend_from_slice(&req_id.to_le_bytes());
            scratch.push(STATUS_OK);
            scratch.extend_from_slice(&body);
            finish_frame(&mut scratch);
            let mut vectored = head.to_vec();
            vectored.extend_from_slice(&body);
            assert_eq!(vectored, scratch, "body_len={body_len}");
            // And it parses back through the standard unframe path.
            let (payload, used) = unframe(&vectored).unwrap().unwrap();
            assert_eq!(used, vectored.len());
            assert_eq!(&payload[..8], &req_id.to_le_bytes());
        }
    }

    #[test]
    fn prop_read_frame_reassembles_hostile_splits() {
        // A peer that dribbles a frame in arbitrary chunks (with pauses)
        // must still produce exactly the sent payload through the
        // vectored read path — and through the portable one.
        use crate::util::prop::{check, Strategy};
        use crate::util::Rng;
        struct Case;
        impl Strategy for Case {
            type Value = (Vec<u8>, u64);
            fn gen(&self, rng: &mut Rng) -> (Vec<u8>, u64) {
                let n = rng.gen_range(600) as usize;
                ((0..n).map(|_| rng.next_u64() as u8).collect(), rng.next_u64())
            }
        }
        check("read-frame-splits", &Case, 30, |(payload, seed)| {
            let framed = crate::codec::frame(payload);
            let listener = TcpListener::bind("127.0.0.1:0").map_err(|e| e.to_string())?;
            let addr = listener.local_addr().unwrap();
            let mut tx = TcpStream::connect(addr).map_err(|e| e.to_string())?;
            let (mut rx, _) = listener.accept().map_err(|e| e.to_string())?;
            rx.set_read_timeout(Some(timeout())).map_err(|e| e.to_string())?;
            let bytes = framed;
            let seed = *seed;
            let writer = std::thread::spawn(move || {
                let mut rng = Rng::new(seed);
                let mut at = 0usize;
                while at < bytes.len() {
                    let n = rng.gen_range(7) as usize + 1;
                    let end = (at + n).min(bytes.len());
                    tx.write_all(&bytes[at..end]).unwrap();
                    if rng.gen_range(3) == 0 {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    at = end;
                }
            });
            let mut scratch = Vec::new();
            let got = read_frame(&mut rx, &mut scratch).map_err(|e| e.to_string());
            writer.join().unwrap();
            let range = got?;
            if &scratch[range] != payload.as_slice() {
                return Err("payload mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn read_frame_rejects_truncation_and_oversize_cleanly() {
        // Truncated mid-body: the reader must error (no hang, no panic).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut tx = TcpStream::connect(addr).unwrap();
        let (mut rx, _) = listener.accept().unwrap();
        rx.set_read_timeout(Some(timeout())).unwrap();
        let framed = crate::codec::frame(b"doomed payload");
        tx.write_all(&framed[..framed.len() - 3]).unwrap();
        drop(tx);
        let mut scratch = Vec::new();
        assert!(read_frame(&mut rx, &mut scratch).is_err());

        // A hostile length prefix past MAX_FRAME is rejected before any
        // allocation of that size.
        let mut tx = TcpStream::connect(addr).unwrap();
        let (mut rx, _) = listener.accept().unwrap();
        rx.set_read_timeout(Some(timeout())).unwrap();
        let mut evil = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        evil.extend_from_slice(&[0u8; 4]);
        evil.extend_from_slice(b"xxxxxxxx");
        tx.write_all(&evil).unwrap();
        let err = read_frame(&mut rx, &mut scratch).unwrap_err();
        assert!(err.to_string().contains("exceeds max"), "{err}");
    }

    #[test]
    fn event_mode_parks_idle_connections() {
        let server = serve_mode(PollMode::Event);
        if server.poll_mode() != PollMode::Event {
            return; // no epoll on this platform
        }
        let clients: Vec<RpcClient> = (0..6)
            .map(|_| RpcClient::new(&server.addr().to_string(), timeout()))
            .collect();
        for (i, c) in clients.iter().enumerate() {
            assert_eq!(c.call(0, &[i as u8]).unwrap(), [i as u8]);
        }
        // All six connections go quiet and return to the parked set.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while server.parked_connections() < 6 {
            assert!(std::time::Instant::now() < deadline, "connections never parked");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        // And they are still serviceable after parking.
        for (i, c) in clients.iter().enumerate() {
            assert_eq!(c.call(1, &[i as u8, 9]).unwrap(), [9, i as u8]);
        }
    }

    #[test]
    fn event_mode_batches_small_ready_sets() {
        let server = serve_mode(PollMode::Event);
        if server.poll_mode() != PollMode::Event {
            return; // no epoll on this platform
        }
        let clients: Vec<RpcClient> = (0..8)
            .map(|_| RpcClient::new(&server.addr().to_string(), timeout()))
            .collect();
        // Rounds of concurrent calls across the fleet: every call must
        // round-trip regardless of how the poller groups ready sets.
        for round in 0..20u8 {
            std::thread::scope(|s| {
                for (i, c) in clients.iter().enumerate() {
                    s.spawn(move || {
                        assert_eq!(c.call(0, &[round, i as u8]).unwrap(), [round, i as u8]);
                    });
                }
            });
        }
        let (dispatches, conns) = server.dispatch_stats();
        assert!(conns > 0, "no ready connections dispatched");
        assert!(
            dispatches <= conns,
            "batched dispatch accounting broken: {dispatches} > {conns}"
        );
    }

    #[test]
    fn tcp_error_propagates_and_connection_survives() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let ch = Channel::remote(&server.addr().to_string(), timeout());
        let err = ch.call(9, b"").unwrap_err();
        assert!(err.to_string().contains("degraded"), "{err}");
        // Same connection still usable after an application error.
        assert_eq!(ch.call(0, b"ok").unwrap(), b"ok");
    }

    #[test]
    fn tcp_large_payload() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let ch = Channel::remote(&server.addr().to_string(), timeout());
        let big: Vec<u8> = (0..2_000_000u32).map(|i| i as u8).collect();
        assert_eq!(ch.call(0, &big).unwrap(), big);
    }

    #[test]
    fn many_sequential_calls() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let client = RpcClient::new(&server.addr().to_string(), timeout());
        for i in 0..200u32 {
            let payload = i.to_le_bytes();
            assert_eq!(client.call(0, &payload).unwrap(), payload);
        }
    }

    #[test]
    fn concurrent_clients() {
        let server = Arc::new(RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap());
        let addr = server.addr().to_string();
        let mut handles = Vec::new();
        for t in 0..4u8 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let client = RpcClient::new(&addr, timeout());
                for i in 0..50u32 {
                    let payload = [t, i as u8];
                    assert_eq!(client.call(1, &payload).unwrap(), [i as u8, t]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn connect_refused_is_error_then_reconnects() {
        // Pick a port by binding+dropping a listener.
        let tmp = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = tmp.local_addr().unwrap().to_string();
        drop(tmp);
        let client = RpcClient::new(&addr, timeout());
        assert!(client.call(0, b"x").is_err());
        // Now start a real server on that address; client should reconnect.
        let _server = match RpcServer::serve(&addr, Arc::new(Echo)) {
            Ok(s) => s,
            Err(_) => return, // port grabbed by another process; skip rest
        };
        assert_eq!(client.call(0, b"x").unwrap(), b"x");
    }

    #[test]
    fn pool_smaller_than_connection_fleet_still_serves() {
        // 8 concurrent long-lived connections share 2 handler threads —
        // the high fan-in shape the pooled server exists for.
        let server = RpcServer::serve_pooled("127.0.0.1:0", Arc::new(Echo), 2).unwrap();
        let addr = server.addr().to_string();
        let mut handles = Vec::new();
        for t in 0..8u8 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let client = RpcClient::new(&addr, timeout());
                for i in 0..25u32 {
                    let payload = [t, i as u8];
                    assert_eq!(client.call(1, &payload).unwrap(), [i as u8, t]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn drop_joins_threads_and_closes_connections() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let addr = server.addr().to_string();
        let client = RpcClient::new(&addr, std::time::Duration::from_millis(500));
        assert_eq!(client.call(0, b"x").unwrap(), b"x");
        // Drop joins the poll thread and the handler pool and closes
        // the parked connection; the client then fails fast.
        drop(server);
        assert!(client.call(0, b"y").is_err());
    }

    #[test]
    fn shutdown_stops_accepting() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let addr = server.addr().to_string();
        server.shutdown();
        std::thread::sleep(std::time::Duration::from_millis(30));
        let client = RpcClient::new(&addr, std::time::Duration::from_millis(300));
        // Either connect fails or the read times out — must error out.
        assert!(client.call(0, b"x").is_err());
    }

    #[test]
    fn conn_shrink_caps_oversized_buffers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _peer = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let mut conn = Conn::new(stream);
        conn.rbuf.reserve(8 << 20);
        conn.wbuf.reserve(8 << 20);
        conn.shrink(1 << 16);
        assert!(conn.rbuf.capacity() <= 1 << 16, "rbuf kept {} bytes", conn.rbuf.capacity());
        assert!(conn.wbuf.capacity() <= 1 << 16, "wbuf kept {} bytes", conn.wbuf.capacity());
        // Under-cap buffers are left alone (no realloc churn).
        conn.rbuf.reserve(1024);
        let cap = conn.rbuf.capacity();
        conn.shrink(1 << 16);
        assert_eq!(conn.rbuf.capacity(), cap);
    }

    #[test]
    fn poll_mode_parses_and_resolves() {
        assert_eq!(PollMode::parse("auto").unwrap(), PollMode::Auto);
        assert_eq!(PollMode::parse("epoll").unwrap(), PollMode::Event);
        assert_eq!(PollMode::parse("event").unwrap(), PollMode::Event);
        assert_eq!(PollMode::parse("peek").unwrap(), PollMode::Peek);
        assert_eq!(PollMode::parse("uring").unwrap(), PollMode::Uring);
        assert!(PollMode::parse("select").is_err());
        assert_ne!(PollMode::Auto.resolve(), PollMode::Auto);
        assert_eq!(PollMode::Peek.resolve(), PollMode::Peek);
        // Uring resolves to itself; serve_with downgrades at runtime if
        // the kernel lacks the ring.
        assert_eq!(PollMode::Uring.resolve(), PollMode::Uring);
    }

    #[test]
    fn stall_timeout_drops_wedged_peer_without_blocking_pool() {
        // A 1-thread pool with an aggressive stall limit: a peer that
        // sends half a frame then goes silent must be dropped quickly and
        // the worker reclaimed for healthy clients.
        let server = RpcServer::serve_with(
            "127.0.0.1:0",
            Arc::new(Echo),
            RpcOptions {
                threads: 1,
                stall: Duration::from_millis(100),
                ..RpcOptions::default()
            },
        )
        .unwrap();
        let addr = server.addr().to_string();
        let mut wedged = TcpStream::connect(&addr).unwrap();
        // Half a header: the handler enters mid-header napping.
        wedged.write_all(&[1, 2, 3]).unwrap();
        std::thread::sleep(Duration::from_millis(250)); // > stall
        let client = RpcClient::new(&addr, timeout());
        assert_eq!(client.call(0, b"after-wedge").unwrap(), b"after-wedge");
    }

    /// Echo plus a deliberately slow bulk method, for admission tests.
    struct SlowBulk;

    impl Service for SlowBulk {
        fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
            match method {
                0 => Ok(payload.to_vec()),
                2 => {
                    std::thread::sleep(Duration::from_millis(300));
                    Ok(payload.to_vec())
                }
                _ => Err(Error::Rpc(format!("no method {method}"))),
            }
        }
    }

    fn qos_policy_for_test() -> QosPolicy {
        QosPolicy {
            predict_methods: vec![0],
            bulk_methods: vec![2],
            bulk_inflight_max: 1,
            control_inflight_max: 0,
        }
    }

    #[test]
    fn qos_sheds_bulk_over_cap_with_typed_nack() {
        let server = RpcServer::serve_with(
            "127.0.0.1:0",
            Arc::new(SlowBulk),
            RpcOptions { threads: 4, qos: Some(qos_policy_for_test()), ..RpcOptions::default() },
        )
        .unwrap();
        let addr = server.addr().to_string();
        // One bulk call occupies the only bulk slot for ~300 ms...
        let holder = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let c = RpcClient::new(&addr, timeout());
                c.call(2, b"bulk").unwrap()
            })
        };
        std::thread::sleep(Duration::from_millis(80));
        // ...so a second bulk call is shed with the typed status, while
        // predict traffic on the same server keeps flowing.
        let c = RpcClient::new(&addr, timeout());
        let err = c.call(2, b"burst").unwrap_err();
        assert!(err.is_overloaded(), "expected typed overload, got: {err}");
        assert_eq!(c.call(0, b"predict").unwrap(), b"predict");
        assert_eq!(holder.join().unwrap(), b"bulk");
        // The slot frees once the holder finishes.
        assert_eq!(c.call(2, b"later").unwrap(), b"later");
        let stats = server.qos_stats().expect("qos enabled");
        assert!(stats[QosClass::Bulk as usize].1 >= 1, "shed counter never moved: {stats:?}");
        assert!(stats[QosClass::Predict as usize].1 == 0, "predict must never shed: {stats:?}");
    }

    #[test]
    fn local_service_overload_stays_typed_over_tcp() {
        struct Shedding;
        impl Service for Shedding {
            fn call(&self, _m: u16, _p: &[u8]) -> Result<Vec<u8>> {
                Err(Error::Overloaded("queue full".into()))
            }
        }
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Shedding)).unwrap();
        let ch = Channel::remote(&server.addr().to_string(), timeout());
        let err = ch.call(0, b"").unwrap_err();
        assert!(err.is_overloaded(), "lost the typed status: {err}");
    }

    #[test]
    fn client_pool_serves_concurrent_callers() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let pool = ClientPool::new(&server.addr().to_string(), timeout(), 4);
        std::thread::scope(|s| {
            for t in 0..8u8 {
                let pool = &pool;
                s.spawn(move || {
                    for i in 0..25u8 {
                        assert_eq!(pool.call(1, &[t, i]).unwrap(), [i, t]);
                    }
                });
            }
        });
        // Pooled channel round-trips like any other.
        let ch = Channel::pooled(&server.addr().to_string(), timeout(), 2);
        assert_eq!(ch.call(0, b"pooled").unwrap(), b"pooled");
    }
}
