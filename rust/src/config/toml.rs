//! TOML-subset parser for WeiPS config files.
//!
//! Supports: `[section]` headers, `key = value` with string / integer /
//! float / boolean values, `#` comments, and blank lines. That covers the
//! launcher's needs without a toml crate (offline environment).

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

/// A parsed document: section -> key -> value. Keys outside any section
/// land in the "" section.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| Error::Config(format!("line {}: unclosed section", lineno + 1)))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
            let value = parse_value(value.trim())
                .map_err(|e| Error::Config(format!("line {}: {e}", lineno + 1)))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(doc)
    }

    /// Load and parse a file.
    pub fn load(path: &str) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {path}: {e}")))?;
        Self::parse(&text)
    }

    /// Raw value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    /// String value (only for string-typed keys).
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key)? {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer value.
    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key)? {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// Float value (ints coerce).
    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key)? {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// Boolean value.
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            TomlValue::Bool(v) => Some(*v),
            _ => None,
        }
    }

    /// Section names.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(TomlValue::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    Err(format!("cannot parse value: {s}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = TomlDoc::parse(
            r#"
            # top-level
            name = "weips"          # trailing comment
            [cluster]
            master_shards = 8
            ratio = 0.5
            enabled = true
            label = "a # not comment"
            [paths]
            root = "/tmp/x"
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("", "name"), Some("weips"));
        assert_eq!(doc.get_int("cluster", "master_shards"), Some(8));
        assert_eq!(doc.get_float("cluster", "ratio"), Some(0.5));
        assert_eq!(doc.get_float("cluster", "master_shards"), Some(8.0));
        assert_eq!(doc.get_bool("cluster", "enabled"), Some(true));
        assert_eq!(doc.get_str("cluster", "label"), Some("a # not comment"));
        assert_eq!(doc.get_str("paths", "root"), Some("/tmp/x"));
        assert_eq!(doc.get("nope", "k"), None);
        assert_eq!(doc.get_int("cluster", "ratio"), None); // type-checked
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
    }

    #[test]
    fn escaped_strings() {
        let doc = TomlDoc::parse(r#"k = "a\"b\\c""#).unwrap();
        assert_eq!(doc.get_str("", "k"), Some(r#"a"b\c"#));
    }

    #[test]
    fn sections_iterate() {
        let doc = TomlDoc::parse("[b]\nx=1\n[a]\ny=2").unwrap();
        let names: Vec<&str> = doc.sections().collect();
        assert_eq!(names, vec!["a", "b"]);
    }
}
