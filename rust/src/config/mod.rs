//! Configuration: model specs (table layouts per model kind) and cluster
//! topology, plus a TOML-subset parser for config files (no serde/toml
//! crates in the offline environment).

mod toml;

pub use toml::TomlDoc;

use crate::optim::{self, FtrlHyper, Optimizer};
use crate::runtime::ModelConfig;
use crate::util::Rng;
use crate::{Error, Result};
use std::sync::Arc;

/// Which model family a WeiPS deployment trains/serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Lr,
    Fm,
    DeepFm,
}

impl ModelKind {
    /// Parse from config string.
    pub fn parse(s: &str) -> Result<ModelKind> {
        match s {
            "lr" => Ok(ModelKind::Lr),
            "fm" => Ok(ModelKind::Fm),
            "deepfm" => Ok(ModelKind::DeepFm),
            other => Err(Error::Config(format!("unknown model kind {other}"))),
        }
    }

    /// AOT module names for this model.
    pub fn train_module(&self) -> &'static str {
        match self {
            ModelKind::Lr => "lr_train",
            ModelKind::Fm => "fm_train",
            ModelKind::DeepFm => "deepfm_train",
        }
    }

    /// Serving-graph module name.
    pub fn predict_module(&self) -> &'static str {
        match self {
            ModelKind::Lr => "lr_predict",
            ModelKind::Fm => "fm_predict",
            ModelKind::DeepFm => "deepfm_predict",
        }
    }
}

/// One sparse table's layout.
#[derive(Debug, Clone)]
pub struct SparseTableSpec {
    pub name: String,
    pub dim: usize,
    pub optimizer: String,
}

/// One dense table's layout.
#[derive(Debug, Clone)]
pub struct DenseTableSpec {
    pub name: String,
    pub len: usize,
    /// He-style init scale (0.0 = zeros).
    pub init_scale: f32,
}

/// Full model specification: what tables exist, how graph inputs assemble.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub kind: ModelKind,
    pub fields: usize,
    pub dim: usize,
    pub hidden: usize,
    pub batch_train: usize,
    pub batch_predict: usize,
    pub sparse: Vec<SparseTableSpec>,
    /// Dense tables in *graph input order* (after the sparse inputs).
    pub dense: Vec<DenseTableSpec>,
    pub ftrl: FtrlHyper,
}

impl ModelSpec {
    /// Derive the spec for `kind` from the AOT manifest config.
    pub fn derive(name: &str, kind: ModelKind, cfg: &ModelConfig) -> ModelSpec {
        let (f, k, h) = (cfg.fields, cfg.dim, cfg.hidden);
        let sparse = match kind {
            ModelKind::Lr => vec![SparseTableSpec { name: "w".into(), dim: 1, optimizer: "ftrl".into() }],
            ModelKind::Fm | ModelKind::DeepFm => vec![
                SparseTableSpec { name: "w".into(), dim: 1, optimizer: "ftrl".into() },
                SparseTableSpec { name: "v".into(), dim: k, optimizer: "ftrl".into() },
            ],
        };
        let dense = match kind {
            ModelKind::Lr | ModelKind::Fm => {
                vec![DenseTableSpec { name: "bias".into(), len: 1, init_scale: 0.0 }]
            }
            ModelKind::DeepFm => vec![
                DenseTableSpec { name: "bias".into(), len: 1, init_scale: 0.0 },
                DenseTableSpec { name: "w1".into(), len: f * k * h, init_scale: (2.0 / (f * k) as f32).sqrt() },
                DenseTableSpec { name: "b1".into(), len: h, init_scale: 0.0 },
                DenseTableSpec { name: "w2".into(), len: h, init_scale: (2.0 / h as f32).sqrt() },
                DenseTableSpec { name: "b2".into(), len: 1, init_scale: 0.0 },
            ],
        };
        ModelSpec {
            name: name.to_string(),
            kind,
            fields: f,
            dim: k,
            hidden: h,
            batch_train: cfg.batch_train,
            batch_predict: cfg.batch_predict,
            sparse,
            dense,
            ftrl: FtrlHyper {
                alpha: cfg.ftrl_alpha,
                beta: cfg.ftrl_beta,
                l1: cfg.ftrl_l1,
                l2: cfg.ftrl_l2,
            },
        }
    }

    /// Instantiate a sparse table's optimizer.
    pub fn optimizer_for(&self, table: &str) -> Result<Arc<dyn Optimizer>> {
        let spec = self
            .sparse
            .iter()
            .find(|t| t.name == table)
            .ok_or_else(|| Error::NotFound(format!("sparse table {table}")))?;
        optim::by_name(&spec.optimizer, &self.ftrl)
    }

    /// Deterministic initial values for a dense table (seeded by model +
    /// table name so every master shard / restart agrees).
    pub fn dense_init(&self, table: &DenseTableSpec) -> Vec<f32> {
        if table.init_scale == 0.0 {
            return vec![0.0; table.len];
        }
        let seed = crate::util::fxhash64(
            crate::util::fxhash64(self.name.len() as u64 ^ 0x5eed)
                ^ table.name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64)),
        );
        let mut rng = Rng::new(seed);
        (0..table.len)
            .map(|_| rng.gen_normal() as f32 * table.init_scale)
            .collect()
    }

    /// Sparse-table dims in graph input order (w, then v for FM/DeepFM).
    pub fn sparse_order(&self) -> Vec<(&str, usize)> {
        self.sparse.iter().map(|s| (s.name.as_str(), s.dim)).collect()
    }
}

/// Gather mode (§4.1.2): when the master flushes collected updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GatherMode {
    /// Flush on every push (most fresh, most bandwidth).
    Realtime,
    /// Flush when this many distinct dirty ids accumulate.
    Threshold(usize),
    /// Flush every `ms` milliseconds.
    Period(u64),
}

impl GatherMode {
    /// Parse "realtime" | "threshold:<n>" | "period:<ms>".
    pub fn parse(s: &str) -> Result<GatherMode> {
        if s == "realtime" {
            return Ok(GatherMode::Realtime);
        }
        if let Some(n) = s.strip_prefix("threshold:") {
            return n
                .parse()
                .map(GatherMode::Threshold)
                .map_err(|_| Error::Config(format!("bad threshold in {s}")));
        }
        if let Some(ms) = s.strip_prefix("period:") {
            return ms
                .parse()
                .map(GatherMode::Period)
                .map_err(|_| Error::Config(format!("bad period in {s}")));
        }
        Err(Error::Config(format!("unknown gather mode {s}")))
    }
}

/// Checkpoint strategy: monolithic full-shard snapshots every time, or
/// incremental chains (periodic bases + dirty-epoch delta chunks + WAL
/// journaling — see `storage::incremental`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CkptMode {
    Full,
    Incremental,
}

impl CkptMode {
    /// Parse "full" | "incremental".
    pub fn parse(s: &str) -> Result<CkptMode> {
        match s {
            "full" => Ok(CkptMode::Full),
            "incremental" => Ok(CkptMode::Incremental),
            other => Err(Error::Config(format!("unknown ckpt mode {other}"))),
        }
    }
}

/// Env-overridable thread-count default (`sync_threads`; `rpc_threads`
/// defers to [`crate::net::default_rpc_threads`], its single source of
/// truth).
fn env_threads(var: &str, default: u32) -> u32 {
    std::env::var(var).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Cluster topology + policies (defaults suit the examples and benches).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub model_name: String,
    pub model_kind: ModelKind,
    pub master_shards: u32,
    pub slave_shards: u32,
    pub slave_replicas: u32,
    pub queue_partitions: u32,
    pub gather_mode: GatherMode,
    /// Feature entry filter threshold (observations before materializing).
    pub entry_threshold: u32,
    /// Lock stripes per sparse table on master and slave shards (≥ 1).
    /// More stripes = more push/pull/gather concurrency per shard; the
    /// contended-throughput bench (`bench_throughput`) measures the curve.
    pub table_stripes: u32,
    /// Threads in the shared sync pool that parallelizes gather value
    /// snapshots, scatter applies and feature-expire passes across table
    /// stripes (0 = run those stages sequentially). `WEIPS_SYNC_THREADS`
    /// overrides the default; `bench_sync_pipeline` measures the curve.
    pub sync_threads: u32,
    /// Handler threads per RPC server (readiness-polled connection fleet
    /// shares this fixed pool instead of one thread per connection).
    /// `WEIPS_RPC_THREADS` overrides the default.
    pub rpc_threads: u32,
    /// RPC stalled-peer drop timeout (ms): a connection that stalls
    /// mid-frame or refuses writes for this long is dropped and its
    /// handler reclaimed. `WEIPS_RPC_STALL_MS` overrides the default.
    pub rpc_stall_ms: u64,
    /// Peek-mode poll back-off lower bound (ms) — the sweep interval
    /// while traffic flows. Irrelevant in epoll mode.
    pub rpc_poll_min_ms: u64,
    /// Peek-mode poll back-off upper bound (ms) — the interval an idle
    /// server backs off to. Irrelevant in epoll mode.
    pub rpc_poll_max_ms: u64,
    /// Readiness mechanism for parked RPC connections: `auto` (epoll
    /// where available), `epoll`, or `peek`. `WEIPS_RPC_POLL` overrides
    /// the default.
    pub rpc_poll_mode: crate::net::PollMode,
    /// QoS admission control on role RPC servers: requests classify into
    /// predict/bulk/control classes, and bulk bursts (migration pulls,
    /// checkpoint replication) over their in-flight cap are shed with a
    /// typed NACK so predict pulls are never starved.
    pub rpc_qos: bool,
    /// In-flight cap for bulk-class requests; 0 = half the RPC handler
    /// pool (at least 1), so predict/control always keep handlers.
    pub rpc_bulk_inflight_max: u32,
    /// Replica balance policy for predictor pull fan-out: `round_robin`,
    /// `least_loaded`, or `latency` (score replicas by observed mean
    /// service latency × queue depth, probing cold replicas first).
    pub replica_balance: crate::replica::BalancePolicy,
    /// mmap checkpoint/delta chunks on load instead of streaming them
    /// through a read+copy (recovery and slot-migration snapshot loads
    /// decode straight over the page cache). Platforms without the raw
    /// mmap binding fall back to streamed reads regardless.
    pub ckpt_mmap_load: bool,
    /// Sparse-table row storage: `arena` (per-stripe bump arenas,
    /// compacted during expire sweeps — pull gathers walk contiguous
    /// memory) or `boxed` (one heap allocation per row).
    pub table_row_store: crate::table::RowStore,
    /// Hot-id serving-cache capacity in rows per predictor process
    /// (0 disables the cache; invalidation is driven by the streaming
    /// scatter, so there is no TTL to tune).
    pub serving_cache_rows: u64,
    /// Warm connections per slave endpoint in a predictor's pull pool
    /// (concurrent predict threads to one slave share this many TCP
    /// connections instead of serializing on one).
    pub pull_pool_connections: u32,
    /// Virtual routing slots in the two-level id→slot→shard map (elastic
    /// resharding; ≥ the largest shard count the deployment will ever
    /// grow to). The slot hash never changes, so this must stay constant
    /// for a model's lifetime. `WEIPS_RESHARD_SLOTS` overrides the
    /// default.
    pub reshard_slots: u32,
    /// WAL fsync cadence: fsync each partition file every n-th append
    /// (power-loss durability); 0 = flush-only (append latency; a crash
    /// of the *process* still loses nothing thanks to torn-tail
    /// truncation). `WEIPS_WAL_SYNC_EVERY` overrides the default.
    pub wal_sync_every: u64,
    /// Feature expire TTL in ms (0 = never).
    pub feature_ttl_ms: u64,
    /// Checkpoint every ~this many ms (randomly jittered, §4.2.1a).
    pub ckpt_interval_ms: u64,
    /// Checkpoint strategy: incremental chains (default) or full
    /// snapshots every time.
    pub ckpt_mode: CkptMode,
    /// Incremental mode: chunks per chain — every `ckpt_base_every`-th
    /// checkpoint reseeds a full base (1 = every checkpoint is a base).
    pub ckpt_base_every: u64,
    /// Local checkpoint versions (full mode) or complete chains
    /// (incremental mode) to keep.
    pub ckpt_keep: usize,
    /// Replicate every k-th checkpoint to the remote tier.
    pub remote_every: u64,
    /// Node heartbeat session TTL.
    pub session_ttl_ms: u64,
    /// Serve the Prometheus `/metrics` endpoint from each role process.
    pub metrics_enabled: bool,
    /// Port for the `/metrics` endpoint (0 = ephemeral; the bound
    /// address is printed at startup either way).
    pub metrics_port: u16,
    /// Update-journey tracing: sample every n-th sync batch per shard
    /// into the `/trace` span ring (0 = off; the hot-path cost is then
    /// one relaxed atomic load + branch per stage). Sync-batch bytes are
    /// identical regardless — the trace context is derived from envelope
    /// fields, never carried on the wire.
    pub trace_sample_every: u64,
    /// `/healthz` degrades (`degraded: ...` body) when a replica's
    /// scatter lag exceeds this many records (0 = never degrade on lag).
    pub health_scatter_lag_max: u64,
    /// `/healthz` degrades when WAL appends since the last fsync exceed
    /// this bound (0 = never degrade on WAL lag; flush-only WALs never
    /// register the probe).
    pub health_wal_unsynced_max: u64,
    /// Alert-evaluator tick interval for the role's background ticker
    /// (0 = no ticker; rules still evaluate on the coordinator's control
    /// tick and on demand via `GET /alerts`).
    pub alert_eval_ms: u64,
    /// Directory for the structured event journal's `events.wal`
    /// persistence (empty = ring-buffer only, no file).
    pub alert_journal_dir: String,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            model_name: "ctr".into(),
            model_kind: ModelKind::Fm,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 2,
            queue_partitions: 4,
            gather_mode: GatherMode::Threshold(4096),
            entry_threshold: 1,
            table_stripes: 8,
            sync_threads: env_threads("WEIPS_SYNC_THREADS", 4),
            rpc_threads: crate::net::default_rpc_threads() as u32,
            rpc_stall_ms: crate::net::default_stall_ms(),
            rpc_poll_min_ms: 1,
            rpc_poll_max_ms: 10,
            rpc_poll_mode: crate::net::default_poll_mode(),
            rpc_qos: true,
            rpc_bulk_inflight_max: 0,
            replica_balance: crate::replica::BalancePolicy::RoundRobin,
            ckpt_mmap_load: true,
            table_row_store: crate::table::RowStore::Arena,
            serving_cache_rows: 1 << 20,
            pull_pool_connections: 4,
            reshard_slots: env_threads("WEIPS_RESHARD_SLOTS", 1024).clamp(1, 65536),
            wal_sync_every: crate::queue::default_wal_sync_every(),
            feature_ttl_ms: 0,
            ckpt_interval_ms: 10_000,
            ckpt_mode: CkptMode::Incremental,
            ckpt_base_every: 4,
            ckpt_keep: 5,
            remote_every: 4,
            session_ttl_ms: 3_000,
            metrics_enabled: true,
            metrics_port: 0,
            trace_sample_every: 0,
            health_scatter_lag_max: 1_000_000,
            health_wal_unsynced_max: 1_000_000,
            alert_eval_ms: 1_000,
            alert_journal_dir: String::new(),
        }
    }
}

impl ClusterConfig {
    /// Build the shared sync pool this config implies: one process-wide
    /// pool driving parallel gather snapshots, scatter applies and expire
    /// passes (`None` when `sync_threads = 0` — sequential stages). The
    /// single construction point for the knob→pool policy (coordinator
    /// and CLI roles both call this).
    pub fn sync_pool(&self) -> Option<Arc<crate::util::ThreadPool>> {
        (self.sync_threads > 0)
            .then(|| Arc::new(crate::util::ThreadPool::new(self.sync_threads as usize, "sync-pool")))
    }

    /// RPC server options this config implies — the single construction
    /// point for the RPC knob→option policy (all serving roles call
    /// this).
    pub fn rpc_options(&self) -> crate::net::RpcOptions {
        crate::net::RpcOptions {
            threads: self.rpc_threads.max(1) as usize,
            stall: std::time::Duration::from_millis(self.rpc_stall_ms.max(1)),
            poll_min_ms: self.rpc_poll_min_ms.max(1),
            poll_max_ms: self.rpc_poll_max_ms.max(self.rpc_poll_min_ms.max(1)),
            scratch_cap: crate::net::default_scratch_cap(),
            mode: self.rpc_poll_mode,
            qos: self
                .rpc_qos
                .then(|| crate::server::default_qos_policy(self.rpc_bulk_inflight_max as usize)),
        }
    }

    /// Apply `[cluster]` section overrides from a parsed TOML document.
    pub fn from_toml(doc: &TomlDoc) -> Result<ClusterConfig> {
        let mut c = ClusterConfig::default();
        if let Some(v) = doc.get_str("cluster", "model_name") {
            c.model_name = v.to_string();
        }
        if let Some(v) = doc.get_str("cluster", "model_kind") {
            c.model_kind = ModelKind::parse(v)?;
        }
        if let Some(v) = doc.get_int("cluster", "master_shards") {
            c.master_shards = v as u32;
        }
        if let Some(v) = doc.get_int("cluster", "slave_shards") {
            c.slave_shards = v as u32;
        }
        if let Some(v) = doc.get_int("cluster", "slave_replicas") {
            c.slave_replicas = v as u32;
        }
        if let Some(v) = doc.get_int("cluster", "queue_partitions") {
            c.queue_partitions = v as u32;
        }
        if let Some(v) = doc.get_str("cluster", "gather_mode") {
            c.gather_mode = GatherMode::parse(v)?;
        }
        if let Some(v) = doc.get_int("cluster", "entry_threshold") {
            c.entry_threshold = v as u32;
        }
        if let Some(v) = doc.get_int("cluster", "table_stripes") {
            // Clamp on the signed value: a negative entry must not wrap
            // into billions of stripes.
            c.table_stripes = v.clamp(1, u32::MAX as i64) as u32;
        }
        if let Some(v) = doc.get_int("cluster", "sync_threads") {
            c.sync_threads = v.clamp(0, u32::MAX as i64) as u32;
        }
        if let Some(v) = doc.get_int("cluster", "rpc_threads") {
            c.rpc_threads = v.clamp(1, u32::MAX as i64) as u32;
        }
        if let Some(v) = doc.get_int("cluster", "rpc_stall_ms") {
            c.rpc_stall_ms = v.max(1) as u64;
        }
        if let Some(v) = doc.get_int("cluster", "rpc_poll_min_ms") {
            c.rpc_poll_min_ms = v.max(1) as u64;
        }
        if let Some(v) = doc.get_int("cluster", "rpc_poll_max_ms") {
            c.rpc_poll_max_ms = v.max(1) as u64;
        }
        if let Some(v) = doc.get_str("cluster", "rpc_poll_mode") {
            c.rpc_poll_mode = crate::net::PollMode::parse(v)?;
        }
        if let Some(v) = doc.get_bool("cluster", "rpc_qos") {
            c.rpc_qos = v;
        }
        if let Some(v) = doc.get_int("cluster", "rpc_bulk_inflight_max") {
            c.rpc_bulk_inflight_max = v.clamp(0, u32::MAX as i64) as u32;
        }
        if let Some(v) = doc.get_str("cluster", "replica_balance") {
            c.replica_balance = crate::replica::BalancePolicy::parse(v)?;
        }
        if let Some(v) = doc.get_bool("cluster", "ckpt_mmap_load") {
            c.ckpt_mmap_load = v;
        }
        if let Some(v) = doc.get_str("cluster", "table_row_store") {
            c.table_row_store = crate::table::RowStore::parse(v)?;
        }
        if let Some(v) = doc.get_int("cluster", "serving_cache_rows") {
            c.serving_cache_rows = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("cluster", "pull_pool_connections") {
            c.pull_pool_connections = v.clamp(1, 1024) as u32;
        }
        if let Some(v) = doc.get_int("cluster", "reshard_slots") {
            // The slot universe is a u16 space; clamp hard so a typo can
            // neither zero it nor overflow slot ids.
            c.reshard_slots = v.clamp(1, u16::MAX as i64 + 1) as u32;
        }
        if let Some(v) = doc.get_int("cluster", "wal_sync_every") {
            c.wal_sync_every = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("cluster", "feature_ttl_ms") {
            c.feature_ttl_ms = v as u64;
        }
        if let Some(v) = doc.get_int("cluster", "ckpt_interval_ms") {
            c.ckpt_interval_ms = v as u64;
        }
        if let Some(v) = doc.get_str("cluster", "ckpt_mode") {
            c.ckpt_mode = CkptMode::parse(v)?;
        }
        if let Some(v) = doc.get_int("cluster", "ckpt_base_every") {
            c.ckpt_base_every = v.max(1) as u64;
        }
        if let Some(v) = doc.get_int("cluster", "ckpt_keep") {
            c.ckpt_keep = v as usize;
        }
        if let Some(v) = doc.get_int("cluster", "session_ttl_ms") {
            c.session_ttl_ms = v as u64;
        }
        if let Some(v) = doc.get_bool("cluster", "metrics_enabled") {
            c.metrics_enabled = v;
        }
        if let Some(v) = doc.get_int("cluster", "metrics_port") {
            c.metrics_port = v as u16;
        }
        if let Some(v) = doc.get_int("cluster", "trace_sample_every") {
            c.trace_sample_every = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("cluster", "health_scatter_lag_max") {
            c.health_scatter_lag_max = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("cluster", "health_wal_unsynced_max") {
            c.health_wal_unsynced_max = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("cluster", "alert_eval_ms") {
            c.alert_eval_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_str("cluster", "alert_journal_dir") {
            c.alert_journal_dir = v.to_string();
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_cfg() -> ModelConfig {
        ModelConfig {
            batch_train: 8,
            batch_predict: 2,
            fields: 4,
            dim: 2,
            hidden: 8,
            ftrl_block_rows: 64,
            ftrl_alpha: 0.05,
            ftrl_beta: 1.0,
            ftrl_l1: 1.0,
            ftrl_l2: 1.0,
        }
    }

    #[test]
    fn lr_spec_tables() {
        let s = ModelSpec::derive("m", ModelKind::Lr, &model_cfg());
        assert_eq!(s.sparse.len(), 1);
        assert_eq!(s.sparse[0].dim, 1);
        assert_eq!(s.dense.len(), 1);
        assert_eq!(s.kind.train_module(), "lr_train");
    }

    #[test]
    fn deepfm_spec_tables() {
        let s = ModelSpec::derive("m", ModelKind::DeepFm, &model_cfg());
        assert_eq!(s.sparse.len(), 2);
        assert_eq!(s.sparse[1].dim, 2);
        let names: Vec<&str> = s.dense.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(names, vec!["bias", "w1", "b1", "w2", "b2"]);
        assert_eq!(s.dense[1].len, 4 * 2 * 8);
        assert_eq!(s.kind.predict_module(), "deepfm_predict");
    }

    #[test]
    fn dense_init_deterministic_and_scaled() {
        let s = ModelSpec::derive("m", ModelKind::DeepFm, &model_cfg());
        let w1 = &s.dense[1];
        let a = s.dense_init(w1);
        let b = s.dense_init(w1);
        assert_eq!(a, b);
        assert!(a.iter().any(|x| *x != 0.0));
        let rms = (a.iter().map(|x| x * x).sum::<f32>() / a.len() as f32).sqrt();
        assert!((rms - w1.init_scale).abs() < w1.init_scale * 0.5, "rms {rms}");
        // Different tables get different values.
        let w2 = s.dense_init(&s.dense[3]);
        assert_ne!(a[0], w2[0]);
        // Zero-scale tables are zeros.
        assert!(s.dense_init(&s.dense[0]).iter().all(|x| *x == 0.0));
    }

    #[test]
    fn optimizer_for_resolves() {
        let s = ModelSpec::derive("m", ModelKind::Fm, &model_cfg());
        assert_eq!(s.optimizer_for("w").unwrap().name(), "ftrl");
        assert!(s.optimizer_for("zzz").is_err());
    }

    #[test]
    fn gather_mode_parsing() {
        assert_eq!(GatherMode::parse("realtime").unwrap(), GatherMode::Realtime);
        assert_eq!(GatherMode::parse("threshold:100").unwrap(), GatherMode::Threshold(100));
        assert_eq!(GatherMode::parse("period:250").unwrap(), GatherMode::Period(250));
        assert!(GatherMode::parse("sometimes").is_err());
        assert!(GatherMode::parse("threshold:x").is_err());
    }

    #[test]
    fn model_kind_parse() {
        assert_eq!(ModelKind::parse("deepfm").unwrap(), ModelKind::DeepFm);
        assert!(ModelKind::parse("transformer").is_err());
    }

    #[test]
    fn cluster_config_from_toml() {
        let doc = TomlDoc::parse(
            r#"
            [cluster]
            model_kind = "deepfm"
            master_shards = 8
            gather_mode = "period:100"
            table_stripes = 16
            sync_threads = 6
            rpc_threads = 12
            rpc_stall_ms = 2500
            rpc_poll_min_ms = 2
            rpc_poll_max_ms = 40
            rpc_poll_mode = "peek"
            ckpt_mode = "full"
            ckpt_base_every = 8
            "#,
        )
        .unwrap();
        let c = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(c.model_kind, ModelKind::DeepFm);
        assert_eq!(c.ckpt_mode, CkptMode::Full);
        assert_eq!(c.ckpt_base_every, 8);
        assert!(CkptMode::parse("woven").is_err());
        assert_eq!(ClusterConfig::default().ckpt_mode, CkptMode::Incremental);
        assert_eq!(c.master_shards, 8);
        assert_eq!(c.gather_mode, GatherMode::Period(100));
        assert_eq!(c.table_stripes, 16);
        assert_eq!(c.sync_threads, 6);
        assert_eq!(c.rpc_threads, 12);
        assert_eq!(c.rpc_stall_ms, 2500);
        assert_eq!(c.rpc_poll_min_ms, 2);
        assert_eq!(c.rpc_poll_max_ms, 40);
        assert_eq!(c.rpc_poll_mode, crate::net::PollMode::Peek);
        assert_eq!(c.slave_shards, 2); // default preserved
        let opts = c.rpc_options();
        assert_eq!(opts.threads, 12);
        assert_eq!(opts.stall, std::time::Duration::from_millis(2500));
        assert_eq!(opts.poll_min_ms, 2);
        assert_eq!(opts.poll_max_ms, 40);
        assert_eq!(opts.mode, crate::net::PollMode::Peek);
    }

    #[test]
    fn trace_and_health_knobs_parse() {
        let doc = TomlDoc::parse(
            "[cluster]\ntrace_sample_every = 64\nhealth_scatter_lag_max = 5000\nhealth_wal_unsynced_max = 0\n",
        )
        .unwrap();
        let c = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(c.trace_sample_every, 64);
        assert_eq!(c.health_scatter_lag_max, 5000);
        assert_eq!(c.health_wal_unsynced_max, 0);
        // Defaults: tracing off, generous (but finite) health bounds.
        let d = ClusterConfig::default();
        assert_eq!(d.trace_sample_every, 0);
        assert!(d.health_scatter_lag_max > 0);
        assert!(d.health_wal_unsynced_max > 0);
    }

    #[test]
    fn alert_knobs_parse_and_default() {
        let doc = TomlDoc::parse(
            "[cluster]\nalert_eval_ms = 250\nalert_journal_dir = \"/tmp/weips-events\"\n",
        )
        .unwrap();
        let c = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(c.alert_eval_ms, 250);
        assert_eq!(c.alert_journal_dir, "/tmp/weips-events");
        // Defaults: evaluator on at 1s, journal persistence off.
        let d = ClusterConfig::default();
        assert_eq!(d.alert_eval_ms, 1_000);
        assert!(d.alert_journal_dir.is_empty());
    }

    #[test]
    fn rpc_knobs_clamp_and_reject_bad_modes() {
        let doc = TomlDoc::parse(
            r#"
            [cluster]
            rpc_stall_ms = 0
            rpc_poll_min_ms = 20
            rpc_poll_max_ms = 5
            "#,
        )
        .unwrap();
        let c = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(c.rpc_stall_ms, 1); // never zero: would drop every peer
        let opts = c.rpc_options();
        // max is lifted to min so the back-off range stays well-formed.
        assert_eq!(opts.poll_min_ms, 20);
        assert_eq!(opts.poll_max_ms, 20);
        let bad = TomlDoc::parse("[cluster]\nrpc_poll_mode = \"select\"\n").unwrap();
        assert!(ClusterConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn reshard_and_wal_knobs_parse_and_clamp() {
        let doc = TomlDoc::parse(
            r#"
            [cluster]
            reshard_slots = 4096
            wal_sync_every = 32
            "#,
        )
        .unwrap();
        let c = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(c.reshard_slots, 4096);
        assert_eq!(c.wal_sync_every, 32);
        // Defaults: 1024-slot universe, flush-only WAL.
        let d = ClusterConfig::default();
        assert_eq!(d.reshard_slots, 1024);
        assert_eq!(d.wal_sync_every, 0);
        // The slot universe is a u16 space and never zero.
        let bad = TomlDoc::parse("[cluster]\nreshard_slots = 0\nwal_sync_every = -5\n").unwrap();
        let c = ClusterConfig::from_toml(&bad).unwrap();
        assert_eq!(c.reshard_slots, 1);
        assert_eq!(c.wal_sync_every, 0);
        let big = TomlDoc::parse("[cluster]\nreshard_slots = 999999\n").unwrap();
        assert_eq!(ClusterConfig::from_toml(&big).unwrap().reshard_slots, 65536);
    }

    #[test]
    fn serving_knobs_parse_clamp_and_resolve() {
        // Defaults: QoS on with auto bulk cap, cache on, 4-way pull pool.
        let d = ClusterConfig::default();
        assert!(d.rpc_qos);
        assert_eq!(d.rpc_bulk_inflight_max, 0);
        assert!(d.serving_cache_rows > 0);
        assert_eq!(d.pull_pool_connections, 4);
        let qos = d.rpc_options().qos.expect("qos on by default");
        assert!(qos.predict_methods.contains(&crate::server::methods::SPARSE_PULL));
        assert!(qos.bulk_methods.contains(&crate::server::methods::MIGRATE_PULL));
        let doc = TomlDoc::parse(
            r#"
            [cluster]
            rpc_qos = false
            rpc_bulk_inflight_max = 3
            serving_cache_rows = 4096
            pull_pool_connections = -2
            "#,
        )
        .unwrap();
        let c = ClusterConfig::from_toml(&doc).unwrap();
        assert!(!c.rpc_qos);
        assert!(c.rpc_options().qos.is_none());
        assert_eq!(c.rpc_bulk_inflight_max, 3);
        assert_eq!(c.serving_cache_rows, 4096);
        assert_eq!(c.pull_pool_connections, 1); // clamped: pool never empty
        let off = TomlDoc::parse("[cluster]\nserving_cache_rows = -1\n").unwrap();
        assert_eq!(ClusterConfig::from_toml(&off).unwrap().serving_cache_rows, 0);
    }

    #[test]
    fn substrate_knobs_parse_and_reject_bad_values() {
        // Defaults: arena rows, mmap loads, round-robin balance.
        let d = ClusterConfig::default();
        assert_eq!(d.replica_balance, crate::replica::BalancePolicy::RoundRobin);
        assert!(d.ckpt_mmap_load);
        assert_eq!(d.table_row_store, crate::table::RowStore::Arena);
        let doc = TomlDoc::parse(
            r#"
            [cluster]
            replica_balance = "latency"
            ckpt_mmap_load = false
            table_row_store = "boxed"
            rpc_poll_mode = "uring"
            "#,
        )
        .unwrap();
        let c = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(c.replica_balance, crate::replica::BalancePolicy::LatencyAware);
        assert!(!c.ckpt_mmap_load);
        assert_eq!(c.table_row_store, crate::table::RowStore::Boxed);
        assert_eq!(c.rpc_poll_mode, crate::net::PollMode::Uring);
        let bad = TomlDoc::parse("[cluster]\nreplica_balance = \"fastest\"\n").unwrap();
        assert!(ClusterConfig::from_toml(&bad).is_err());
        let bad = TomlDoc::parse("[cluster]\ntable_row_store = \"slab\"\n").unwrap();
        assert!(ClusterConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn thread_knobs_clamp_to_sane_ranges() {
        let doc = TomlDoc::parse(
            r#"
            [cluster]
            sync_threads = -3
            rpc_threads = -1
            "#,
        )
        .unwrap();
        let c = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(c.sync_threads, 0); // negative -> sequential
        assert_eq!(c.rpc_threads, 1); // server always has >= 1 handler
    }
}
