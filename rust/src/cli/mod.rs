//! `weips` CLI: role launcher (scheduler-embedded broker, master shards,
//! slave replicas, trainer/predictor workers) plus an all-in-one `local`
//! mode. Argument parsing is hand-rolled (no clap offline).

mod args;
mod roles;
mod top;

pub use args::Args;

use crate::Result;

const HELP: &str = r#"weips — symmetric fusion parameter server (WeiPS reproduction)

USAGE:
    weips <ROLE> [FLAGS]

ROLES:
    local       all-in-one in-process cluster: trains the synthetic CTR
                stream, streams updates to serving replicas, prints metrics
    broker      queue broker (the external-queue service)
    master      one master PS shard (training-facing)
    slave       one slave PS replica (serving-facing)
    trainer     training worker loop
    predictor   serving worker loop
    top         live one-screen ops dashboard over a metrics endpoint
    help        this text

COMMON FLAGS:
    --artifacts <dir>       AOT artifacts dir      [default: ./artifacts]
    --model <lr|fm|deepfm>  model kind             [default: fm]
    --config <file>         TOML config ([cluster] section)
    --metrics-port <p>      Prometheus /metrics port (0 = ephemeral;
                            bound address printed at startup)
    --metrics-enabled <0|1> serve /metrics          [default: 1]
    --metrics-targets a,b   host:port peers for the aggregated /cluster
                            view on this role's metrics endpoint

LOCAL MODE:
    weips local --steps 500 --masters 4 --slaves 2 --replicas 2 \
                --gather threshold:4096 --report-every 50

DISTRIBUTED (one process per role, same machine or not):
    weips broker    --addr 127.0.0.1:7100 --partitions 4
    weips master    --shard 0 --addr 127.0.0.1:7200 --broker 127.0.0.1:7100 \
                    --masters 4
    weips slave     --shard 0 --replica 0 --addr 127.0.0.1:7300 \
                    --broker 127.0.0.1:7100 --masters 4 --slaves 2
    weips trainer   --masters-at 127.0.0.1:7200,127.0.0.1:7201,... --steps 1000
    weips predictor --slaves-at "127.0.0.1:7300,127.0.0.1:7301;127.0.0.1:7302" \
                    --requests 1000

OPS:
    weips top --endpoint 127.0.0.1:9100 [--interval-ms 1000] [--once 1]
              live dashboard: push→visible p50/p99, queue depth, scatter
              lag, WAL fsync lag, slot-heat sparkline, QoS sheds, engaged
              degradation modes, trace-stage breakdown. Prefers the
              endpoint's aggregated /cluster view, falls back to /metrics.
    Tracing:  every role accepts --trace-sample-every N (sample every
              n-th sync batch into GET /trace; 0 = off) plus
              --health-scatter-lag-max / --health-wal-unsynced-max
              readiness bounds for /healthz.
    Alerts:   every role evaluates the declared alert rules (GET /alerts,
              gauge weips_alert_state) on an --alert-eval-ms cadence
              (default 1000; 0 = coordinator/control-tick only) and logs
              state transitions, degradations, checkpoints, reshards and
              recoveries to the structured event journal (GET /events;
              --alert-journal-dir <dir> persists it across restarts).
              Firing quality rules drive the domino rollback machinery.
"#;

/// CLI entry point.
pub fn run(argv: Vec<String>) -> Result<()> {
    let Some((role, rest)) = argv.split_first() else {
        println!("{HELP}");
        return Ok(());
    };
    let args = Args::parse(rest)?;
    match role.as_str() {
        "local" => roles::run_local(&args),
        "broker" => roles::run_broker(&args),
        "master" => roles::run_master(&args),
        "slave" => roles::run_slave(&args),
        "trainer" => roles::run_trainer(&args),
        "predictor" => roles::run_predictor(&args),
        "top" => top::run_top(&args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => {
            println!("unknown role '{other}'\n\n{HELP}");
            std::process::exit(2);
        }
    }
}
