//! Role entrypoints for the `weips` binary.

use std::sync::Arc;
use std::time::Duration;

use super::Args;
use crate::config::{ClusterConfig, GatherMode, ModelKind, ModelSpec, TomlDoc};
use crate::coordinator::{ClusterOpts, LocalCluster};
use crate::net::{Channel, RpcServer};
use crate::queue::{Queue, QueueService, RemoteLog, SyncLog};
use crate::replica::{BalancePolicy, ReplicaGroup};
use crate::runtime::Engine;
use crate::sample::{Workload, WorkloadConfig};
use crate::server::master::{MasterService, MasterShard};
use crate::server::slave::{SlaveService, SlaveShard};
use crate::storage::CheckpointStore;
use crate::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use crate::util::clock::SystemClock;
use crate::worker::{Predictor, ShardedClient, SlaveClient, SlaveEndpoint, Trainer};
use crate::{Error, Result};

const RPC_TIMEOUT: Duration = Duration::from_secs(10);

fn cluster_config(args: &Args) -> Result<ClusterConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ClusterConfig::from_toml(&TomlDoc::load(path)?)?,
        None => ClusterConfig::default(),
    };
    if let Some(kind) = args.get("model") {
        cfg.model_kind = ModelKind::parse(kind)?;
    }
    cfg.master_shards = args.get_u64("masters", cfg.master_shards as u64)? as u32;
    cfg.slave_shards = args.get_u64("slaves", cfg.slave_shards as u64)? as u32;
    cfg.slave_replicas = args.get_u64("replicas", cfg.slave_replicas as u64)? as u32;
    cfg.queue_partitions = args.get_u64("partitions", cfg.master_shards as u64)? as u32;
    if let Some(g) = args.get("gather") {
        cfg.gather_mode = GatherMode::parse(g)?;
    }
    cfg.ckpt_interval_ms = args.get_u64("ckpt-interval-ms", cfg.ckpt_interval_ms)?;
    cfg.sync_threads = args.get_u64("sync-threads", cfg.sync_threads as u64)? as u32;
    cfg.rpc_threads = args.get_u64("rpc-threads", cfg.rpc_threads as u64)?.max(1) as u32;
    Ok(cfg)
}

fn load_engine(args: &Args) -> Result<Arc<Engine>> {
    let dir = args.get_or("artifacts", crate::runtime::default_artifacts_dir().to_str().unwrap());
    Ok(Arc::new(Engine::load(dir)?))
}

fn block_forever() -> ! {
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

/// `weips local`: full in-process cluster on the synthetic CTR stream.
pub fn run_local(args: &Args) -> Result<()> {
    let steps = args.get_u64("steps", 300)?;
    let report = args.get_u64("report-every", 50)?.max(1);
    let serve_every = args.get_u64("serve-every", 25)?.max(1);
    let cfg = cluster_config(args)?;
    println!(
        "weips local: model={:?} masters={} slaves={}x{} gather={:?} steps={steps}",
        cfg.model_kind, cfg.master_shards, cfg.slave_shards, cfg.slave_replicas, cfg.gather_mode
    );
    let cluster = LocalCluster::new(ClusterOpts {
        cluster: cfg,
        artifacts_dir: args
            .get("artifacts")
            .map(Into::into)
            .unwrap_or_else(crate::runtime::default_artifacts_dir),
        ..Default::default()
    })?;
    for step in 1..=steps {
        let loss = cluster.train_step()?;
        cluster.sync_tick()?;
        if step % 10 == 0 {
            cluster.control_tick()?;
        }
        if step % serve_every == 0 {
            let reqs = cluster.serving_requests(8);
            let preds = cluster.predict(&reqs)?;
            let mean: f32 = preds.iter().sum::<f32>() / preds.len() as f32;
            if step % report == 0 {
                let snap = cluster.monitor.snapshot();
                println!(
                    "step {step:>6}  loss={loss:.4}  auc={:.4}  window_auc={:.4}  logloss={:.4}  served_mean_ctr={mean:.3}  sync_lag={}",
                    snap.auc, snap.window_auc, snap.logloss, cluster.sync_lag()
                );
            }
        } else if step % report == 0 {
            let snap = cluster.monitor.snapshot();
            println!(
                "step {step:>6}  loss={loss:.4}  auc={:.4}  window_auc={:.4}  logloss={:.4}",
                snap.auc, snap.window_auc, snap.logloss
            );
        }
    }
    cluster.flush_sync()?;
    let v = cluster.checkpoint()?;
    let snap = cluster.monitor.snapshot();
    println!(
        "done: {} samples, auc={:.4}, logloss={:.4}, checkpoint v{v}",
        snap.samples, snap.auc, snap.logloss
    );
    Ok(())
}

/// `weips broker`: run the external-queue service.
pub fn run_broker(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7100");
    let partitions = args.get_u64("partitions", 4)? as usize;
    let model = args.get_or("model-name", "ctr");
    let cfg = cluster_config(args)?;
    let queue = Queue::default();
    let topic = queue.create_topic(&format!("sync.{model}"), partitions)?;
    let server =
        RpcServer::serve_with(&addr, Arc::new(QueueService { topic }), cfg.rpc_options())?;
    println!("broker on {} ({partitions} partitions)", server.addr());
    block_forever()
}

/// `weips master`: one master shard + its sync pipeline.
pub fn run_master(args: &Args) -> Result<()> {
    let shard = args.get_u64("shard", 0)? as u32;
    let addr = args.get_or("addr", "127.0.0.1:7200");
    let broker = args.get_or("broker", "127.0.0.1:7100");
    let cfg = cluster_config(args)?;
    let engine = load_engine(args)?;
    let spec = ModelSpec::derive(&cfg.model_name, cfg.model_kind, engine.config());
    let clock = Arc::new(SystemClock);
    let master = Arc::new(MasterShard::with_stripes(
        shard,
        spec,
        Some(engine),
        cfg.entry_threshold,
        cfg.table_stripes as usize,
        clock.clone(),
    )?);
    let data_dir: std::path::PathBuf = args.get_or("data-dir", "/tmp/weips-data").into();
    let store = Arc::new(CheckpointStore::new(data_dir, None));
    let server = RpcServer::serve_with(
        &addr,
        Arc::new(MasterService { shard: master.clone(), store: Some(store) }),
        cfg.rpc_options(),
    )?;
    println!("master shard {shard} on {} (broker {broker})", server.addr());

    // Sync pump: gather -> pusher against the remote broker; snapshots
    // fan out over the shared sync pool.
    let log: Arc<dyn SyncLog> =
        Arc::new(RemoteLog::connect(Channel::remote(&broker, RPC_TIMEOUT))?);
    let mut gather = Gather::with_pool(master, cfg.gather_mode, clock, cfg.sync_pool());
    let pusher = Pusher::new(log, shard);
    loop {
        let batches = gather.poll();
        if batches.is_empty() {
            std::thread::sleep(Duration::from_millis(5));
        } else {
            pusher.push_all(&batches)?;
        }
    }
}

fn slave_layout(spec: &ModelSpec) -> Result<(Vec<(String, usize)>, Vec<(String, usize)>, Arc<ServingWeights>)> {
    let tables: Vec<(String, usize)> =
        spec.sparse.iter().map(|t| (t.name.clone(), t.dim)).collect();
    let dense: Vec<(String, usize)> = spec.dense.iter().map(|d| (d.name.clone(), d.len)).collect();
    let transform = Arc::new(ServingWeights::new(
        spec.sparse
            .iter()
            .map(|t| Ok((t.name.clone(), spec.optimizer_for(&t.name)?, t.dim)))
            .collect::<Result<Vec<_>>>()?,
    ));
    Ok((tables, dense, transform))
}

/// `weips slave`: one slave replica + its scatter consumer.
pub fn run_slave(args: &Args) -> Result<()> {
    let shard = args.get_u64("shard", 0)? as u32;
    let replica = args.get_u64("replica", 0)? as u32;
    let addr = args.get_or("addr", "127.0.0.1:7300");
    let broker = args.get_or("broker", "127.0.0.1:7100");
    let cfg = cluster_config(args)?;
    let engine = load_engine(args)?;
    let spec = ModelSpec::derive(&cfg.model_name, cfg.model_kind, engine.config());
    let (tables, dense, transform) = slave_layout(&spec)?;
    let slave = Arc::new(SlaveShard::with_stripes(
        shard,
        replica,
        &cfg.model_name,
        tables,
        dense,
        transform,
        Router::new(cfg.slave_shards),
        cfg.table_stripes as usize,
    ));
    let server = RpcServer::serve_with(
        &addr,
        Arc::new(SlaveService { shard: slave.clone() }),
        cfg.rpc_options(),
    )?;
    println!(
        "slave {shard}/{replica} on {} (broker {broker}, {} slave shards)",
        server.addr(),
        cfg.slave_shards
    );
    let log: Arc<dyn SyncLog> =
        Arc::new(RemoteLog::connect(Channel::remote(&broker, RPC_TIMEOUT))?);
    let mut scatter = Scatter::with_pool(
        log,
        slave,
        cfg.master_shards,
        cfg.slave_shards,
        Arc::new(SystemClock),
        cfg.sync_pool(),
    );
    println!("consuming partitions {:?}", scatter.partitions());
    loop {
        if scatter.poll(Duration::from_millis(50))? == 0 {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// `weips trainer`: training worker against remote masters.
pub fn run_trainer(args: &Args) -> Result<()> {
    let masters_at = args
        .get("masters-at")
        .ok_or_else(|| Error::Config("trainer needs --masters-at a,b,c".into()))?;
    let steps = args.get_u64("steps", 1000)?;
    let cfg = cluster_config(args)?;
    let engine = load_engine(args)?;
    let spec = ModelSpec::derive(&cfg.model_name, cfg.model_kind, engine.config());
    let channels: Vec<Channel> = masters_at
        .split(',')
        .map(|a| Channel::remote(a.trim(), RPC_TIMEOUT))
        .collect();
    let monitor = Arc::new(crate::monitor::Monitor::new(4096));
    let trainer = Trainer::new(
        engine,
        spec.clone(),
        ShardedClient::new(&cfg.model_name, channels),
        monitor.clone(),
    );
    let mut workload = Workload::new(WorkloadConfig { fields: spec.fields, ..Default::default() });
    for step in 1..=steps {
        let samples = workload.batch(step * 100, spec.batch_train);
        let out = trainer.train_batch(&samples)?;
        if step % 50 == 0 {
            let snap = monitor.snapshot();
            println!("step {step:>6} loss={:.4} auc={:.4}", out.loss, snap.auc);
        }
    }
    Ok(())
}

/// `weips predictor`: serving worker against remote slave groups.
pub fn run_predictor(args: &Args) -> Result<()> {
    let slaves_at = args
        .get("slaves-at")
        .ok_or_else(|| Error::Config("predictor needs --slaves-at 'a,b;c,d' (';' splits shards)".into()))?;
    let requests = args.get_u64("requests", 1000)?;
    let cfg = cluster_config(args)?;
    let engine = load_engine(args)?;
    let spec = ModelSpec::derive(&cfg.model_name, cfg.model_kind, engine.config());
    let groups: Vec<Arc<ReplicaGroup<SlaveEndpoint>>> = slaves_at
        .split(';')
        .map(|group| {
            let endpoints: Vec<Arc<SlaveEndpoint>> = group
                .split(',')
                .map(|a| {
                    Arc::new(SlaveEndpoint::remote(Channel::remote(a.trim(), RPC_TIMEOUT)))
                })
                .collect();
            Arc::new(ReplicaGroup::new(endpoints, BalancePolicy::RoundRobin))
        })
        .collect();
    let predictor = Predictor::new(
        engine,
        spec.clone(),
        SlaveClient::new(&cfg.model_name, groups),
    );
    let mut workload = Workload::new(WorkloadConfig { fields: spec.fields, ..Default::default() });
    let mut served = 0u64;
    while served < requests {
        let batch: Vec<Vec<u64>> = workload
            .batch(served * 10, spec.batch_predict)
            .into_iter()
            .map(|s| s.ids)
            .collect();
        let preds = predictor.predict(&batch)?;
        served += preds.len() as u64;
    }
    println!(
        "served {served} requests: latency {}",
        predictor.metrics.latency_ns.summary()
    );
    Ok(())
}
