//! Role entrypoints for the `weips` binary.

use std::sync::Arc;
use std::time::Duration;

use super::Args;
use crate::config::{CkptMode, ClusterConfig, GatherMode, ModelKind, ModelSpec, TomlDoc};
use crate::coordinator::{ClusterOpts, LocalCluster};
use crate::meta::MetaStore;
use crate::net::{Channel, RpcServer};
use crate::queue::{Queue, QueueService, RemoteLog, SyncLog, WalLog};
use crate::replica::{BalancePolicy, ReplicaGroup};
use crate::runtime::Engine;
use crate::sample::{Workload, WorkloadConfig};
use crate::scheduler::{CkptPolicy, Scheduler};
use crate::server::master::{MasterService, MasterShard};
use crate::server::slave::{SlaveService, SlaveShard};
use crate::storage::incremental::{self, IncrPolicy, WalJournal};
use crate::storage::CheckpointStore;
use crate::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use crate::util::clock::{Clock, SystemClock};
use crate::worker::{Predictor, ShardedClient, SlaveClient, SlaveEndpoint, Trainer};
use crate::{Error, Result};

const RPC_TIMEOUT: Duration = Duration::from_secs(10);

fn cluster_config(args: &Args) -> Result<ClusterConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ClusterConfig::from_toml(&TomlDoc::load(path)?)?,
        None => ClusterConfig::default(),
    };
    if let Some(kind) = args.get("model") {
        cfg.model_kind = ModelKind::parse(kind)?;
    }
    cfg.master_shards = args.get_u64("masters", cfg.master_shards as u64)? as u32;
    cfg.slave_shards = args.get_u64("slaves", cfg.slave_shards as u64)? as u32;
    cfg.slave_replicas = args.get_u64("replicas", cfg.slave_replicas as u64)? as u32;
    cfg.queue_partitions = args.get_u64("partitions", cfg.master_shards as u64)? as u32;
    if let Some(g) = args.get("gather") {
        cfg.gather_mode = GatherMode::parse(g)?;
    }
    cfg.ckpt_interval_ms = args.get_u64("ckpt-interval-ms", cfg.ckpt_interval_ms)?;
    if let Some(mode) = args.get("ckpt-mode") {
        cfg.ckpt_mode = CkptMode::parse(mode)?;
    }
    cfg.ckpt_base_every = args.get_u64("ckpt-base-every", cfg.ckpt_base_every)?.max(1);
    cfg.sync_threads = args.get_u64("sync-threads", cfg.sync_threads as u64)? as u32;
    cfg.rpc_threads = args.get_u64("rpc-threads", cfg.rpc_threads as u64)?.max(1) as u32;
    cfg.reshard_slots =
        args.get_u64("reshard-slots", cfg.reshard_slots as u64)?.clamp(1, 65536) as u32;
    cfg.wal_sync_every = args.get_u64("wal-sync-every", cfg.wal_sync_every)?;
    cfg.metrics_port = args.get_u64("metrics-port", cfg.metrics_port as u64)? as u16;
    if let Some(v) = args.get("metrics-enabled") {
        cfg.metrics_enabled = v != "0";
    }
    if let Some(b) = args.get("balance") {
        cfg.replica_balance = BalancePolicy::parse(b)?;
    }
    if let Some(v) = args.get("ckpt-mmap") {
        cfg.ckpt_mmap_load = v != "0";
    }
    if let Some(rs) = args.get("row-store") {
        cfg.table_row_store = crate::table::RowStore::parse(rs)?;
    }
    if let Some(p) = args.get("poll-mode") {
        cfg.rpc_poll_mode = crate::net::PollMode::parse(p)?;
    }
    cfg.trace_sample_every = args.get_u64("trace-sample-every", cfg.trace_sample_every)?;
    cfg.health_scatter_lag_max =
        args.get_u64("health-scatter-lag-max", cfg.health_scatter_lag_max)?;
    cfg.health_wal_unsynced_max =
        args.get_u64("health-wal-unsynced-max", cfg.health_wal_unsynced_max)?;
    cfg.alert_eval_ms = args.get_u64("alert-eval-ms", cfg.alert_eval_ms)?;
    if let Some(d) = args.get("alert-journal-dir") {
        cfg.alert_journal_dir = d.to_string();
    }
    Ok(cfg)
}

/// Start this role's Prometheus endpoint per the `metrics_enabled` /
/// `metrics_port` knobs, plus the background alert-rule evaluator
/// (`alert_eval_ms`). `--metrics-targets a,b` additionally enables the
/// aggregated `/cluster` view over those peers. Returns the server and
/// ticker handles — bind them for the role's lifetime (dropping them
/// stops the endpoint and the evaluator thread).
fn serve_role_metrics(
    args: &Args,
    role: &str,
    cfg: &ClusterConfig,
) -> Result<(Option<crate::metrics::http::MetricsServer>, Option<crate::alerts::Ticker>)> {
    // Process-global observability knobs: the trace sampling cadence, the
    // /healthz readiness bounds, and the event-journal persistence apply
    // whether or not this role serves the endpoint (another process may
    // scrape it via --metrics-targets).
    crate::trace::configure(cfg.trace_sample_every);
    crate::metrics::set_health_bound(
        "scatter_lag_records",
        Some(cfg.health_scatter_lag_max as f64),
    );
    crate::metrics::set_health_bound(
        "wal_unsynced_appends",
        Some(cfg.health_wal_unsynced_max as f64),
    );
    if !cfg.alert_journal_dir.is_empty() {
        crate::alerts::set_journal_dir(Some(std::path::Path::new(&cfg.alert_journal_dir)))
            .map_err(|e| {
                Error::Config(format!("alert_journal_dir {}: {e}", cfg.alert_journal_dir))
            })?;
    }
    // The evaluator runs even without the HTTP endpoint: it still drives
    // the alert-state gauges and the persisted event journal for this
    // process.
    let ticker = crate::alerts::spawn_ticker(role, cfg.alert_eval_ms);
    if !cfg.metrics_enabled {
        return Ok((None, ticker));
    }
    let targets: Vec<String> = args
        .get("metrics-targets")
        .map(|t| t.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
        .unwrap_or_default();
    let addr = format!("127.0.0.1:{}", cfg.metrics_port);
    let server = crate::metrics::http::MetricsServer::serve_with_targets(&addr, targets)?;
    println!("metrics on http://{}/metrics", server.addr());
    Ok((Some(server), ticker))
}

fn load_engine(args: &Args) -> Result<Arc<Engine>> {
    let dir = args.get_or("artifacts", crate::runtime::default_artifacts_dir().to_str().unwrap());
    Ok(Arc::new(Engine::load(dir)?))
}

fn block_forever() -> ! {
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

/// Fetch the published slot map from the first master in `channels`
/// that answers [`methods::FETCH_SLOT_MAP`]. `None` when no channel is
/// reachable or no master has a route guard installed (a cold cluster
/// routes by the canonical uniform map and publishes nothing).
fn fetch_slot_map(channels: &[Channel]) -> Option<crate::reshard::SlotMap> {
    for ch in channels {
        if let Ok(bytes) = ch.call(crate::server::methods::FETCH_SLOT_MAP, &[]) {
            if let Ok(map) = crate::reshard::SlotMap::from_bytes(&bytes) {
                return Some(map);
            }
        }
    }
    None
}

/// Build a [`crate::worker::client::RouteRefresher`] over the master
/// `channels`: invoked by clients on a [`Error::StaleRoute`] NACK, it
/// re-fetches the published slot map and installs it when the routing
/// epoch advanced — remote workers converge on a live migration without
/// a restart. Maps from a different slot universe are ignored (a skewed
/// universe would route through the wrong slot hash).
pub fn route_refresher(channels: Vec<Channel>) -> crate::worker::client::RouteRefresher {
    Arc::new(move |router: &Router| {
        if let Some(map) = fetch_slot_map(&channels) {
            if map.epoch > router.epoch() && map.slots() == router.snapshot().slots() {
                let _ = router.install(map);
            }
        }
    })
}

/// `weips local`: full in-process cluster on the synthetic CTR stream.
/// `--reshard-at N` runs a live slot migration (`--reshard-from`,
/// `--reshard-to`, `--reshard-count`) at step N, under the training
/// traffic — the elastic-resharding demo.
pub fn run_local(args: &Args) -> Result<()> {
    let steps = args.get_u64("steps", 300)?;
    let report = args.get_u64("report-every", 50)?.max(1);
    let serve_every = args.get_u64("serve-every", 25)?.max(1);
    let reshard_at = args.get_u64("reshard-at", 0)?;
    let reshard_from = args.get_u64("reshard-from", 0)? as u32;
    let reshard_to = args.get_u64("reshard-to", 1)? as u32;
    let reshard_count = args.get_u64("reshard-count", 0)? as usize;
    let cfg = cluster_config(args)?;
    println!(
        "weips local: model={:?} masters={} slaves={}x{} gather={:?} steps={steps}",
        cfg.model_kind, cfg.master_shards, cfg.slave_shards, cfg.slave_replicas, cfg.gather_mode
    );
    let cluster = LocalCluster::new(ClusterOpts {
        cluster: cfg.clone(),
        artifacts_dir: args
            .get("artifacts")
            .map(Into::into)
            .unwrap_or_else(crate::runtime::default_artifacts_dir),
        ..Default::default()
    })?;
    let _metrics = serve_role_metrics(args, "coordinator", &cfg)?;
    for step in 1..=steps {
        let loss = cluster.train_step()?;
        cluster.sync_tick()?;
        if reshard_at != 0 && step == reshard_at {
            let map = cluster.master_router.snapshot();
            let count = if reshard_count == 0 {
                map.slots_of(reshard_from).len() / 2
            } else {
                reshard_count
            };
            let slots = crate::reshard::pick_donor_slots(&map, reshard_from, count)?;
            let r = cluster.migrate_slots(reshard_from, reshard_to, &slots)?;
            println!(
                "step {step:>6}  resharded: {} slots {reshard_from}->{reshard_to} \
                 (base {} rows, {} catch-up rounds / {} rows, {} in the sealed window, \
                 purged {}, routing epoch {})",
                r.slots_moved,
                r.base_rows,
                r.catchup_rounds,
                r.catchup_rows,
                r.final_rows,
                r.purged_rows,
                cluster.master_router.epoch()
            );
        }
        if step % 10 == 0 {
            cluster.control_tick()?;
        }
        if step % serve_every == 0 {
            let reqs = cluster.serving_requests(8);
            let preds = cluster.predict(&reqs)?;
            let mean: f32 = preds.iter().sum::<f32>() / preds.len() as f32;
            if step % report == 0 {
                let snap = cluster.monitor.snapshot();
                println!(
                    "step {step:>6}  loss={loss:.4}  auc={:.4}  window_auc={:.4}  logloss={:.4}  served_mean_ctr={mean:.3}  sync_lag={}",
                    snap.auc, snap.window_auc, snap.logloss, cluster.sync_lag()
                );
            }
        } else if step % report == 0 {
            let snap = cluster.monitor.snapshot();
            println!(
                "step {step:>6}  loss={loss:.4}  auc={:.4}  window_auc={:.4}  logloss={:.4}",
                snap.auc, snap.window_auc, snap.logloss
            );
        }
    }
    cluster.flush_sync()?;
    let v = cluster.checkpoint()?;
    let snap = cluster.monitor.snapshot();
    println!(
        "done: {} samples, auc={:.4}, logloss={:.4}, checkpoint v{v}",
        snap.samples, snap.auc, snap.logloss
    );
    Ok(())
}

/// `weips broker`: run the external-queue service.
pub fn run_broker(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7100");
    let partitions = args.get_u64("partitions", 4)? as usize;
    let model = args.get_or("model-name", "ctr");
    let cfg = cluster_config(args)?;
    let queue = Queue::default();
    let topic = queue.create_topic(&format!("sync.{model}"), partitions)?;
    for p in 0..topic.partition_count() {
        let weak = Arc::downgrade(&topic);
        crate::metrics::register_fn(
            "weips_queue_depth_records",
            &[("role", "broker".to_string()), ("partition", p.to_string())],
            Box::new(move || {
                weak.upgrade().map(|t| t.partition(p).map(|part| part.len() as f64).unwrap_or(0.0))
            }),
        );
    }
    let _metrics = serve_role_metrics(args, "broker", &cfg)?;
    let server =
        RpcServer::serve_with(&addr, Arc::new(QueueService { topic }), cfg.rpc_options())?;
    println!("broker on {} ({partitions} partitions)", server.addr());
    block_forever()
}

/// `weips master`: one master shard + its sync pipeline. In incremental
/// checkpoint mode (the default) the shard warm-starts from its local
/// chain + WAL tail, journals every gather window to the WAL and seals
/// base/delta chunks on the jittered checkpoint timer — master-side
/// fault tolerance that needs neither the broker nor a scheduler
/// process. `--warm-start 0` forces a cold boot.
pub fn run_master(args: &Args) -> Result<()> {
    let shard = args.get_u64("shard", 0)? as u32;
    let addr = args.get_or("addr", "127.0.0.1:7200");
    let broker = args.get_or("broker", "127.0.0.1:7100");
    let cfg = cluster_config(args)?;
    let engine = load_engine(args)?;
    let spec = ModelSpec::derive(&cfg.model_name, cfg.model_kind, engine.config());
    let clock = Arc::new(SystemClock);
    let master = Arc::new(MasterShard::with_row_store(
        shard,
        spec,
        Some(engine),
        cfg.entry_threshold,
        cfg.table_stripes as usize,
        cfg.table_row_store,
        clock.clone(),
    )?);
    let data_dir: std::path::PathBuf = args.get_or("data-dir", "/tmp/weips-data").into();
    let mut store = CheckpointStore::new(data_dir.clone(), None);
    store.set_mmap_load(cfg.ckpt_mmap_load);
    let store = Arc::new(store);
    store.register_metrics("master");
    let incremental_mode = cfg.ckpt_mode == CkptMode::Incremental;
    if !incremental_mode {
        // No delta consumer: skip tombstone tracking (expired rows free
        // all their memory).
        master.set_incremental_tracking(false);
    }

    // Shard-private durability state: the chain chunks and the WAL live
    // beside the shared store, so concurrent shard processes sharing a
    // data dir never collide on manifests.
    let own_dir = data_dir.join(format!("master-{shard}"));
    let mut own_store = CheckpointStore::new(own_dir.join("chain"), None);
    own_store.set_mmap_load(cfg.ckpt_mmap_load);
    let own_store = Arc::new(own_store);
    let wal = Arc::new(WalLog::open_with(own_dir.join("wal"), 1, cfg.wal_sync_every)?);
    if incremental_mode && args.get_or("warm-start", "1") != "0" {
        // A crash before the first seal leaves WAL records but no chain:
        // replay from offset 0 in that case instead of booting empty.
        let (chain, from) = match own_store.latest_version(&cfg.model_name) {
            Some(version) => {
                let tip = master.restore_chain(&own_store, version, 0)?;
                (format!("v{version} chain"), tip.wal_offsets.first().copied().unwrap_or(0))
            }
            None => ("no chain".to_string(), 0),
        };
        let replayed = incremental::replay_wal(&master, &wal, 0, from)?;
        println!(
            "warm start: {chain} + {replayed} WAL records -> {} rows",
            master.total_rows()
        );
    }

    let server = RpcServer::serve_with(
        &addr,
        Arc::new(MasterService { shard: master.clone(), store: Some(store) }),
        cfg.rpc_options(),
    )?;
    println!("master shard {shard} on {} (broker {broker})", server.addr());
    master.register_metrics("master");
    let _metrics = serve_role_metrics(args, "master", &cfg)?;

    let mut scheduler = Scheduler::new(
        MetaStore::new(clock.clone()),
        own_store,
        &cfg.model_name,
        CkptPolicy {
            interval_ms: cfg.ckpt_interval_ms,
            jitter: 0.3,
            keep_local: cfg.ckpt_keep,
            remote_every: 0,
        },
        clock.clone(),
    );
    scheduler.set_incr_policy(IncrPolicy {
        base_every: cfg.ckpt_base_every.max(1),
        keep_chains: cfg.ckpt_keep.max(1),
    });
    let mut journal = WalJournal::new(0);
    journal.reset(master.cut_epoch(), master.dense_versions());

    // Sync pump: gather -> pusher against the remote broker; snapshots
    // fan out over the shared sync pool. Every window is journaled to
    // the WAL; the jittered timer seals base/delta chunks.
    let log: Arc<dyn SyncLog> =
        Arc::new(RemoteLog::connect(Channel::remote(&broker, RPC_TIMEOUT))?);
    let mut gather =
        Gather::with_pool(master.clone(), cfg.gather_mode, clock.clone(), cfg.sync_pool());
    let pusher = Pusher::new(log, shard);
    let masters = [master.clone()];
    loop {
        let batches = gather.poll();
        if batches.is_empty() {
            std::thread::sleep(Duration::from_millis(5));
        } else {
            pusher.push_all(&batches)?;
        }
        if !incremental_mode {
            continue;
        }
        journal.poll(&master, &wal, clock.now_ms())?;
        if scheduler.checkpoint_due() {
            let wal_offsets = wal.latest_offsets();
            let (v, kind, cuts) =
                scheduler.checkpoint_incremental(&masters, vec![], wal_offsets.clone(), 0.0)?;
            journal.reset(cuts[0], master.dense_versions());
            wal.trim_until(0, wal_offsets[0])?;
            println!("sealed {} checkpoint v{v}", kind.as_str());
        }
    }
}

fn slave_layout(spec: &ModelSpec) -> Result<(Vec<(String, usize)>, Vec<(String, usize)>, Arc<ServingWeights>)> {
    let tables: Vec<(String, usize)> =
        spec.sparse.iter().map(|t| (t.name.clone(), t.dim)).collect();
    let dense: Vec<(String, usize)> = spec.dense.iter().map(|d| (d.name.clone(), d.len)).collect();
    let transform = Arc::new(ServingWeights::new(
        spec.sparse
            .iter()
            .map(|t| Ok((t.name.clone(), spec.optimizer_for(&t.name)?, t.dim)))
            .collect::<Result<Vec<_>>>()?,
    ));
    Ok((tables, dense, transform))
}

/// `weips slave`: one slave replica + its scatter consumer.
pub fn run_slave(args: &Args) -> Result<()> {
    let shard = args.get_u64("shard", 0)? as u32;
    let replica = args.get_u64("replica", 0)? as u32;
    let addr = args.get_or("addr", "127.0.0.1:7300");
    let broker = args.get_or("broker", "127.0.0.1:7100");
    let cfg = cluster_config(args)?;
    let engine = load_engine(args)?;
    let spec = ModelSpec::derive(&cfg.model_name, cfg.model_kind, engine.config());
    let (tables, dense, transform) = slave_layout(&spec)?;
    let slave = Arc::new(SlaveShard::with_stripes(
        shard,
        replica,
        &cfg.model_name,
        tables,
        dense,
        transform,
        Router::with_slots(cfg.slave_shards, cfg.reshard_slots as usize),
        cfg.table_stripes as usize,
    ));
    // One shared pool for scatter applies and serving-pull prefetch.
    let pool = cfg.sync_pool();
    slave.set_sync_pool(pool.clone());
    slave.register_metrics("slave");
    let server = RpcServer::serve_with(
        &addr,
        Arc::new(SlaveService { shard: slave.clone() }),
        cfg.rpc_options(),
    )?;
    println!(
        "slave {shard}/{replica} on {} (broker {broker}, {} slave shards)",
        server.addr(),
        cfg.slave_shards
    );
    let _metrics = serve_role_metrics(args, "slave", &cfg)?;
    let log: Arc<dyn SyncLog> =
        Arc::new(RemoteLog::connect(Channel::remote(&broker, RPC_TIMEOUT))?);
    let mut scatter = Scatter::with_pool(
        log,
        slave,
        cfg.master_shards,
        cfg.slave_shards,
        Arc::new(SystemClock),
        pool,
    );
    // Bootstrap from the published slot map when `--masters-at` is
    // given: a cluster whose map was ever rebalanced (epoch > 0)
    // invalidates the reduced partition subset — it is only sound for
    // the canonical uniform map — so widen to every partition
    // automatically. `--consume-all 1` forces widening by hand (e.g.
    // when no master is reachable at boot).
    let master_channels: Vec<Channel> = args
        .get("masters-at")
        .map(|s| s.split(',').map(|a| Channel::remote(a.trim(), RPC_TIMEOUT)).collect())
        .unwrap_or_default();
    let rebalanced = match fetch_slot_map(&master_channels) {
        Some(map) if map.epoch > 0 => {
            println!("published slot map at routing epoch {}: consuming all partitions", map.epoch);
            true
        }
        _ => false,
    };
    if rebalanced || args.get_or("consume-all", "0") != "0" {
        scatter.subscribe_all()?;
    }
    println!("consuming partitions {:?}", scatter.partitions());
    loop {
        if scatter.poll(Duration::from_millis(50))? == 0 {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// `weips trainer`: training worker against remote masters.
pub fn run_trainer(args: &Args) -> Result<()> {
    let masters_at = args
        .get("masters-at")
        .ok_or_else(|| Error::Config("trainer needs --masters-at a,b,c".into()))?;
    let steps = args.get_u64("steps", 1000)?;
    let cfg = cluster_config(args)?;
    let engine = load_engine(args)?;
    let spec = ModelSpec::derive(&cfg.model_name, cfg.model_kind, engine.config());
    let channels: Vec<Channel> = masters_at
        .split(',')
        .map(|a| Channel::remote(a.trim(), RPC_TIMEOUT))
        .collect();
    let monitor = Arc::new(crate::monitor::Monitor::new(4096));
    monitor.register_metrics("trainer");
    let _metrics = serve_role_metrics(args, "trainer", &cfg)?;
    // Route over the cluster's configured slot universe, not the default
    // — a universe skew would push to the wrong masters.
    let router = Router::with_slots(channels.len() as u32, cfg.reshard_slots as usize);
    // Bootstrap from the published slot map: a trainer joining after a
    // live migration would otherwise push through the stale uniform map
    // and burn a StaleRoute round-trip per batch until the first NACK.
    if let Some(map) = fetch_slot_map(&channels) {
        if map.epoch > 0 && map.slots() == cfg.reshard_slots as usize {
            println!("bootstrapped slot map at routing epoch {}", map.epoch);
            let _ = router.install(map);
        }
    }
    let mut client = ShardedClient::with_router(&cfg.model_name, channels.clone(), router);
    client.set_route_refresher(route_refresher(channels));
    let trainer = Trainer::new(engine, spec.clone(), client, monitor.clone());
    let mut workload = Workload::new(WorkloadConfig { fields: spec.fields, ..Default::default() });
    for step in 1..=steps {
        let samples = workload.batch(step * 100, spec.batch_train);
        let out = trainer.train_batch(&samples)?;
        if step % 50 == 0 {
            let snap = monitor.snapshot();
            println!("step {step:>6} loss={:.4} auc={:.4}", out.loss, snap.auc);
        }
    }
    Ok(())
}

/// `weips predictor`: serving worker against remote slave groups.
pub fn run_predictor(args: &Args) -> Result<()> {
    let slaves_at = args
        .get("slaves-at")
        .ok_or_else(|| Error::Config("predictor needs --slaves-at 'a,b;c,d' (';' splits shards)".into()))?;
    let requests = args.get_u64("requests", 1000)?;
    let cfg = cluster_config(args)?;
    let engine = load_engine(args)?;
    let spec = ModelSpec::derive(&cfg.model_name, cfg.model_kind, engine.config());
    let groups: Vec<Arc<ReplicaGroup<SlaveEndpoint>>> = slaves_at
        .split(';')
        .map(|group| {
            let endpoints: Vec<Arc<SlaveEndpoint>> = group
                .split(',')
                .map(|a| {
                    // Warm connection pool per slave: concurrent predict
                    // batches fan out without serializing on one socket.
                    Arc::new(SlaveEndpoint::remote(Channel::pooled(
                        a.trim(),
                        RPC_TIMEOUT,
                        cfg.pull_pool_connections as usize,
                    )))
                })
                .collect();
            Arc::new(ReplicaGroup::new(endpoints, cfg.replica_balance))
        })
        .collect();
    let _metrics = serve_role_metrics(args, "predictor", &cfg)?;
    let router = Router::with_slots(groups.len() as u32, cfg.reshard_slots as usize);
    // No hot-id cache here: the standalone predictor does not consume
    // the scatter stream, so there is no invalidation source and a
    // cache would violate the one-tick freshness guarantee. Caching is
    // wired where the scatter runs in-process (LocalCluster).
    let mut client = SlaveClient::with_router(&cfg.model_name, groups, router);
    client.register_metrics("predictor");
    let predictor = Predictor::new(engine, spec.clone(), client);
    let mut workload = Workload::new(WorkloadConfig { fields: spec.fields, ..Default::default() });
    let mut served = 0u64;
    while served < requests {
        let batch: Vec<Vec<u64>> = workload
            .batch(served * 10, spec.batch_predict)
            .into_iter()
            .map(|s| s.ids)
            .collect();
        let preds = predictor.predict(&batch)?;
        served += preds.len() as u64;
    }
    println!(
        "served {served} requests: latency {}",
        predictor.metrics.latency_ns.summary()
    );
    Ok(())
}
