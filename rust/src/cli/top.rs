//! `weips top`: one-screen live ops dashboard over the metrics feed.
//!
//! Polls a role's metrics endpoint — preferring the aggregated
//! `/cluster` view when the endpoint has targets configured, falling
//! back to its own `/metrics` otherwise — and renders the streaming-sync
//! health picture the runbook cares about: push→visible p50/p99, queue
//! depth, scatter lag, WAL fsync lag, per-slot heat as a sparkline, QoS
//! sheds, engaged degradation modes and the update-journey trace-stage
//! breakdown. Everything is computed from parsed exposition samples by
//! [`render`], a pure function the unit tests drive directly.

use std::time::Duration;

use super::Args;
use crate::metrics::http::http_get;
use crate::metrics::{parse_exposition, Sample};
use crate::{Error, Result};

const FETCH_TIMEOUT: Duration = Duration::from_secs(4);
/// Journal entries shown in the events pane.
const EVENT_LINES: usize = 10;
const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
/// Slot-heat buckets shown in the sparkline (matches the exporter's
/// `HEAT_BUCKETS` ceiling).
const HEAT_BUCKETS: usize = 64;

/// `weips top --endpoint host:port [--interval-ms 1000] [--once 1]`.
pub fn run_top(args: &Args) -> Result<()> {
    let endpoint = args
        .get("endpoint")
        .ok_or_else(|| {
            Error::Config("top needs --endpoint host:port (a role's metrics address)".into())
        })?
        .to_string();
    let interval = Duration::from_millis(args.get_u64("interval-ms", 1000)?.max(100));
    let once = args.get_or("once", "0") != "0";
    loop {
        let (source, body) = fetch(&endpoint)?;
        let samples = parse_exposition(&body)
            .map_err(|e| Error::State(format!("bad exposition from {endpoint}: {e}")))?;
        let mut screen = render(&samples);
        screen.push_str(&render_events(&fetch_events(&endpoint), EVENT_LINES));
        if once {
            println!("weips top — {endpoint} ({source})\n{screen}");
            return Ok(());
        }
        // ANSI clear + home: a one-screen live view, not a scrolling log.
        print!("\x1b[2J\x1b[H");
        println!(
            "weips top — {endpoint} ({source}, every {}ms, ctrl-c quits)\n{screen}",
            interval.as_millis()
        );
        std::thread::sleep(interval);
    }
}

/// Fetch the freshest feed: `/cluster` (fleet-merged) when the endpoint
/// aggregates, else its own `/metrics`.
fn fetch(endpoint: &str) -> Result<(&'static str, String)> {
    if let Ok(body) = http_get(endpoint, "/cluster", FETCH_TIMEOUT) {
        return Ok(("/cluster", body));
    }
    let body = http_get(endpoint, "/metrics", FETCH_TIMEOUT)
        .map_err(|e| Error::State(format!("scrape {endpoint} failed: {e}")))?;
    Ok(("/metrics", body))
}

/// Journal feed: fleet-merged `/cluster/events` when the endpoint
/// aggregates, else its own `/events`. Empty body when neither answers
/// (an older endpoint) — the pane just stays out of the screen.
fn fetch_events(endpoint: &str) -> String {
    if let Ok(body) = http_get(endpoint, "/cluster/events", FETCH_TIMEOUT) {
        return body;
    }
    http_get(endpoint, "/events", FETCH_TIMEOUT).unwrap_or_default()
}

/// Sum of every sample of `name` (across shards/replicas/instances).
fn sum_of(samples: &[Sample], name: &str) -> f64 {
    samples.iter().filter(|s| s.name == name).map(|s| s.value).sum()
}

/// Cumulative histogram buckets of `name` merged across instances:
/// sorted `(le_seconds, cumulative_count)` pairs (`+Inf` last).
fn buckets_of(samples: &[Sample], name: &str) -> Vec<(f64, f64)> {
    let bucket_name = format!("{name}_bucket");
    let mut acc: Vec<(f64, f64)> = Vec::new();
    for s in samples.iter().filter(|s| s.name == bucket_name) {
        let le = match s.label("le") {
            Some("+Inf") => f64::INFINITY,
            Some(v) => match v.parse::<f64>() {
                Ok(x) => x,
                Err(_) => continue,
            },
            None => continue,
        };
        match acc.iter_mut().find(|(b, _)| *b == le) {
            Some((_, c)) => *c += s.value,
            None => acc.push((le, s.value)),
        }
    }
    acc.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    acc
}

/// Quantile (0..1) from cumulative buckets, interpolated within the
/// landing bucket. 0 when the histogram is empty.
fn quantile(buckets: &[(f64, f64)], q: f64) -> f64 {
    let total = buckets.last().map(|(_, c)| *c).unwrap_or(0.0);
    if total <= 0.0 {
        return 0.0;
    }
    let target = q * total;
    let mut prev_le = 0.0;
    let mut prev_count = 0.0;
    for &(le, count) in buckets {
        if count >= target {
            if le.is_infinite() {
                return prev_le; // best lower bound for the open bucket
            }
            let in_bucket = count - prev_count;
            let frac = if in_bucket > 0.0 { (target - prev_count) / in_bucket } else { 1.0 };
            return prev_le + (le - prev_le) * frac;
        }
        prev_le = le;
        prev_count = count;
    }
    prev_le
}

/// Unicode sparkline scaled to the slice max (all-blank when flat zero).
fn sparkline(values: &[f64]) -> String {
    let max = values.iter().cloned().fold(0.0_f64, f64::max);
    values
        .iter()
        .map(|v| {
            if max <= 0.0 {
                SPARK[0]
            } else {
                SPARK[((v / max) * 7.0).round().clamp(0.0, 7.0) as usize]
            }
        })
        .collect()
}

/// Human latency: ns under a µs, µs under a ms, ms under a s.
fn fmt_latency(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.2}s")
    } else if seconds >= 1e-3 {
        format!("{:.1}ms", seconds * 1e3)
    } else if seconds >= 1e-6 {
        format!("{:.1}µs", seconds * 1e6)
    } else {
        format!("{:.0}ns", seconds * 1e9)
    }
}

/// Distinct values of `label` on `name` samples, sorted.
fn label_values(samples: &[Sample], name: &str, label: &str) -> Vec<String> {
    let mut vals: Vec<String> = samples
        .iter()
        .filter(|s| s.name == name && s.value != 0.0)
        .filter_map(|s| s.label(label).map(|v| v.to_string()))
        .collect();
    vals.sort();
    vals.dedup();
    vals
}

/// Render the dashboard from parsed exposition samples (pure; the unit
/// tests feed synthetic samples straight in).
pub fn render(samples: &[Sample]) -> String {
    let mut out = String::new();

    // -- streaming sync: the second-level deployment headline ------------
    let visible = buckets_of(samples, "weips_push_visible_latency_seconds");
    let applied = sum_of(samples, "weips_scatter_batches_applied_total");
    out.push_str(&format!(
        "sync    push→visible p50 {}  p99 {}  ({} batches applied)\n",
        fmt_latency(quantile(&visible, 0.5)),
        fmt_latency(quantile(&visible, 0.99)),
        applied as u64,
    ));
    out.push_str(&format!(
        "        queue depth {:>8}   scatter lag {:>8}   WAL unsynced {:>6}   fsync p99 {}\n",
        sum_of(samples, "weips_queue_depth_records") as u64,
        sum_of(samples, "weips_scatter_lag_records") as u64,
        sum_of(samples, "weips_wal_unsynced_appends") as u64,
        fmt_latency(quantile(&buckets_of(samples, "weips_wal_fsync_duration_seconds"), 0.99)),
    ));

    // -- per-slot write heat ---------------------------------------------
    let mut heat = vec![0.0; HEAT_BUCKETS];
    let mut seen_heat = false;
    for s in samples.iter().filter(|s| s.name == "weips_slot_pushes_total") {
        if let Some(b) = s.label("slot_bucket").and_then(|v| v.parse::<usize>().ok()) {
            if b < HEAT_BUCKETS {
                heat[b] += s.value;
                seen_heat = true;
            }
        }
    }
    if seen_heat {
        let top = heat.iter().cloned().fold(0.0_f64, f64::max);
        out.push_str(&format!("heat    {}  (max bucket {})\n", sparkline(&heat), top as u64));
    }

    // -- admission control ------------------------------------------------
    let shed = sum_of(samples, "weips_rpc_class_shed_total");
    let dispatched = sum_of(samples, "weips_rpc_class_dispatches_total");
    if shed > 0.0 || dispatched > 0.0 {
        out.push_str(&format!(
            "qos     dispatched {}   shed {}\n",
            dispatched as u64, shed as u64
        ));
    }

    // -- engaged degradation state ---------------------------------------
    let polls = label_values(samples, "weips_rpc_engaged_poll_mode", "mode");
    let stores = label_values(samples, "weips_table_row_store_info", "store");
    let mmap_series: Vec<&Sample> =
        samples.iter().filter(|s| s.name == "weips_ckpt_mmap_engaged").collect();
    if !polls.is_empty() || !stores.is_empty() || !mmap_series.is_empty() {
        let mmap = if mmap_series.is_empty() {
            "-".to_string()
        } else if mmap_series.iter().all(|s| s.value >= 1.0) {
            "on".to_string()
        } else {
            "off".to_string()
        };
        out.push_str(&format!(
            "engaged rpc poll [{}]   row store [{}]   ckpt mmap {}\n",
            if polls.is_empty() { "-".to_string() } else { polls.join(",") },
            if stores.is_empty() { "-".to_string() } else { stores.join(",") },
            mmap,
        ));
    }

    // -- update-journey stage breakdown ----------------------------------
    let mut stage_lines = Vec::new();
    for stage in crate::trace::STAGES {
        let (mut sum, mut count) = (0.0, 0.0);
        for s in samples.iter().filter(|s| s.label("stage") == Some(stage)) {
            if s.name == "weips_trace_stage_duration_seconds_sum" {
                sum += s.value;
            } else if s.name == "weips_trace_stage_duration_seconds_count" {
                count += s.value;
            }
        }
        if count > 0.0 {
            stage_lines.push(format!("{stage} {}", fmt_latency(sum / count)));
        }
    }
    if !stage_lines.is_empty() {
        out.push_str(&format!("trace   mean/stage: {}\n", stage_lines.join("  ")));
    }

    // -- model quality -----------------------------------------------------
    let auc = samples.iter().find(|s| s.name == "weips_model_auc").map(|s| s.value);
    if let Some(auc) = auc {
        out.push_str(&format!("model   auc {auc:.4}\n"));
    }

    // -- active alerts -----------------------------------------------------
    // `weips_alert_state` gauges: 1 = pending, 2 = firing. Quiet when
    // every rule is Ok; /cluster duplicates per instance dedupe away.
    let mut alert_lines: Vec<String> = samples
        .iter()
        .filter(|s| s.name == "weips_alert_state" && s.value > 0.0)
        .map(|s| {
            let rule = s.label("rule").unwrap_or("?");
            let severity = s.label("severity").unwrap_or("info");
            let color = match severity {
                "critical" => "\x1b[31m",
                "warning" => "\x1b[33m",
                _ => "",
            };
            let state = if s.value >= 2.0 { "FIRING" } else { "pending" };
            format!("{color}{state} {rule} ({severity})\x1b[0m")
        })
        .collect();
    alert_lines.sort();
    alert_lines.dedup();
    if !alert_lines.is_empty() {
        out.push_str(&format!("alerts  {}\n", alert_lines.join("   ")));
    }
    out
}

/// Events pane from a `/events` (or fleet-merged `/cluster/events`) JSON
/// body: the newest `limit` journal entries, one per line. Empty string
/// on an empty or unparsable body, so the pane vanishes rather than
/// printing noise.
pub fn render_events(body: &str, limit: usize) -> String {
    let Ok(doc) = crate::util::json::Json::parse(body) else {
        return String::new();
    };
    let mut events: Vec<(i64, String)> = Vec::new();
    let mut collect = |doc: &crate::util::json::Json| {
        let Some(arr) = doc.get("events").and_then(|e| e.as_arr()) else {
            return;
        };
        for ev in arr {
            let seq = ev.get("seq").and_then(|v| v.as_i64()).unwrap_or(0);
            let kind = ev.get("kind").and_then(|v| v.as_str()).unwrap_or("?");
            let name = ev.get("name").and_then(|v| v.as_str()).unwrap_or("?");
            let detail = ev.get("detail").and_then(|v| v.as_str()).unwrap_or("");
            events.push((seq, format!("  [{kind}] {name}  {detail}\n")));
        }
    };
    match doc.get("instances").and_then(|i| i.as_arr()) {
        Some(instances) => {
            for inst in instances {
                if let Some(data) = inst.get("data") {
                    collect(data);
                }
            }
        }
        None => collect(&doc),
    }
    if events.is_empty() {
        return String::new();
    }
    events.sort_by_key(|(seq, _)| std::cmp::Reverse(*seq));
    events.truncate(limit);
    let mut out = String::from("events\n");
    for (_, line) in events {
        out.push_str(&line);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(name: &str, labels: &[(&str, &str)], value: f64) -> Sample {
        Sample {
            name: name.into(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            value,
        }
    }

    #[test]
    fn quantile_interpolates_and_handles_empty() {
        assert_eq!(quantile(&[], 0.5), 0.0);
        // 100 obs: 50 in (0, 0.01], 50 in (0.01, 0.1].
        let b = vec![(0.01, 50.0), (0.1, 100.0), (f64::INFINITY, 100.0)];
        let p50 = quantile(&b, 0.5);
        assert!((p50 - 0.01).abs() < 1e-9, "p50 {p50}");
        let p75 = quantile(&b, 0.75);
        assert!(p75 > 0.01 && p75 < 0.1, "p75 {p75}");
        // Everything in the +Inf bucket reports the highest finite bound.
        let open = vec![(0.01, 0.0), (f64::INFINITY, 10.0)];
        assert_eq!(quantile(&open, 0.99), 0.01);
    }

    #[test]
    fn sparkline_scales_to_max() {
        let line = sparkline(&[0.0, 1.0, 4.0, 8.0]);
        assert_eq!(line.chars().count(), 4);
        assert!(line.starts_with('▁'));
        assert!(line.ends_with('█'));
        assert_eq!(sparkline(&[0.0, 0.0]), "▁▁");
    }

    #[test]
    fn render_covers_every_dashboard_section() {
        let mut s = vec![
            // push→visible histogram: 2 obs ≤ 10ms, 2 more ≤ 100ms.
            sample(
                "weips_push_visible_latency_seconds_bucket",
                &[("role", "slave"), ("shard", "0"), ("replica", "0"), ("le", "0.01")],
                2.0,
            ),
            sample(
                "weips_push_visible_latency_seconds_bucket",
                &[("role", "slave"), ("shard", "0"), ("replica", "0"), ("le", "0.1")],
                4.0,
            ),
            sample(
                "weips_push_visible_latency_seconds_bucket",
                &[("role", "slave"), ("shard", "0"), ("replica", "0"), ("le", "+Inf")],
                4.0,
            ),
            sample("weips_scatter_batches_applied_total", &[("role", "slave")], 4.0),
            sample("weips_queue_depth_records", &[("partition", "0")], 7.0),
            sample("weips_scatter_lag_records", &[("shard", "0")], 3.0),
            sample("weips_wal_unsynced_appends", &[("role", "master")], 2.0),
            sample("weips_rpc_class_shed_total", &[("class", "bulk")], 5.0),
            sample("weips_rpc_engaged_poll_mode", &[("server", "a"), ("mode", "epoll")], 1.0),
            sample("weips_table_row_store_info", &[("shard", "0"), ("store", "arena")], 1.0),
            sample("weips_ckpt_mmap_engaged", &[("role", "master")], 1.0),
            sample(
                "weips_trace_stage_duration_seconds_sum",
                &[("role", "master"), ("stage", "gather_emit")],
                0.004,
            ),
            sample(
                "weips_trace_stage_duration_seconds_count",
                &[("role", "master"), ("stage", "gather_emit")],
                2.0,
            ),
            sample("weips_model_auc", &[("role", "trainer")], 0.75),
        ];
        for b in 0..4 {
            let bucket = b.to_string();
            s.push(sample(
                "weips_slot_pushes_total",
                &[("role", "master"), ("slot_bucket", bucket.as_str())],
                b as f64,
            ));
        }
        let screen = render(&s);
        assert!(screen.contains("push→visible p50 10.0ms"), "{screen}");
        assert!(screen.contains("queue depth        7"), "{screen}");
        assert!(screen.contains("scatter lag        3"), "{screen}");
        assert!(screen.contains("WAL unsynced      2"), "{screen}");
        assert!(screen.contains("heat    "), "{screen}");
        assert!(screen.contains("shed 5"), "{screen}");
        assert!(screen.contains("rpc poll [epoll]"), "{screen}");
        assert!(screen.contains("row store [arena]"), "{screen}");
        assert!(screen.contains("ckpt mmap on"), "{screen}");
        assert!(screen.contains("gather_emit 2.0ms"), "{screen}");
        assert!(screen.contains("auc 0.7500"), "{screen}");
    }

    #[test]
    fn render_is_quiet_on_an_empty_scrape() {
        let screen = render(&[]);
        // The sync headline always prints; optional sections stay out.
        assert!(screen.contains("push→visible"));
        assert!(!screen.contains("engaged"));
        assert!(!screen.contains("trace"));
        assert!(!screen.contains("alerts"));
    }

    #[test]
    fn alerts_pane_colors_by_severity_and_dedupes_instances() {
        // The same firing rule from two /cluster instances plus a pending
        // warning; Ok rules (value 0) stay off the pane.
        let s = vec![
            sample("weips_alert_state", &[("rule", "window_auc_low"), ("severity", "critical")], 2.0),
            sample("weips_alert_state", &[("rule", "window_auc_low"), ("severity", "critical")], 2.0),
            sample("weips_alert_state", &[("rule", "scatter_lag_high"), ("severity", "warning")], 1.0),
            sample("weips_alert_state", &[("rule", "wal_unsynced_high"), ("severity", "warning")], 0.0),
        ];
        let screen = render(&s);
        assert!(screen.contains("\x1b[31mFIRING window_auc_low (critical)\x1b[0m"), "{screen}");
        assert!(screen.contains("\x1b[33mpending scatter_lag_high (warning)\x1b[0m"), "{screen}");
        assert!(!screen.contains("wal_unsynced_high"), "{screen}");
        assert_eq!(screen.matches("window_auc_low").count(), 1, "{screen}");
    }

    #[test]
    fn events_pane_renders_flat_and_cluster_bodies_newest_first() {
        let flat = r#"{"events":[
            {"seq":2,"ts_ms":5,"kind":"alert_firing","name":"scatter_lag_high","detail":"role=slave"},
            {"seq":1,"ts_ms":4,"kind":"checkpoint","name":"checkpoint_finalized","detail":"v3"}]}"#;
        let pane = render_events(flat, 10);
        assert!(pane.starts_with("events\n"), "{pane}");
        let firing = pane.find("alert_firing").unwrap();
        let ckpt = pane.find("checkpoint_finalized").unwrap();
        assert!(firing < ckpt, "newest first: {pane}");

        let merged = format!(
            r#"{{"instances":[{{"instance":"a","data":{flat}}},{{"instance":"b","data":{{"events":[{{"seq":9,"kind":"degradation","name":"qos_shed_engaged","detail":"class bulk"}}]}}}}]}}"#
        );
        let pane = render_events(&merged, 2);
        assert!(pane.contains("qos_shed_engaged"), "{pane}");
        assert!(pane.contains("alert_firing"), "{pane}");
        assert!(!pane.contains("checkpoint_finalized"), "limit 2 keeps newest: {pane}");

        // Unparsable / empty feeds keep the pane out entirely.
        assert_eq!(render_events("", 10), "");
        assert_eq!(render_events("{\"events\":[]}", 10), "");
    }

    #[test]
    fn fmt_latency_picks_sane_units() {
        assert_eq!(fmt_latency(2.5), "2.50s");
        assert_eq!(fmt_latency(0.0123), "12.3ms");
        assert_eq!(fmt_latency(42e-6), "42.0µs");
        assert_eq!(fmt_latency(5e-9), "5ns");
    }
}
