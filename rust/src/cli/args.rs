//! Minimal `--flag value` / `--flag` argument parser.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `--key value` pairs and bare `--switch`es.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            let Some(key) = arg.strip_prefix("--") else {
                return Err(Error::Config(format!("unexpected positional argument '{arg}'")));
            };
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
                i += 1;
                continue;
            }
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Args { flags })
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Integer flag with default.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} expects an integer, got '{v}'"))),
        }
    }

    /// Float flag with default.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} expects a number, got '{v}'"))),
        }
    }

    /// Boolean switch (present or `=true`).
    pub fn has(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_pairs_switches_and_equals() {
        let a = Args::parse(&argv("--steps 100 --verbose --gather=period:50 --rate 0.5")).unwrap();
        assert_eq!(a.get_u64("steps", 0).unwrap(), 100);
        assert!(a.has("verbose"));
        assert_eq!(a.get("gather"), Some("period:50"));
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_or("missing", "x"), "x");
        assert_eq!(a.get_u64("missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_positionals_and_bad_numbers() {
        assert!(Args::parse(&argv("positional")).is_err());
        let a = Args::parse(&argv("--steps abc")).unwrap();
        assert!(a.get_u64("steps", 0).is_err());
    }

    #[test]
    fn negative_like_values_become_switches() {
        // "--a --b 5": a is a switch.
        let a = Args::parse(&argv("--a --b 5")).unwrap();
        assert!(a.has("a"));
        assert_eq!(a.get_u64("b", 0).unwrap(), 5);
    }
}
