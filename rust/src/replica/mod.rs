//! Hot backup: multi-replica load balancing for slave shards (§4.2.2).
//!
//! "When an instance of the online service node crashes, the other
//! instance takes over the requests that belong to that node." Online
//! learning is *stateful*, so replicas are not interchangeable blanks —
//! each keeps itself consistent via full + streaming sync; the balancer's
//! job is health-aware selection and instant failover.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use crate::{Error, Result};

/// Balancing policy across healthy replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BalancePolicy {
    /// Rotate through healthy replicas.
    RoundRobin,
    /// Pick the replica with the fewest in-flight requests.
    LeastLoaded,
    /// Weight replicas by observed mean service latency × queue depth:
    /// score = `mean_latency_ns × (inflight + 1)`, lowest wins. Replicas
    /// with no samples yet are probed first so a cold slot earns a
    /// latency profile instead of being starved by warmed-up peers.
    LatencyAware,
}

impl BalancePolicy {
    /// Parse the config-string form (`replica_balance` knob).
    pub fn parse(s: &str) -> Result<BalancePolicy> {
        match s {
            "round_robin" => Ok(BalancePolicy::RoundRobin),
            "least_loaded" => Ok(BalancePolicy::LeastLoaded),
            "latency" => Ok(BalancePolicy::LatencyAware),
            other => Err(Error::Config(format!(
                "replica_balance: unknown policy {other:?} (round_robin | least_loaded | latency)"
            ))),
        }
    }
}

/// A replica endpoint: something that can serve and report health.
pub trait Endpoint: Send + Sync {
    /// Cheap health probe (no I/O beyond what the impl wants).
    fn healthy(&self) -> bool;
}

struct Slot<E> {
    endpoint: Arc<E>,
    inflight: AtomicU64,
    /// Consecutive failures observed by `report_result`.
    failures: AtomicU64,
    /// Requests this replica answered successfully.
    served: AtomicU64,
    /// Total service time across served requests, nanoseconds.
    lat_ns: AtomicU64,
}

/// A group of replicas serving the same slave shard.
pub struct ReplicaGroup<E: Endpoint> {
    slots: RwLock<Vec<Arc<Slot<E>>>>,
    policy: BalancePolicy,
    rr: AtomicUsize,
    /// Trip a replica after this many consecutive errors (auto-eject).
    max_failures: u64,
    pub failovers: AtomicU64,
}

/// Guard for one checked-out request; returns the in-flight token on drop.
pub struct Lease<E: Endpoint> {
    slot: Arc<Slot<E>>,
}

impl<E: Endpoint> Lease<E> {
    /// The replica to call.
    pub fn endpoint(&self) -> &Arc<E> {
        &self.slot.endpoint
    }

    /// Report the call outcome (drives the failure-trip accounting).
    pub fn report(&self, ok: bool) {
        if ok {
            self.slot.failures.store(0, Ordering::Relaxed);
        } else {
            self.slot.failures.fetch_add(1, Ordering::Relaxed);
        }
    }
}

impl<E: Endpoint> Drop for Lease<E> {
    fn drop(&mut self) {
        self.slot.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

impl<E: Endpoint> ReplicaGroup<E> {
    /// New group over `endpoints`.
    pub fn new(endpoints: Vec<Arc<E>>, policy: BalancePolicy) -> ReplicaGroup<E> {
        ReplicaGroup {
            slots: RwLock::new(
                endpoints
                    .into_iter()
                    .map(|endpoint| {
                        Arc::new(Slot {
                            endpoint,
                            inflight: AtomicU64::new(0),
                            failures: AtomicU64::new(0),
                            served: AtomicU64::new(0),
                            lat_ns: AtomicU64::new(0),
                        })
                    })
                    .collect(),
            ),
            policy,
            rr: AtomicUsize::new(0),
            max_failures: 3,
            failovers: AtomicU64::new(0),
        }
    }

    /// Add a replica at runtime (scale-out / recovery).
    pub fn add(&self, endpoint: Arc<E>) {
        self.slots.write().unwrap().push(Arc::new(Slot {
            endpoint,
            inflight: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            served: AtomicU64::new(0),
            lat_ns: AtomicU64::new(0),
        }));
    }

    /// Replica count (healthy + unhealthy).
    pub fn len(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    /// True when the group has no replicas at all.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Healthy replica count.
    pub fn healthy_count(&self) -> usize {
        self.slots
            .read()
            .unwrap()
            .iter()
            .filter(|s| self.usable(s))
            .count()
    }

    fn usable(&self, slot: &Slot<E>) -> bool {
        slot.endpoint.healthy() && slot.failures.load(Ordering::Relaxed) < self.max_failures
    }

    /// Pick a replica per policy; errors when none is usable (the caller
    /// surfaces this as service unavailability — E4 measures the window).
    pub fn pick(&self) -> Result<Lease<E>> {
        let slots = self.slots.read().unwrap();
        if slots.is_empty() {
            return Err(Error::Unavailable("replica group empty".into()));
        }
        let chosen = match self.policy {
            BalancePolicy::RoundRobin => {
                let n = slots.len();
                let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
                (0..n)
                    .map(|i| &slots[(start + i) % n])
                    .find(|s| self.usable(s))
            }
            BalancePolicy::LeastLoaded => slots
                .iter()
                .filter(|s| self.usable(s))
                .min_by_key(|s| s.inflight.load(Ordering::Relaxed)),
            BalancePolicy::LatencyAware => {
                // Unserved slots first (cold-start probing), then lowest
                // expected wait: mean latency scaled by queue depth.
                let usable: Vec<&Arc<Slot<E>>> =
                    slots.iter().filter(|s| self.usable(s)).collect();
                usable
                    .iter()
                    .find(|s| s.served.load(Ordering::Relaxed) == 0)
                    .or_else(|| {
                        usable.iter().min_by_key(|s| {
                            let n = s.served.load(Ordering::Relaxed).max(1);
                            let mean = s.lat_ns.load(Ordering::Relaxed) / n;
                            mean.saturating_mul(s.inflight.load(Ordering::Relaxed) + 1)
                        })
                    })
                    .copied()
            }
        };
        match chosen {
            Some(slot) => {
                slot.inflight.fetch_add(1, Ordering::Relaxed);
                Ok(Lease { slot: slot.clone() })
            }
            None => Err(Error::Unavailable("no healthy replica".into())),
        }
    }

    /// Pick with failover: try up to `attempts` distinct replicas through
    /// `f`, counting failovers. This is the client-side hot-backup path.
    /// Each successful call is timed and charged to the replica that served
    /// it, so the balancer's spread is observable (`served_counts`).
    pub fn call_with_failover<T>(
        &self,
        attempts: usize,
        mut f: impl FnMut(&Arc<E>) -> Result<T>,
    ) -> Result<T> {
        let mut last_err = None;
        for attempt in 0..attempts.max(1) {
            let lease = match self.pick() {
                Ok(l) => l,
                Err(e) => {
                    last_err = Some(e);
                    break;
                }
            };
            let start = std::time::Instant::now();
            match f(lease.endpoint()) {
                Ok(v) => {
                    let elapsed = start.elapsed().as_nanos() as u64;
                    lease.slot.served.fetch_add(1, Ordering::Relaxed);
                    lease.slot.lat_ns.fetch_add(elapsed, Ordering::Relaxed);
                    lease.report(true);
                    return Ok(v);
                }
                Err(e) => {
                    lease.report(false);
                    if attempt + 1 < attempts {
                        self.failovers.fetch_add(1, Ordering::Relaxed);
                    }
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or_else(|| Error::Unavailable("no replicas".into())))
    }

    /// Successful requests served per replica, in slot order. An even
    /// spread under RoundRobin (or load-proportional under LeastLoaded)
    /// is the fan-out working; a single hot slot means failover is
    /// carrying the group.
    pub fn served_counts(&self) -> Vec<u64> {
        self.slots
            .read()
            .unwrap()
            .iter()
            .map(|s| s.served.load(Ordering::Relaxed))
            .collect()
    }

    /// Mean service latency per replica in nanoseconds (0 when unserved),
    /// in slot order. Feeds operator dashboards and the serving bench.
    pub fn mean_latency_ns(&self) -> Vec<u64> {
        self.slots
            .read()
            .unwrap()
            .iter()
            .map(|s| {
                let n = s.served.load(Ordering::Relaxed);
                if n == 0 {
                    0
                } else {
                    s.lat_ns.load(Ordering::Relaxed) / n
                }
            })
            .collect()
    }

    /// Clear failure counters (after recovery).
    pub fn reset_failures(&self) {
        for s in self.slots.read().unwrap().iter() {
            s.failures.store(0, Ordering::Relaxed);
        }
    }

    /// Visit each endpoint (e.g. to broadcast a version switch).
    pub fn for_each(&self, mut f: impl FnMut(&Arc<E>)) {
        for s in self.slots.read().unwrap().iter() {
            f(&s.endpoint);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    struct FakeReplica {
        id: usize,
        up: AtomicBool,
    }

    impl FakeReplica {
        fn new(id: usize) -> Arc<FakeReplica> {
            Arc::new(FakeReplica { id, up: AtomicBool::new(true) })
        }
    }

    impl Endpoint for FakeReplica {
        fn healthy(&self) -> bool {
            self.up.load(Ordering::Relaxed)
        }
    }

    fn group(n: usize, policy: BalancePolicy) -> (ReplicaGroup<FakeReplica>, Vec<Arc<FakeReplica>>) {
        let eps: Vec<Arc<FakeReplica>> = (0..n).map(FakeReplica::new).collect();
        (ReplicaGroup::new(eps.clone(), policy), eps)
    }

    #[test]
    fn round_robin_rotates() {
        let (g, _) = group(3, BalancePolicy::RoundRobin);
        let ids: Vec<usize> = (0..6).map(|_| g.pick().unwrap().endpoint().id).collect();
        assert_eq!(ids, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn skips_unhealthy() {
        let (g, eps) = group(3, BalancePolicy::RoundRobin);
        eps[1].up.store(false, Ordering::Relaxed);
        let ids: Vec<usize> = (0..4).map(|_| g.pick().unwrap().endpoint().id).collect();
        assert!(!ids.contains(&1));
        assert_eq!(g.healthy_count(), 2);
    }

    #[test]
    fn all_down_is_unavailable() {
        let (g, eps) = group(2, BalancePolicy::RoundRobin);
        for e in &eps {
            e.up.store(false, Ordering::Relaxed);
        }
        assert!(matches!(g.pick(), Err(Error::Unavailable(_))));
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let (g, _) = group(2, BalancePolicy::LeastLoaded);
        let hold = g.pick().unwrap(); // replica with inflight=1
        let first = hold.endpoint().id;
        // Next picks should go to the other replica while we hold the lease.
        for _ in 0..3 {
            let l = g.pick().unwrap();
            assert_ne!(l.endpoint().id, first);
        }
        drop(hold);
    }

    #[test]
    fn lease_drop_releases_inflight() {
        let (g, _) = group(1, BalancePolicy::LeastLoaded);
        {
            let _l = g.pick().unwrap();
            let slots = g.slots.read().unwrap();
            assert_eq!(slots[0].inflight.load(Ordering::Relaxed), 1);
        }
        let slots = g.slots.read().unwrap();
        assert_eq!(slots[0].inflight.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn consecutive_failures_eject_until_reset() {
        let (g, _) = group(2, BalancePolicy::RoundRobin);
        // Fail replica 0 three times via report.
        for _ in 0..3 {
            loop {
                let l = g.pick().unwrap();
                let id = l.endpoint().id;
                if id == 0 {
                    l.report(false);
                    break;
                }
            }
        }
        assert_eq!(g.healthy_count(), 1);
        for _ in 0..4 {
            assert_eq!(g.pick().unwrap().endpoint().id, 1);
        }
        g.reset_failures();
        assert_eq!(g.healthy_count(), 2);
    }

    #[test]
    fn failover_retries_distinct_replicas() {
        let (g, _) = group(3, BalancePolicy::RoundRobin);
        let mut failed_once = false;
        let out = g
            .call_with_failover(3, |e| {
                if e.id == 0 && !failed_once {
                    failed_once = true;
                    Err(Error::Rpc("boom".into()))
                } else {
                    Ok(e.id)
                }
            })
            .unwrap();
        assert_ne!(out, 0);
        assert_eq!(g.failovers.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn failover_exhausts_to_error() {
        let (g, _) = group(2, BalancePolicy::RoundRobin);
        let err = g
            .call_with_failover::<()>(2, |_| Err(Error::Rpc("down".into())))
            .unwrap_err();
        assert!(err.to_string().contains("down"));
    }

    #[test]
    fn served_counts_track_successful_calls() {
        let (g, _) = group(2, BalancePolicy::RoundRobin);
        for _ in 0..6 {
            g.call_with_failover(1, |e| Ok(e.id)).unwrap();
        }
        assert_eq!(g.served_counts(), vec![3, 3]);
        // Failures are not charged as served work.
        let _ = g.call_with_failover::<()>(1, |_| Err(Error::Rpc("down".into())));
        assert_eq!(g.served_counts().iter().sum::<u64>(), 6);
        assert_eq!(g.mean_latency_ns().len(), 2);
    }

    #[test]
    fn balance_policy_parses_config_strings() {
        assert_eq!(BalancePolicy::parse("round_robin").unwrap(), BalancePolicy::RoundRobin);
        assert_eq!(BalancePolicy::parse("least_loaded").unwrap(), BalancePolicy::LeastLoaded);
        assert_eq!(BalancePolicy::parse("latency").unwrap(), BalancePolicy::LatencyAware);
        assert!(BalancePolicy::parse("fastest").is_err());
    }

    #[test]
    fn latency_aware_probes_cold_slots_then_prefers_fast_ones() {
        let (g, _) = group(3, BalancePolicy::LatencyAware);
        // Seed latency profiles by hand: replica 0 slow, 1 fast, 2 cold.
        {
            let slots = g.slots.read().unwrap();
            slots[0].served.store(10, Ordering::Relaxed);
            slots[0].lat_ns.store(10 * 9_000_000, Ordering::Relaxed); // 9 ms mean
            slots[1].served.store(10, Ordering::Relaxed);
            slots[1].lat_ns.store(10 * 1_000_000, Ordering::Relaxed); // 1 ms mean
        }
        // The unserved replica is probed first.
        let probe = g.pick().unwrap();
        assert_eq!(probe.endpoint().id, 2);
        probe.slot.served.store(10, Ordering::Relaxed);
        probe.slot.lat_ns.store(10 * 5_000_000, Ordering::Relaxed); // 5 ms mean
        drop(probe);
        // With all profiles warm, the fastest replica wins.
        for _ in 0..3 {
            assert_eq!(g.pick().unwrap().endpoint().id, 1);
        }
        // Queue depth scales the score: holding leases on the fast
        // replica pushes traffic to the next-cheapest expected wait
        // (1 ms × 6 > 5 ms × 1).
        let holds: Vec<_> = (0..5).map(|_| g.pick().unwrap()).collect();
        assert!(holds.iter().all(|l| l.endpoint().id == 1));
        assert_eq!(g.pick().unwrap().endpoint().id, 2);
        drop(holds);
    }

    #[test]
    fn latency_aware_skips_unhealthy_and_tripped() {
        let (g, eps) = group(2, BalancePolicy::LatencyAware);
        {
            let slots = g.slots.read().unwrap();
            for s in slots.iter() {
                s.served.store(5, Ordering::Relaxed);
                s.lat_ns.store(5_000_000, Ordering::Relaxed);
            }
            // Replica 0 is much faster — it would win on latency alone.
            slots[0].lat_ns.store(500, Ordering::Relaxed);
        }
        eps[0].up.store(false, Ordering::Relaxed);
        for _ in 0..3 {
            assert_eq!(g.pick().unwrap().endpoint().id, 1);
        }
        eps[0].up.store(true, Ordering::Relaxed);
        assert_eq!(g.pick().unwrap().endpoint().id, 0);
    }

    #[test]
    fn add_replica_at_runtime() {
        let (g, _) = group(1, BalancePolicy::RoundRobin);
        assert_eq!(g.len(), 1);
        g.add(FakeReplica::new(9));
        assert_eq!(g.len(), 2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            seen.insert(g.pick().unwrap().endpoint().id);
        }
        assert!(seen.contains(&9));
    }
}
