//! Update-journey tracing for the streaming sync path.
//!
//! The push→visible histogram (PR 6) says *how long* deployment takes in
//! aggregate; this module says *where one batch's time went*. A sampled
//! sync batch gets a **derived trace context**: its trace id is computed
//! with [`trace_id`] from fields every stage already carries in the
//! [`crate::proto::SyncBatch`] envelope (model, table, shard, seq), so
//! the context "rides" the existing envelopes **without adding a single
//! wire byte** — sync-batch bytes are identical with tracing off, on or
//! sampled, by construction (asserted by `tests/it_tracing.rs`). Each
//! pipeline stage re-derives the id independently, times itself, and
//! records a nanosecond [`Span`] into a process-global lock-striped ring
//! buffer.
//!
//! The module follows the `metrics` registry discipline: stage names are
//! declared up front in [`STAGES`] and recording an undeclared stage
//! panics. Sampled spans additionally feed the
//! `weips_trace_stage_duration_seconds{role,stage}` histogram, so the
//! per-stage breakdown is scrapeable fleet-wide (and rendered by
//! `weips top`), and the scatter links each sampled batch to the
//! push→visible histogram as an OpenMetrics exemplar.
//!
//! Sampling is controlled by the `trace_sample_every` cluster knob
//! ([`configure`]): `0` (default) disables tracing — the hot-path cost
//! is then exactly one relaxed atomic load and branch per stage — and
//! `N` samples every batch whose envelope `seq % N == 0`. Because the
//! decision is a pure function of the envelope, every stage agrees on
//! which batches are sampled without coordination.
//!
//! Recent traces are served as JSON by the metrics endpoint:
//! `GET /trace` (most recent chains) and `GET /trace/<hex id>`.

use std::hash::Hasher;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::hash::FxHasher;
use crate::util::json::Json;

/// Every pipeline stage this build can record, in update-journey order.
/// Recording an undeclared stage panics (same discipline as
/// [`crate::metrics::DESCRIPTORS`]); `docs/METRICS.md` documents the
/// journey in exactly these terms.
pub static STAGES: &[&str] = &[
    // Master applies trainer gradients (accumulated across the window
    // that produced the sampled batch).
    "push_apply",
    // Gather drains the collector's per-stripe dirty queues.
    "collector_drain",
    // Gather dedups the window and snapshots row values into a batch.
    "gather_emit",
    // The tick's dirty window is journaled to the write-ahead log.
    "wal_append",
    // Pusher encodes + compresses the batch and appends it to the queue.
    "queue_append",
    // Scatter fetches the record and decompresses + decodes it.
    "scatter_decode",
    // Scatter applies the batch to the replica's serving tables.
    "scatter_apply",
    // `ScatterTap`s invalidate the hot-id cache for the applied rows.
    "cache_invalidate",
];

/// Index of a declared stage; panics on an undeclared name.
pub fn stage_index(stage: &str) -> usize {
    STAGES
        .iter()
        .position(|s| *s == stage)
        .unwrap_or_else(|| panic!("trace: stage {stage} is not declared in STAGES"))
}

/// One recorded stage timing for one sampled sync batch.
#[derive(Debug, Clone)]
pub struct Span {
    /// Derived trace id ([`trace_id`]) shared by every stage of the chain.
    pub trace_id: u64,
    /// Declared stage name (see [`STAGES`]).
    pub stage: &'static str,
    /// Role that recorded the span (`master` / `slave` / `broker`).
    pub role: &'static str,
    /// Free-form locator within the role, e.g. `shard=0 replica=1`.
    pub detail: String,
    /// Monotonic start ([`crate::util::mono_ns`]).
    pub start_ns: u64,
    /// Stage duration in nanoseconds.
    pub dur_ns: u64,
    /// The batch's `created_ms` origin timestamp (wall clock).
    pub origin_ms: u64,
    /// The batch's envelope sequence number.
    pub seq: u64,
    /// The originating master shard.
    pub shard: u32,
}

const STRIPES: usize = 16;
/// Spans retained per stripe; a chain is ~8 spans, so the sink holds a
/// few hundred recent traces before the ring overwrites.
const PER_STRIPE: usize = 512;

struct Stripe {
    ring: Vec<Span>,
    next: usize,
}

/// Process-global trace sink: a sampling switch plus a lock-striped ring
/// buffer of recent spans. All spans of one trace land in one stripe
/// (striped by trace id), so eviction drops whole chains, not arbitrary
/// middles.
pub struct TraceSink {
    sample_every: AtomicU64,
    stripes: Vec<Mutex<Stripe>>,
}

impl TraceSink {
    fn new() -> TraceSink {
        TraceSink {
            sample_every: AtomicU64::new(0),
            stripes: (0..STRIPES)
                .map(|_| Mutex::new(Stripe { ring: Vec::new(), next: 0 }))
                .collect(),
        }
    }
}

/// The process-global sink used by every free function below.
pub fn default() -> &'static TraceSink {
    static SINK: OnceLock<TraceSink> = OnceLock::new();
    SINK.get_or_init(TraceSink::new)
}

/// Set the sampling cadence: `0` disables tracing, `n` samples every
/// batch whose envelope seq is a multiple of `n`. Mirrors the
/// `trace_sample_every` cluster knob.
pub fn configure(sample_every: u64) {
    default().sample_every.store(sample_every, Ordering::Relaxed);
}

/// Current sampling cadence (`0` = off).
pub fn sample_every() -> u64 {
    default().sample_every.load(Ordering::Relaxed)
}

/// Whether tracing is on at all. This is the *entire* hot-path cost with
/// tracing disabled: one relaxed load + branch.
#[inline]
pub fn enabled() -> bool {
    sample_every() != 0
}

/// Whether the batch with envelope sequence `seq` is sampled. Pure
/// function of the envelope + the configured cadence, so every stage
/// agrees without any wire bytes.
#[inline]
pub fn sampled(seq: u64) -> bool {
    let n = sample_every();
    n != 0 && seq % n == 0
}

/// Derive the trace id from envelope fields every stage already has.
/// Deterministic: master, broker and every replica compute the same id
/// for the same batch independently.
pub fn trace_id(model: &str, table: &str, shard: u32, seq: u64) -> u64 {
    let mut h = FxHasher::default();
    h.write(model.as_bytes());
    h.write(table.as_bytes());
    h.write_u32(shard);
    h.write_u64(seq);
    h.finish()
}

/// Record one stage span into the ring buffer and the
/// `weips_trace_stage_duration_seconds` histogram. Panics if
/// `span.stage` is not declared in [`STAGES`].
pub fn record(span: Span) {
    stage_index(span.stage); // declared-stage discipline
    crate::metrics::histogram(
        "weips_trace_stage_duration_seconds",
        &[("role", span.role.to_string()), ("stage", span.stage.to_string())],
    )
    .record(span.dur_ns);
    let sink = default();
    let mut s = sink.stripes[(span.trace_id % STRIPES as u64) as usize].lock().unwrap();
    if s.ring.len() < PER_STRIPE {
        s.ring.push(span);
    } else {
        let i = s.next;
        s.ring[i] = span;
        s.next = (i + 1) % PER_STRIPE;
    }
}

/// Convenience: build + [`record`] a span in one call.
#[allow(clippy::too_many_arguments)]
pub fn record_stage(
    trace_id: u64,
    stage: &'static str,
    role: &'static str,
    detail: String,
    start_ns: u64,
    dur_ns: u64,
    origin_ms: u64,
    seq: u64,
    shard: u32,
) {
    record(Span { trace_id, stage, role, detail, start_ns, dur_ns, origin_ms, seq, shard });
}

/// All recorded spans for one trace id, in journey order.
pub fn spans_for(id: u64) -> Vec<Span> {
    let sink = default();
    let s = sink.stripes[(id % STRIPES as u64) as usize].lock().unwrap();
    let mut spans: Vec<Span> = s.ring.iter().filter(|sp| sp.trace_id == id).cloned().collect();
    spans.sort_by_key(|sp| (stage_index(sp.stage), sp.start_ns));
    spans
}

/// The most recent `limit` trace chains (newest first, by the latest
/// span start in each chain).
pub fn recent(limit: usize) -> Vec<(u64, Vec<Span>)> {
    let sink = default();
    let mut by_id: std::collections::BTreeMap<u64, Vec<Span>> = std::collections::BTreeMap::new();
    for stripe in &sink.stripes {
        let s = stripe.lock().unwrap();
        for sp in &s.ring {
            by_id.entry(sp.trace_id).or_default().push(sp.clone());
        }
    }
    let mut chains: Vec<(u64, Vec<Span>)> = by_id.into_iter().collect();
    for (_, spans) in chains.iter_mut() {
        spans.sort_by_key(|sp| (stage_index(sp.stage), sp.start_ns));
    }
    chains.sort_by_key(|(_, spans)| {
        std::cmp::Reverse(spans.iter().map(|sp| sp.start_ns).max().unwrap_or(0))
    });
    chains.truncate(limit);
    chains
}

/// Drop every recorded span (tests and benches; sampling cadence is
/// untouched).
pub fn clear() {
    let sink = default();
    for stripe in &sink.stripes {
        let mut s = stripe.lock().unwrap();
        s.ring.clear();
        s.next = 0;
    }
}

/// Canonical text form of a trace id (16 hex digits, as served in URLs
/// and exemplar labels).
pub fn format_id(id: u64) -> String {
    format!("{id:016x}")
}

/// Parse [`format_id`] output (also accepts shorter hex).
pub fn parse_id(s: &str) -> Option<u64> {
    u64::from_str_radix(s.trim(), 16).ok()
}

fn chain_json(id: u64, spans: &[Span]) -> Json {
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("trace_id".to_string(), Json::Str(format_id(id)));
    if let Some(first) = spans.first() {
        obj.insert("origin_ms".to_string(), Json::Num(first.origin_ms as f64));
        obj.insert("seq".to_string(), Json::Num(first.seq as f64));
        obj.insert("shard".to_string(), Json::Num(first.shard as f64));
    }
    obj.insert(
        "total_ns".to_string(),
        Json::Num(spans.iter().map(|s| s.dur_ns).sum::<u64>() as f64),
    );
    obj.insert(
        "spans".to_string(),
        Json::Arr(
            spans
                .iter()
                .map(|s| {
                    let mut sp = std::collections::BTreeMap::new();
                    sp.insert("stage".to_string(), Json::Str(s.stage.to_string()));
                    sp.insert("role".to_string(), Json::Str(s.role.to_string()));
                    sp.insert("detail".to_string(), Json::Str(s.detail.clone()));
                    sp.insert("start_ns".to_string(), Json::Num(s.start_ns as f64));
                    sp.insert("dur_ns".to_string(), Json::Num(s.dur_ns as f64));
                    Json::Obj(sp)
                })
                .collect(),
        ),
    );
    Json::Obj(obj)
}

/// JSON body of `GET /trace`: the most recent chains, newest first.
pub fn render_recent_json(limit: usize) -> String {
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("sample_every".to_string(), Json::Num(sample_every() as f64));
    obj.insert("stages".to_string(), {
        Json::Arr(STAGES.iter().map(|s| Json::Str(s.to_string())).collect())
    });
    obj.insert(
        "traces".to_string(),
        Json::Arr(recent(limit).iter().map(|(id, spans)| chain_json(*id, spans)).collect()),
    );
    Json::Obj(obj).to_string()
}

/// JSON body of `GET /trace/<id>`, or `None` when the id has aged out
/// of the ring (or never existed).
pub fn render_trace_json(id: u64) -> Option<String> {
    let spans = spans_for(id);
    if spans.is_empty() {
        return None;
    }
    Some(chain_json(id, &spans).to_string())
}

/// Serializes lib-internal tests that mutate the process-global sink or
/// sampling cadence (the trace module's own tests plus the HTTP route
/// tests share one process).
#[cfg(test)]
pub fn test_lock() -> &'static Mutex<()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64, stage: &'static str, start_ns: u64, dur_ns: u64) -> Span {
        Span {
            trace_id: id,
            stage,
            role: "master",
            detail: "shard=0".into(),
            start_ns,
            dur_ns,
            origin_ms: 1000,
            seq: 8,
            shard: 0,
        }
    }

    #[test]
    fn derived_ids_are_deterministic_and_distinct() {
        let a = trace_id("ctr", "emb", 0, 8);
        assert_eq!(a, trace_id("ctr", "emb", 0, 8));
        assert_ne!(a, trace_id("ctr", "emb", 0, 9));
        assert_ne!(a, trace_id("ctr", "emb", 1, 8));
        assert_ne!(a, trace_id("ctr", "wide", 0, 8));
        assert_eq!(parse_id(&format_id(a)), Some(a));
    }

    #[test]
    fn sampling_is_a_pure_function_of_seq() {
        let _g = test_lock().lock().unwrap();
        configure(0);
        assert!(!enabled());
        assert!(!sampled(0));
        configure(4);
        assert!(sampled(0) && sampled(8) && !sampled(3));
        configure(0);
    }

    #[test]
    #[should_panic(expected = "not declared in STAGES")]
    fn undeclared_stage_panics() {
        record(span(1, "made_up_stage", 0, 1));
    }

    #[test]
    fn chains_round_trip_through_json() {
        let _g = test_lock().lock().unwrap();
        clear();
        let id = trace_id("ctr-json", "emb", 0, 8);
        record(span(id, "gather_emit", 100, 40));
        record(span(id, "push_apply", 10, 50));
        let spans = spans_for(id);
        assert_eq!(spans.len(), 2);
        // Journey order, not insertion order.
        assert_eq!(spans[0].stage, "push_apply");
        let body = render_trace_json(id).expect("chain present");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("trace_id").unwrap().as_str(), Some(format_id(id).as_str()));
        assert_eq!(j.get("total_ns").unwrap().as_f64(), Some(90.0));
        assert_eq!(j.get("spans").unwrap().as_arr().unwrap().len(), 2);
        assert!(render_trace_json(id ^ 1).is_none(), "unknown id must 404");
        let listing = Json::parse(&render_recent_json(8)).unwrap();
        assert!(!listing.get("traces").unwrap().as_arr().unwrap().is_empty());
        clear();
    }

    #[test]
    fn ring_overwrites_oldest_without_growing() {
        let _g = test_lock().lock().unwrap();
        clear();
        // Saturate one stripe: ids congruent mod STRIPES land together.
        for i in 0..(2 * super::PER_STRIPE as u64) {
            record(span(i * super::STRIPES as u64, "queue_append", i, 1));
        }
        let total: usize =
            default().stripes.iter().map(|s| s.lock().unwrap().ring.len()).sum();
        assert!(total <= super::PER_STRIPE * super::STRIPES);
        clear();
    }
}
