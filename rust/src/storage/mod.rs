//! Hierarchical checkpoint storage (§4.2.1: cold backup of fault tolerance).
//!
//! Two tiers, as the paper prescribes: a fast **local** tier (sub-hourly
//! save intervals) and a slower **remote** tier (hourly/daily), here two
//! directory roots — the remote root stands in for HDFS/object storage and
//! is replicated to asynchronously.
//!
//! Layout:  `<root>/<model>/v<version>/shard_<i>.ckpt` + `manifest.json`
//! (delta versions store `shard_<i>.delta` instead — see
//! [`incremental`]). Shard files are CRC-framed (`codec::frame`) so torn
//! writes are detected; writes go through a temp file + atomic rename.
//! The manifest records the external-queue offsets at checkpoint time —
//! the hook the domino downgrade uses to resume streaming after a
//! rollback (§4.3.2) — and, for incremental chains, the parent version,
//! per-shard epoch cuts and WAL offsets the recovery path replays from.

pub mod incremental;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::codec::{frame, unframe};
use crate::util::json::Json;
use crate::util::sys;
use crate::{Error, Result};

/// One shard chunk's verified payload, borrowing either a heap buffer or
/// an mmap'd file region. Decoders walk it through `Deref<Target = [u8]>`
/// — with a mapped backing, recovery decodes straight out of the page
/// cache with no intermediate copy of the chunk.
pub struct ChunkData {
    backing: Backing,
    start: usize,
    end: usize,
}

enum Backing {
    Owned(Vec<u8>),
    Mapped(sys::Mmap),
}

impl ChunkData {
    /// The CRC-verified payload bytes.
    pub fn as_slice(&self) -> &[u8] {
        match &self.backing {
            Backing::Owned(v) => &v[self.start..self.end],
            Backing::Mapped(m) => &m[self.start..self.end],
        }
    }

    /// True when the payload is served from a mapped file region rather
    /// than a heap copy (observability for tests and benches).
    pub fn is_mapped(&self) -> bool {
        matches!(self.backing, Backing::Mapped(_))
    }

    /// Detach into an owned buffer (copies only if mapped or framed).
    pub fn into_vec(self) -> Vec<u8> {
        match self.backing {
            Backing::Owned(v) if self.start == 0 && self.end == v.len() => v,
            _ => self.as_slice().to_vec(),
        }
    }
}

impl std::ops::Deref for ChunkData {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

/// What a checkpoint version's shard chunks contain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CkptKind {
    /// Full shard snapshots — a recovery chain starts here.
    Base,
    /// Dirty-epoch delta chunks against the manifest's `parent` version.
    Delta,
}

impl CkptKind {
    /// Manifest string form.
    pub fn as_str(&self) -> &'static str {
        match self {
            CkptKind::Base => "base",
            CkptKind::Delta => "delta",
        }
    }

    /// Parse the manifest string form.
    pub fn parse(s: &str) -> Result<CkptKind> {
        match s {
            "base" => Ok(CkptKind::Base),
            "delta" => Ok(CkptKind::Delta),
            other => Err(Error::Checkpoint(format!("unknown checkpoint kind {other}"))),
        }
    }
}

/// Per-checkpoint metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptManifest {
    pub model: String,
    pub version: u64,
    pub created_ms: u64,
    pub num_shards: u32,
    /// Queue offset per sync partition at checkpoint time.
    pub queue_offsets: Vec<u64>,
    /// Business metric snapshot (streaming AUC) — the downgrade's "optimal
    /// index version strategy" picks by this.
    pub metric: f64,
    /// Base (full shard snapshots) or delta (dirty-epoch chunks).
    pub kind: CkptKind,
    /// Previous version in the chain (0 = none; only deltas have one).
    pub parent: u64,
    /// Per-shard dirty-epoch cut at seal time, in shard save order. A
    /// delta at child version collects rows stamped `> epochs[i]` of its
    /// parent; recovery re-arms shard `i`'s write epoch to
    /// `epochs[i] + 1`.
    pub epochs: Vec<u64>,
    /// Write-ahead-log offset per WAL partition at seal time — recovery
    /// replays the WAL tail from here (empty when no WAL is attached).
    pub wal_offsets: Vec<u64>,
    /// Routing epoch at seal time (0 = still on the implicit uniform
    /// map). Lets a cold-started cluster know which slot map its shard
    /// chunks were cut under without a live scheduler.
    pub route_epoch: u64,
    /// Encoded [`crate::reshard::SlotMap`] at seal time (empty when
    /// `route_epoch` is 0) — recovery installs it before replay so
    /// foreign-row purges see the right ownership.
    pub slot_map: Vec<u8>,
}

impl CkptManifest {
    fn to_json(&self) -> Json {
        let nums = |v: &[u64]| Json::Arr(v.iter().map(|o| Json::Num(*o as f64)).collect());
        let mut m = std::collections::BTreeMap::new();
        m.insert("model".into(), Json::Str(self.model.clone()));
        m.insert("version".into(), Json::Num(self.version as f64));
        m.insert("created_ms".into(), Json::Num(self.created_ms as f64));
        m.insert("num_shards".into(), Json::Num(self.num_shards as f64));
        m.insert("queue_offsets".into(), nums(&self.queue_offsets));
        m.insert("metric".into(), Json::Num(self.metric));
        m.insert("kind".into(), Json::Str(self.kind.as_str().to_string()));
        m.insert("parent".into(), Json::Num(self.parent as f64));
        m.insert("epochs".into(), nums(&self.epochs));
        m.insert("wal_offsets".into(), nums(&self.wal_offsets));
        m.insert("route_epoch".into(), Json::Num(self.route_epoch as f64));
        if !self.slot_map.is_empty() {
            m.insert("slot_map".into(), Json::Str(to_hex(&self.slot_map)));
        }
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<CkptManifest> {
        let field = |k: &str| {
            j.get(k)
                .ok_or_else(|| Error::Checkpoint(format!("manifest missing {k}")))
        };
        let nums = |k: &str| -> Vec<u64> {
            j.get(k)
                .and_then(|v| v.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(|v| v.as_i64().unwrap_or(0) as u64)
                .collect()
        };
        // Chain fields default to a standalone base so pre-incremental
        // manifests keep loading.
        let kind = match j.get("kind").and_then(|v| v.as_str()) {
            Some(s) => CkptKind::parse(s)?,
            None => CkptKind::Base,
        };
        Ok(CkptManifest {
            model: field("model")?.as_str().unwrap_or_default().to_string(),
            version: field("version")?.as_i64().unwrap_or(0) as u64,
            created_ms: field("created_ms")?.as_i64().unwrap_or(0) as u64,
            num_shards: field("num_shards")?.as_i64().unwrap_or(0) as u32,
            queue_offsets: field("queue_offsets")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|v| v.as_i64().unwrap_or(0) as u64)
                .collect(),
            metric: field("metric")?.as_f64().unwrap_or(0.0),
            kind,
            parent: j.get("parent").and_then(|v| v.as_i64()).unwrap_or(0) as u64,
            epochs: nums("epochs"),
            wal_offsets: nums("wal_offsets"),
            route_epoch: j.get("route_epoch").and_then(|v| v.as_i64()).unwrap_or(0) as u64,
            slot_map: j
                .get("slot_map")
                .and_then(|v| v.as_str())
                .map(from_hex)
                .unwrap_or_default(),
        })
    }
}

/// Lowercase hex for opaque manifest payloads (the slot map).
fn to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Inverse of [`to_hex`]; malformed input yields the readable prefix
/// (manifest loading stays tolerant, the epoch guard catches the rest).
fn from_hex(s: &str) -> Vec<u8> {
    let digits: Vec<u8> = s.bytes().collect();
    digits
        .chunks(2)
        .map_while(|pair| match pair {
            [hi, lo] => {
                let h = (*hi as char).to_digit(16)?;
                let l = (*lo as char).to_digit(16)?;
                Some((h * 16 + l) as u8)
            }
            _ => None,
        })
        .collect()
}

/// Two-tier checkpoint store.
pub struct CheckpointStore {
    local: PathBuf,
    remote: Option<PathBuf>,
    mmap_load: bool,
}

impl CheckpointStore {
    /// Store rooted at `local`, optionally replicating to `remote`.
    pub fn new(local: impl Into<PathBuf>, remote: Option<PathBuf>) -> CheckpointStore {
        CheckpointStore { local: local.into(), remote, mmap_load: true }
    }

    /// Toggle mmap-backed chunk loads (`ckpt_mmap_load` knob). On by
    /// default; platforms without the raw mmap binding fall back to
    /// streamed reads regardless.
    pub fn set_mmap_load(&mut self, on: bool) {
        self.mmap_load = on;
    }

    /// Whether mmap-backed chunk loads are actually engaged: configured on
    /// *and* supported by the platform's raw mmap binding.
    pub fn mmap_load_engaged(&self) -> bool {
        self.mmap_load && sys::supported()
    }

    /// Register the engaged-mmap info gauge (`weips_ckpt_mmap_engaged`)
    /// under `role`. Weak-held like every sampler: a dropped store's
    /// series disappears from scrapes.
    pub fn register_metrics(self: &Arc<Self>, role: &str) {
        let weak = Arc::downgrade(self);
        crate::metrics::register_fn(
            "weips_ckpt_mmap_engaged",
            &[("role", role.to_string())],
            Box::new(move || {
                weak.upgrade().map(|s| if s.mmap_load_engaged() { 1.0 } else { 0.0 })
            }),
        );
    }

    fn version_dir(root: &Path, model: &str, version: u64) -> PathBuf {
        root.join(model).join(format!("v{version:010}"))
    }

    fn shard_path(root: &Path, model: &str, version: u64, shard: u32, kind: CkptKind) -> PathBuf {
        let ext = match kind {
            CkptKind::Base => "ckpt",
            CkptKind::Delta => "delta",
        };
        Self::version_dir(root, model, version).join(format!("shard_{shard}.{ext}"))
    }

    /// Atomically write one shard's full-snapshot chunk (base kind).
    pub fn save_shard(&self, model: &str, version: u64, shard: u32, data: &[u8]) -> Result<()> {
        self.save_chunk(model, version, shard, CkptKind::Base, data)
    }

    /// Atomically write one shard's chunk of the given kind.
    pub fn save_chunk(
        &self,
        model: &str,
        version: u64,
        shard: u32,
        kind: CkptKind,
        data: &[u8],
    ) -> Result<()> {
        let path = Self::shard_path(&self.local, model, version, shard, kind);
        std::fs::create_dir_all(path.parent().unwrap())?;
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, frame(data))?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    /// Load one shard's full-snapshot chunk (CRC-verified).
    pub fn load_shard(&self, model: &str, version: u64, shard: u32) -> Result<ChunkData> {
        self.load_chunk(model, version, shard, CkptKind::Base)
    }

    /// Load one shard's chunk of the given kind (CRC-verified, remote
    /// fallback). The chunk is mmap'd when the platform allows it, so
    /// callers decode over the page cache instead of a heap copy.
    pub fn load_chunk(
        &self,
        model: &str,
        version: u64,
        shard: u32,
        kind: CkptKind,
    ) -> Result<ChunkData> {
        self.load_chunk_from(&self.local, model, version, shard, kind)
            .or_else(|e| match &self.remote {
                Some(remote) => self.load_chunk_from(remote, model, version, shard, kind),
                None => Err(e),
            })
    }

    fn load_chunk_from(
        &self,
        root: &Path,
        model: &str,
        version: u64,
        shard: u32,
        kind: CkptKind,
    ) -> Result<ChunkData> {
        let path = Self::shard_path(root, model, version, shard, kind);
        if self.mmap_load && sys::supported() {
            if let Ok(file) = std::fs::File::open(&path) {
                if let Ok(map) = sys::Mmap::map(&file) {
                    // Recovery walks the chunk front-to-back exactly once.
                    map.advise(sys::MADV_SEQUENTIAL);
                    let (start, end) = match unframe(&map)? {
                        Some((payload, used)) if used == map.len() => (8, 8 + payload.len()),
                        _ => {
                            return Err(Error::Checkpoint(format!(
                                "{}: truncated",
                                path.display()
                            )))
                        }
                    };
                    return Ok(ChunkData { backing: Backing::Mapped(map), start, end });
                }
            }
            // Open/map failure (missing file, empty file, exotic fs):
            // the streamed path below produces the error — or the bytes.
        }
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::Checkpoint(format!("{}: {e}", path.display())))?;
        let (start, end) = match unframe(&bytes)? {
            Some((payload, used)) if used == bytes.len() => (8, 8 + payload.len()),
            _ => return Err(Error::Checkpoint(format!("{}: truncated", path.display()))),
        };
        Ok(ChunkData { backing: Backing::Owned(bytes), start, end })
    }

    /// Finalize a checkpoint: write its manifest (makes it visible).
    pub fn write_manifest(&self, m: &CkptManifest) -> Result<()> {
        let dir = Self::version_dir(&self.local, &m.model, m.version);
        std::fs::create_dir_all(&dir)?;
        let tmp = dir.join("manifest.json.tmp");
        std::fs::write(&tmp, m.to_json().to_string())?;
        std::fs::rename(tmp, dir.join("manifest.json"))?;
        Ok(())
    }

    /// Read a checkpoint's manifest.
    pub fn load_manifest(&self, model: &str, version: u64) -> Result<CkptManifest> {
        for root in std::iter::once(&self.local).chain(self.remote.iter()) {
            let path = Self::version_dir(root, model, version).join("manifest.json");
            if let Ok(text) = std::fs::read_to_string(&path) {
                return CkptManifest::from_json(&Json::parse(&text)?);
            }
        }
        Err(Error::Checkpoint(format!("{model} v{version}: no manifest")))
    }

    /// All finalized versions (ascending) visible for `model`.
    pub fn list_versions(&self, model: &str) -> Vec<u64> {
        let mut versions = std::collections::BTreeSet::new();
        for root in std::iter::once(&self.local).chain(self.remote.iter()) {
            let dir = root.join(model);
            let Ok(entries) = std::fs::read_dir(&dir) else { continue };
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().into_owned();
                if let Some(v) = name.strip_prefix('v').and_then(|s| s.parse::<u64>().ok()) {
                    if e.path().join("manifest.json").exists() {
                        versions.insert(v);
                    }
                }
            }
        }
        versions.into_iter().collect()
    }

    /// Latest finalized version.
    pub fn latest_version(&self, model: &str) -> Option<u64> {
        self.list_versions(model).into_iter().last()
    }

    /// Copy a finalized checkpoint to the remote tier (the hourly/daily
    /// backup). No-op without a remote root.
    pub fn replicate_to_remote(&self, model: &str, version: u64) -> Result<()> {
        let Some(remote) = &self.remote else { return Ok(()) };
        let src = Self::version_dir(&self.local, model, version);
        let dst = Self::version_dir(remote, model, version);
        std::fs::create_dir_all(&dst)?;
        for entry in std::fs::read_dir(&src)? {
            let entry = entry?;
            if entry.path().extension().map(|e| e == "tmp").unwrap_or(false) {
                continue;
            }
            std::fs::copy(entry.path(), dst.join(entry.file_name()))?;
        }
        Ok(())
    }

    /// Keep the newest `keep` local versions, delete the rest. Returns the
    /// removed versions. Remote tier is never GC'd here.
    pub fn gc_local(&self, model: &str, keep: usize) -> Result<Vec<u64>> {
        let versions = self.list_local_versions(model);
        if versions.len() <= keep {
            return Ok(Vec::new());
        }
        let cut = versions.len() - keep;
        let mut removed = Vec::new();
        for v in &versions[..cut] {
            std::fs::remove_dir_all(Self::version_dir(&self.local, model, *v))?;
            removed.push(*v);
        }
        Ok(removed)
    }

    /// Delete one local version outright (chain-aware GC uses this; the
    /// plain newest-N [`Self::gc_local`] would cut delta chains in half).
    /// No-op if the version directory does not exist. Remote is untouched.
    pub fn remove_local_version(&self, model: &str, version: u64) -> Result<()> {
        let dir = Self::version_dir(&self.local, model, version);
        if dir.exists() {
            std::fs::remove_dir_all(dir)?;
        }
        Ok(())
    }

    fn list_local_versions(&self, model: &str) -> Vec<u64> {
        let mut versions = Vec::new();
        let dir = self.local.join(model);
        let Ok(entries) = std::fs::read_dir(&dir) else { return versions };
        for e in entries.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            if let Some(v) = name.strip_prefix('v').and_then(|s| s.parse::<u64>().ok()) {
                if e.path().join("manifest.json").exists() {
                    versions.push(v);
                }
            }
        }
        versions.sort();
        versions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(remote: bool) -> (CheckpointStore, PathBuf) {
        let base = std::env::temp_dir().join(format!(
            "weips-ckpt-{}-{:x}",
            std::process::id(),
            crate::util::mono_ns()
        ));
        let local = base.join("local");
        let remote_dir = remote.then(|| base.join("remote"));
        std::fs::create_dir_all(&local).unwrap();
        if let Some(r) = &remote_dir {
            std::fs::create_dir_all(r).unwrap();
        }
        (CheckpointStore::new(local, remote_dir), base)
    }

    fn manifest(v: u64, shards: u32) -> CkptManifest {
        CkptManifest {
            model: "ctr".into(),
            version: v,
            created_ms: 123,
            num_shards: shards,
            queue_offsets: vec![10, 20],
            metric: 0.75,
            kind: CkptKind::Base,
            parent: 0,
            epochs: vec![7],
            wal_offsets: vec![1, 2],
            route_epoch: 3,
            slot_map: vec![0xAB, 0xCD, 0x01],
        }
    }

    #[test]
    fn save_load_round_trip() {
        let (s, base) = tmp_store(false);
        s.save_shard("ctr", 1, 0, b"shard-zero").unwrap();
        s.save_shard("ctr", 1, 1, b"shard-one").unwrap();
        s.write_manifest(&manifest(1, 2)).unwrap();
        assert_eq!(s.load_shard("ctr", 1, 0).unwrap().as_slice(), b"shard-zero");
        assert_eq!(s.load_shard("ctr", 1, 1).unwrap().as_slice(), b"shard-one");
        let m = s.load_manifest("ctr", 1).unwrap();
        assert_eq!(m, manifest(1, 2));
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let (s, base) = tmp_store(false);
        s.save_shard("ctr", 1, 0, b"data-to-corrupt").unwrap();
        // Flip a byte on disk.
        let path = base
            .join("local/ctr/v0000000001/shard_0.ckpt");
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();
        assert!(s.load_shard("ctr", 1, 0).is_err());
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn mmap_and_streamed_loads_are_byte_identical() {
        let (mut s, base) = tmp_store(false);
        let payload: Vec<u8> = (0..60_000u32).map(|i| (i * 2654435761) as u8).collect();
        s.save_shard("ctr", 1, 0, &payload).unwrap();
        let mapped = s.load_shard("ctr", 1, 0).unwrap();
        s.set_mmap_load(false);
        let streamed = s.load_shard("ctr", 1, 0).unwrap();
        assert!(!streamed.is_mapped());
        assert_eq!(mapped.as_slice(), streamed.as_slice());
        assert_eq!(streamed.as_slice(), payload.as_slice());
        if sys::supported() {
            assert!(mapped.is_mapped(), "mmap path should engage on this platform");
            assert_eq!(mapped.into_vec(), payload);
        }
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn truncated_and_bitflipped_mapped_chunks_error_cleanly() {
        let (s, base) = tmp_store(false);
        let payload: Vec<u8> = (0..10_000u32).map(|i| i as u8).collect();
        s.save_shard("ctr", 1, 0, &payload).unwrap();
        let path = base.join("local/ctr/v0000000001/shard_0.ckpt");
        let good = std::fs::read(&path).unwrap();

        // Torn tail: the frame header promises more bytes than the file
        // holds — a clean truncation error, no hang, no UB.
        for cut in [good.len() - 1, good.len() / 2, 7, 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            let err = s.load_shard("ctr", 1, 0).unwrap_err();
            assert!(!err.to_string().is_empty(), "cut={cut}");
        }

        // Empty file: mmap of zero bytes is rejected before the decode.
        std::fs::write(&path, b"").unwrap();
        assert!(s.load_shard("ctr", 1, 0).is_err());

        // Bit flips anywhere — header, length, body — fail the CRC (or
        // the length sanity check), never crash.
        for at in [0usize, 3, 5, 8, good.len() / 2, good.len() - 1] {
            let mut bad = good.clone();
            bad[at] ^= 0x40;
            std::fs::write(&path, &bad).unwrap();
            assert!(s.load_shard("ctr", 1, 0).is_err(), "flip at {at}");
        }

        // Restoring the original bytes restores the load.
        std::fs::write(&path, &good).unwrap();
        assert_eq!(s.load_shard("ctr", 1, 0).unwrap().as_slice(), payload.as_slice());
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn versions_listed_only_when_finalized() {
        let (s, base) = tmp_store(false);
        s.save_shard("ctr", 1, 0, b"x").unwrap();
        // No manifest yet: not visible.
        assert!(s.list_versions("ctr").is_empty());
        s.write_manifest(&manifest(1, 1)).unwrap();
        s.save_shard("ctr", 3, 0, b"y").unwrap();
        s.write_manifest(&manifest(3, 1)).unwrap();
        assert_eq!(s.list_versions("ctr"), vec![1, 3]);
        assert_eq!(s.latest_version("ctr"), Some(3));
        assert_eq!(s.latest_version("other"), None);
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn remote_tier_fallback() {
        let (s, base) = tmp_store(true);
        s.save_shard("ctr", 1, 0, b"payload").unwrap();
        s.write_manifest(&manifest(1, 1)).unwrap();
        s.replicate_to_remote("ctr", 1).unwrap();
        // Simulate local disk loss.
        std::fs::remove_dir_all(base.join("local/ctr")).unwrap();
        assert_eq!(s.load_shard("ctr", 1, 0).unwrap().as_slice(), b"payload");
        assert_eq!(s.load_manifest("ctr", 1).unwrap().version, 1);
        assert_eq!(s.list_versions("ctr"), vec![1]);
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn gc_keeps_newest_local_only() {
        let (s, base) = tmp_store(true);
        for v in 1..=5 {
            s.save_shard("ctr", v, 0, b"d").unwrap();
            s.write_manifest(&manifest(v, 1)).unwrap();
            s.replicate_to_remote("ctr", v).unwrap();
        }
        let removed = s.gc_local("ctr", 2).unwrap();
        assert_eq!(removed, vec![1, 2, 3]);
        // Remote still has everything -> versions remain visible.
        assert_eq!(s.list_versions("ctr"), vec![1, 2, 3, 4, 5]);
        assert_eq!(s.load_shard("ctr", 1, 0).unwrap().as_slice(), b"d");
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn missing_artifacts_error_cleanly() {
        let (s, base) = tmp_store(false);
        assert!(s.load_shard("nope", 1, 0).is_err());
        assert!(s.load_manifest("nope", 1).is_err());
        assert!(s.list_versions("nope").is_empty());
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn chain_manifest_fields_round_trip_and_default() {
        let (s, base) = tmp_store(false);
        let mut m = manifest(9, 2);
        m.kind = CkptKind::Delta;
        m.parent = 8;
        m.epochs = vec![4, 5];
        m.wal_offsets = vec![100, 200, 300];
        s.write_manifest(&m).unwrap();
        assert_eq!(s.load_manifest("ctr", 9).unwrap(), m);
        // Pre-incremental manifests (no chain keys) load as a plain base.
        let dir = base.join("local/ctr/v0000000003");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"model":"ctr","version":3,"created_ms":1,"num_shards":1,"queue_offsets":[5],"metric":0.5}"#,
        )
        .unwrap();
        let old = s.load_manifest("ctr", 3).unwrap();
        assert_eq!(old.kind, CkptKind::Base);
        assert_eq!(old.parent, 0);
        assert!(old.epochs.is_empty() && old.wal_offsets.is_empty());
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn delta_chunks_live_beside_base_chunks() {
        let (s, base) = tmp_store(false);
        s.save_chunk("ctr", 2, 0, CkptKind::Delta, b"delta-bytes").unwrap();
        assert_eq!(s.load_chunk("ctr", 2, 0, CkptKind::Delta).unwrap().as_slice(), b"delta-bytes");
        // The base chunk of the same version is a distinct artifact.
        assert!(s.load_shard("ctr", 2, 0).is_err());
        s.save_shard("ctr", 2, 0, b"base-bytes").unwrap();
        assert_eq!(s.load_shard("ctr", 2, 0).unwrap().as_slice(), b"base-bytes");
        assert_eq!(s.load_chunk("ctr", 2, 0, CkptKind::Delta).unwrap().as_slice(), b"delta-bytes");
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn remove_local_version_only_touches_that_version() {
        let (s, base) = tmp_store(false);
        for v in 1..=3 {
            s.save_shard("ctr", v, 0, b"d").unwrap();
            s.write_manifest(&manifest(v, 1)).unwrap();
        }
        s.remove_local_version("ctr", 2).unwrap();
        s.remove_local_version("ctr", 99).unwrap(); // absent: no-op
        assert_eq!(s.list_versions("ctr"), vec![1, 3]);
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn manifest_queue_offsets_round_trip() {
        // The downgrade path depends on offsets surviving the round trip.
        let (s, base) = tmp_store(false);
        let mut m = manifest(7, 4);
        m.queue_offsets = vec![0, u32::MAX as u64 + 5, 42, 1];
        m.metric = 0.812345;
        s.write_manifest(&m).unwrap();
        let back = s.load_manifest("ctr", 7).unwrap();
        assert_eq!(back.queue_offsets, m.queue_offsets);
        assert!((back.metric - m.metric).abs() < 1e-12);
        std::fs::remove_dir_all(base).ok();
    }
}
