//! Incremental checkpoint & recovery engine: manifest chains, chain GC,
//! and the WAL journal that bounds data loss between delta chunks.
//!
//! Monolith-style durability (Lian et al., 2022) on top of the
//! dirty-epoch substrate: periodic **base** snapshots plus **delta**
//! chunks holding only the rows touched since the parent checkpoint, a
//! replayable write-ahead log closing the gap from the last sealed chunk
//! to the crash point, and a recovery path that loads base → applies the
//! delta chain → replays the WAL tail. Chunk formats live with the data
//! they serialize ([`crate::table::StripedSparseTable::encode_delta_rows`],
//! [`crate::server::master::MasterShard::encode_delta`]); this module
//! owns the *lineage*: which versions form a chain, which chains are
//! still needed, and what the WAL must replay.
//!
//! Chain shape: every version's [`CkptManifest`] records its kind and,
//! for deltas, the parent version plus the per-shard epoch cuts the delta
//! was collected against. [`resolve_chain`] walks tip → base and
//! validates the lineage (missing manifests, duplicate versions / cycles,
//! non-monotonic parents all fail cleanly — hostile or half-GC'd stores
//! must never panic or silently mis-restore).

use crate::queue::wal::WalLog;
use crate::server::master::MasterShard;
use crate::storage::{CheckpointStore, CkptKind, CkptManifest};
use crate::{Error, Result};

/// Hard cap on chain length: a longer walk means a corrupt lineage (the
/// policy reseeds a base every few checkpoints), not a legitimate chain.
pub const MAX_CHAIN: usize = 1024;

/// WAL record envelope tag: payload after the tag byte is a full delta
/// chunk ([`MasterShard::encode_delta`] format).
pub const WAL_TAG_FULL: u8 = 0xD1;

/// WAL record envelope tag: payload after the tag byte is a
/// metadata-only access-stamp micro-delta
/// ([`MasterShard::encode_access_delta`] format) — written for windows
/// where the only dirt is read-path access-time refreshes, at a fraction
/// of a full chunk's size.
pub const WAL_TAG_META: u8 = 0xD2;

/// Incremental checkpoint policy knobs.
#[derive(Debug, Clone)]
pub struct IncrPolicy {
    /// Chunks per chain: every `base_every`-th checkpoint reseeds a full
    /// base (1 = every checkpoint is a base, i.e. the legacy behaviour).
    pub base_every: u64,
    /// Complete chains to keep locally; GC drops whole chains only, never
    /// a base out from under its deltas.
    pub keep_chains: usize,
}

impl Default for IncrPolicy {
    fn default() -> Self {
        IncrPolicy { base_every: 4, keep_chains: 2 }
    }
}

/// Resolve the recovery chain for `version`: returns manifests ordered
/// base first, `version`'s last. Validates the lineage and fails cleanly
/// on missing manifests, cycles / duplicate versions, parents that do not
/// precede their child, or chains longer than [`MAX_CHAIN`].
pub fn resolve_chain(
    store: &CheckpointStore,
    model: &str,
    version: u64,
) -> Result<Vec<CkptManifest>> {
    let mut rev: Vec<CkptManifest> = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    let mut v = version;
    loop {
        if !seen.insert(v) {
            return Err(Error::Checkpoint(format!(
                "{model} v{version}: manifest chain revisits v{v} (cycle or duplicate)"
            )));
        }
        if rev.len() >= MAX_CHAIN {
            return Err(Error::Checkpoint(format!(
                "{model} v{version}: chain exceeds {MAX_CHAIN} links"
            )));
        }
        let m = store.load_manifest(model, v)?;
        if m.version != v {
            return Err(Error::Checkpoint(format!(
                "{model} v{v}: manifest claims version {}",
                m.version
            )));
        }
        let (kind, parent) = (m.kind, m.parent);
        rev.push(m);
        match kind {
            CkptKind::Base => break,
            CkptKind::Delta => {
                if parent == 0 || parent >= v {
                    return Err(Error::Checkpoint(format!(
                        "{model} v{v}: delta has invalid parent {parent}"
                    )));
                }
                v = parent;
            }
        }
    }
    rev.reverse();
    Ok(rev)
}

/// Decide the next checkpoint's kind: a base when there is no usable
/// lineage (nothing yet, or a corrupt/unresolvable chain — reseeding is
/// the self-healing move) or when the current chain already holds
/// `base_every` chunks; otherwise a delta against the latest version,
/// whose manifest is returned for its epoch cuts.
pub fn plan_next(
    store: &CheckpointStore,
    model: &str,
    policy: &IncrPolicy,
) -> (CkptKind, Option<CkptManifest>) {
    let Some(latest) = store.latest_version(model) else {
        return (CkptKind::Base, None);
    };
    match resolve_chain(store, model, latest) {
        Ok(chain) if (chain.len() as u64) < policy.base_every.max(1) => {
            let tip = chain.into_iter().next_back();
            (CkptKind::Delta, tip)
        }
        _ => (CkptKind::Base, None),
    }
}

/// Chain-aware local GC: keep the newest `keep_chains` bases and every
/// version from the oldest kept base onwards; remove older versions
/// wholesale. Never cuts a live chain in half (version numbers within a
/// lineage are monotonically increasing). Returns the removed versions.
pub fn gc_chains(store: &CheckpointStore, model: &str, keep_chains: usize) -> Result<Vec<u64>> {
    let versions = store.list_versions(model);
    let mut bases = Vec::new();
    for &v in &versions {
        if let Ok(m) = store.load_manifest(model, v) {
            if m.kind == CkptKind::Base {
                bases.push(v);
            }
        }
    }
    let keep = keep_chains.max(1);
    if bases.len() <= keep {
        return Ok(Vec::new());
    }
    let cutoff = bases[bases.len() - keep];
    let mut removed = Vec::new();
    for &v in &versions {
        if v < cutoff {
            store.remove_local_version(model, v)?;
            removed.push(v);
        }
    }
    Ok(removed)
}

/// Per-shard WAL journal: drains the shard's dirty set as a micro-delta
/// chunk into one WAL partition on every poll. Records are the same
/// chunk format as checkpoint deltas, so recovery replays them through
/// the identical decode path — base chunk, delta chain, then these.
pub struct WalJournal {
    partition: u32,
    /// Epoch cut of the last journaled micro-delta.
    last_cut: u64,
    /// Dense-table versions at the last append (dense state piggybacks on
    /// every chunk; this gates appends when only dense changed).
    last_dense: Vec<u64>,
    /// While set, polls are no-ops. A crashed-and-replaced shard must not
    /// journal its blank replacement's state — recovery would replay that
    /// junk over the restored rows. [`Self::reset`] resumes.
    suspended: bool,
}

impl WalJournal {
    /// Journal for one shard writing to `partition`.
    pub fn new(partition: u32) -> WalJournal {
        WalJournal { partition, last_cut: 0, last_dense: Vec::new(), suspended: false }
    }

    /// The WAL partition this journal appends to.
    pub fn partition(&self) -> u32 {
        self.partition
    }

    /// Stop journaling until the next [`Self::reset`] (between a shard
    /// crash and its recovery).
    pub fn suspend(&mut self) {
        self.suspended = true;
    }

    /// Cut the shard's write epoch and journal everything dirtied since
    /// the previous cut. Clean windows append nothing and — because the
    /// dirty probe is a per-stripe `max_epoch` compare, not an encode —
    /// cost no allocation, keeping idle masters idle. Returns the
    /// appended offset, if any.
    pub fn poll(
        &mut self,
        master: &MasterShard,
        wal: &WalLog,
        now_ms: u64,
    ) -> Result<Option<u64>> {
        match self.encode_window(master) {
            Some(payload) => {
                let offset = crate::queue::SyncLog::append(wal, self.partition, now_ms, payload)?;
                Ok(Some(offset))
            }
            None => Ok(None),
        }
    }

    /// The encode half of [`Self::poll`]: cut the write epoch, encode the
    /// dirty window as a tagged envelope and advance the frontier —
    /// without touching the log. Encoding dominates the journal cost, so
    /// [`journal_tick`] fans these out across the sync pool; the appends
    /// themselves must stay in tick order and are issued sequentially by
    /// whoever called this.
    pub fn encode_window(&mut self, master: &MasterShard) -> Option<Vec<u8>> {
        if self.suspended {
            return None;
        }
        let dense = master.dense_versions();
        let (rows, graves, access_only) = master.dirty_counts_split(self.last_cut);
        if rows + graves + access_only == 0 && dense == self.last_dense {
            return None;
        }
        let cut = master.cut_epoch();
        let payload = if rows + graves == 0 && dense == self.last_dense {
            // Access-time-only window (pure read traffic): a metadata
            // micro-record carries just the (id, last_access_ms) stamps,
            // keeping feature-expiry fidelity across recovery without
            // paying for full row payloads.
            let body = master.encode_access_delta(self.last_cut);
            let mut rec = Vec::with_capacity(body.len() + 1);
            rec.push(WAL_TAG_META);
            rec.extend_from_slice(&body);
            rec
        } else {
            let chunk = master.encode_delta(self.last_cut);
            let mut rec = Vec::with_capacity(chunk.bytes.len() + 1);
            rec.push(WAL_TAG_FULL);
            rec.extend_from_slice(&chunk.bytes);
            rec
        };
        self.last_cut = cut;
        self.last_dense = dense;
        Some(payload)
    }

    /// Re-arm the journal frontier after a checkpoint seal: subsequent
    /// polls journal only what the sealed chunks do not already cover.
    /// Does **not** lift a suspension — a checkpoint taken between a
    /// crash and its recovery must not let the blank replacement reach
    /// the log ([`Self::resume`] is recovery's job).
    pub fn reset(&mut self, cut: u64, dense_versions: Vec<u64>) {
        self.last_cut = cut;
        self.last_dense = dense_versions;
    }

    /// Re-arm **and** lift any suspension — call once the shard's state
    /// has been restored (recovery / downgrade rollback).
    pub fn resume(&mut self, cut: u64, dense_versions: Vec<u64>) {
        self.reset(cut, dense_versions);
        self.suspended = false;
    }
}

/// Journal one sync tick across every shard: the micro-delta *encodes*
/// (the expensive half) run concurrently on `pool` when one is given,
/// while the *appends* are issued sequentially afterwards in shard order
/// — each partition sees exactly the offsets a sequential tick would
/// have produced, so replay bounds and checkpoint `wal_offsets` are
/// unaffected by the offload. Returns the number of records appended.
///
/// Callers are the sync-tick / pump threads, never the pool's own
/// workers (`run_borrowed` from inside a task would deadlock a full
/// pool).
pub fn journal_tick(
    journals: &[std::sync::Mutex<WalJournal>],
    masters: &[std::sync::Arc<MasterShard>],
    wal: &WalLog,
    now_ms: u64,
    pool: Option<&crate::util::ThreadPool>,
) -> Result<usize> {
    let n = journals.len().min(masters.len());
    let payloads: Vec<Option<Vec<u8>>> = match pool {
        Some(pool) if n > 1 => {
            let slots: Vec<std::sync::Mutex<Option<Vec<u8>>>> =
                (0..n).map(|_| std::sync::Mutex::new(None)).collect();
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(n);
            for i in 0..n {
                let (journal, master, slot) = (&journals[i], &masters[i], &slots[i]);
                tasks.push(Box::new(move || {
                    *slot.lock().unwrap() = journal.lock().unwrap().encode_window(master);
                }));
            }
            pool.run_borrowed(tasks);
            slots.into_iter().map(|s| s.into_inner().unwrap()).collect()
        }
        _ => (0..n).map(|i| journals[i].lock().unwrap().encode_window(&masters[i])).collect(),
    };
    let mut appended = 0;
    for (i, payload) in payloads.into_iter().enumerate() {
        if let Some(payload) = payload {
            let partition = journals[i].lock().unwrap().partition();
            crate::queue::SyncLog::append(wal, partition, now_ms, payload)?;
            appended += 1;
        }
    }
    Ok(appended)
}

/// Replay a WAL partition's tail into a master shard. Records carry a
/// one-byte envelope tag: [`WAL_TAG_FULL`] wraps a micro-delta chunk
/// (rows stamped with the shard's *current* write epoch so the next
/// checkpoint delta captures them), [`WAL_TAG_META`] wraps an
/// access-stamp record. Any other leading byte is treated as a legacy
/// untagged full chunk from a pre-envelope WAL (ambiguous only for
/// legacy shards whose id ≡ 0xD1/0xD2 mod 256, i.e. deployments with
/// 210+ shards journaled before the upgrade). Returns records replayed.
pub fn replay_wal(
    master: &MasterShard,
    wal: &WalLog,
    partition: u32,
    from_offset: u64,
) -> Result<usize> {
    use crate::queue::SyncLog;
    let earliest = wal.earliest_offset(partition)?;
    let mut offset = from_offset.max(earliest);
    let mut replayed = 0usize;
    loop {
        let records = wal.fetch(partition, offset, 256, std::time::Duration::ZERO)?;
        if records.is_empty() {
            return Ok(replayed);
        }
        for rec in &records {
            offset = rec.offset + 1;
            match rec.payload.split_first() {
                Some((&WAL_TAG_META, body)) => {
                    master.apply_access_delta(body)?;
                }
                Some((&WAL_TAG_FULL, body)) => {
                    master.apply_delta(body, true)?;
                }
                _ => {
                    master.apply_delta(&rec.payload, true)?;
                }
            }
            replayed += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store() -> (CheckpointStore, std::path::PathBuf) {
        let base = std::env::temp_dir().join(format!(
            "weips-incr-{}-{:x}",
            std::process::id(),
            crate::util::mono_ns()
        ));
        let local = base.join("local");
        std::fs::create_dir_all(&local).unwrap();
        (CheckpointStore::new(local, None), base)
    }

    fn manifest(v: u64, kind: CkptKind, parent: u64) -> CkptManifest {
        CkptManifest {
            model: "ctr".into(),
            version: v,
            created_ms: v * 10,
            num_shards: 1,
            queue_offsets: vec![],
            metric: 0.5,
            kind,
            parent,
            epochs: vec![v],
            wal_offsets: vec![],
            route_epoch: 0,
            slot_map: vec![],
        }
    }

    fn seal(s: &CheckpointStore, v: u64, kind: CkptKind, parent: u64) {
        s.save_chunk("ctr", v, 0, kind, b"chunk").unwrap();
        s.write_manifest(&manifest(v, kind, parent)).unwrap();
    }

    #[test]
    fn resolve_chain_walks_base_first() {
        let (s, base) = tmp_store();
        seal(&s, 1, CkptKind::Base, 0);
        seal(&s, 2, CkptKind::Delta, 1);
        seal(&s, 3, CkptKind::Delta, 2);
        let chain = resolve_chain(&s, "ctr", 3).unwrap();
        let versions: Vec<u64> = chain.iter().map(|m| m.version).collect();
        assert_eq!(versions, vec![1, 2, 3]);
        assert_eq!(chain[0].kind, CkptKind::Base);
        // A base resolves to itself.
        assert_eq!(resolve_chain(&s, "ctr", 1).unwrap().len(), 1);
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn resolve_chain_rejects_hostile_lineage() {
        let (s, base) = tmp_store();
        // Missing parent manifest.
        seal(&s, 5, CkptKind::Delta, 4);
        assert!(resolve_chain(&s, "ctr", 5).is_err());
        // Self-parent (cycle of one).
        let mut m = manifest(7, CkptKind::Delta, 7);
        m.parent = 7;
        s.save_chunk("ctr", 7, 0, CkptKind::Delta, b"x").unwrap();
        s.write_manifest(&m).unwrap();
        assert!(resolve_chain(&s, "ctr", 7).is_err());
        // Parent newer than child.
        seal(&s, 9, CkptKind::Base, 0);
        seal(&s, 8, CkptKind::Delta, 9);
        assert!(resolve_chain(&s, "ctr", 8).is_err());
        // Delta claiming parent 0.
        seal(&s, 11, CkptKind::Delta, 0);
        assert!(resolve_chain(&s, "ctr", 11).is_err());
        // Manifest whose recorded version disagrees with its directory.
        let mut lying = manifest(13, CkptKind::Base, 0);
        lying.version = 12;
        let dir = base.join("local/ctr/v0000000013");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), {
            // Reuse the store writer for v12, then move it under v13.
            s.write_manifest(&lying).unwrap();
            std::fs::read(base.join("local/ctr/v0000000012/manifest.json")).unwrap()
        })
        .unwrap();
        assert!(resolve_chain(&s, "ctr", 13).is_err());
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn plan_next_alternates_bases_and_deltas() {
        let (s, base) = tmp_store();
        let policy = IncrPolicy { base_every: 3, keep_chains: 2 };
        assert_eq!(plan_next(&s, "ctr", &policy).0, CkptKind::Base);
        seal(&s, 1, CkptKind::Base, 0);
        let (kind, tip) = plan_next(&s, "ctr", &policy);
        assert_eq!(kind, CkptKind::Delta);
        assert_eq!(tip.unwrap().version, 1);
        seal(&s, 2, CkptKind::Delta, 1);
        let (kind, tip) = plan_next(&s, "ctr", &policy);
        assert_eq!(kind, CkptKind::Delta);
        assert_eq!(tip.unwrap().version, 2);
        seal(&s, 3, CkptKind::Delta, 2);
        // Chain is full (3 chunks): reseed.
        assert_eq!(plan_next(&s, "ctr", &policy).0, CkptKind::Base);
        // Corrupt lineage also reseeds instead of erroring.
        seal(&s, 4, CkptKind::Delta, 99);
        assert_eq!(plan_next(&s, "ctr", &policy).0, CkptKind::Base);
        std::fs::remove_dir_all(base).ok();
    }

    fn shard(clock: crate::util::clock::ManualClock) -> MasterShard {
        use crate::config::{ModelKind, ModelSpec};
        use crate::runtime::ModelConfig;
        let cfg = ModelConfig {
            batch_train: 8,
            batch_predict: 2,
            fields: 4,
            dim: 2,
            hidden: 8,
            ftrl_block_rows: 64,
            ftrl_alpha: 0.05,
            ftrl_beta: 1.0,
            ftrl_l1: 1.0,
            ftrl_l2: 1.0,
        };
        let spec = ModelSpec::derive("ctr", ModelKind::Fm, &cfg);
        MasterShard::new(0, spec, None, 1, std::sync::Arc::new(clock)).unwrap()
    }

    fn tmp_wal() -> (WalLog, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "weips-incr-wal-{}-{:x}",
            std::process::id(),
            crate::util::mono_ns()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        (WalLog::open(&dir, 1).unwrap(), dir)
    }

    #[test]
    fn access_only_window_journals_meta_record_and_replays() {
        use crate::proto::{SparsePull, SparsePush};
        use crate::util::clock::ManualClock;

        let clock = ManualClock::new(0);
        let src = shard(clock.clone());
        for i in 0..20u64 {
            src.sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![i],
                grads: vec![2.0],
            })
            .unwrap();
        }
        let (wal, dir) = tmp_wal();
        let mut journal = WalJournal::new(0);
        // Value-dirty window: full chunk under the FULL tag.
        journal.poll(&src, &wal, 1).unwrap().unwrap();

        // Pure read window: pulls refresh access times only.
        clock.set(10_000);
        src.sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: (0..5).collect(),
            slot: "w".into(),
        })
        .unwrap();
        journal.poll(&src, &wal, 2).unwrap().unwrap();
        // Nothing since: no record.
        assert!(journal.poll(&src, &wal, 3).unwrap().is_none());

        let recs = wal.fetch(0, 0, 16, std::time::Duration::ZERO).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].payload[0], WAL_TAG_FULL);
        assert_eq!(recs[1].payload[0], WAL_TAG_META);
        assert!(
            recs[1].payload.len() < recs[0].payload.len() / 2,
            "meta record should be far smaller than the full chunk"
        );

        // Replay into a blank shard: values land, and the access stamps
        // keep the refreshed rows alive through a feature-expire pass.
        let dst = shard(ManualClock::new(15_000));
        assert_eq!(replay_wal(&dst, &wal, 0, 0).unwrap(), 2);
        let evicted = dst.expire_features(6_000);
        assert_eq!(evicted, 15, "unrefreshed rows expire, stamped rows survive");
        let sv = dst
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![1],
                slot: "w".into(),
            })
            .unwrap();
        let expect = src
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![1],
                slot: "w".into(),
            })
            .unwrap();
        assert_eq!(sv.values, expect.values);
        assert!(sv.values[0] != 0.0);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn access_delta_decode_survives_hostile_input() {
        use crate::proto::{SparsePull, SparsePush};
        use crate::util::clock::ManualClock;

        let clock = ManualClock::new(0);
        let src = shard(clock.clone());
        for i in 0..8u64 {
            src.sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![i],
                grads: vec![2.0],
            })
            .unwrap();
        }
        let cut = src.cut_epoch();
        clock.set(500);
        src.sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: (0..8).collect(),
            slot: "w".into(),
        })
        .unwrap();
        let body = src.encode_access_delta(cut);
        let dst = shard(ManualClock::new(0));
        assert_eq!(dst.apply_access_delta(&body).unwrap(), 0, "no rows yet: skipped, not error");

        // Every truncation and every single-byte corruption must return
        // (Ok or Err) — never panic or allocate unboundedly.
        for n in 0..body.len() {
            let _ = dst.apply_access_delta(&body[..n]);
        }
        for i in 0..body.len() {
            let mut mutated = body.clone();
            mutated[i] ^= 0xFF;
            let _ = dst.apply_access_delta(&mutated);
        }

        // A record claiming absurd table counts errors cleanly.
        let mut w = crate::codec::Writer::with_capacity(16);
        w.put_u32(0);
        w.put_varint(0);
        w.put_varint(u32::MAX as u64);
        assert!(dst.apply_access_delta(&w.into_bytes()).is_err());

        // Unknown table names are advisory no-ops.
        let mut w = crate::codec::Writer::with_capacity(32);
        w.put_u32(0);
        w.put_varint(0);
        w.put_varint(1);
        w.put_str("no-such-table");
        w.put_varint(1);
        w.put_varint(7);
        w.put_varint(123);
        assert_eq!(dst.apply_access_delta(&w.into_bytes()).unwrap(), 0);
    }

    #[test]
    fn pooled_journal_tick_is_byte_identical_to_sequential_polls() {
        use crate::proto::SparsePush;
        use crate::util::clock::ManualClock;
        use std::sync::{Arc, Mutex};

        // Two identical 3-shard worlds: one journaled through the pooled
        // tick, one through plain sequential polls. Same WAL bytes, same
        // offsets — the offload moves work, never content.
        let build = || -> Vec<Arc<MasterShard>> {
            (0..3u32)
                .map(|_| {
                    let m = Arc::new(shard(ManualClock::new(0)));
                    for i in 0..40u64 {
                        m.sparse_push(&SparsePush {
                            model: "ctr".into(),
                            table: "w".into(),
                            ids: vec![i * 7 + 1],
                            grads: vec![0.5 + i as f32],
                        })
                        .unwrap();
                    }
                    m
                })
                .collect()
        };
        let pooled_masters = build();
        let seq_masters = build();

        let dir = std::env::temp_dir().join(format!(
            "weips-jtick-{}-{:x}",
            std::process::id(),
            crate::util::mono_ns()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let pooled_wal = WalLog::open(dir.join("pooled"), 3).unwrap();
        let seq_wal = WalLog::open(dir.join("seq"), 3).unwrap();

        let journals: Vec<Mutex<WalJournal>> =
            (0..3).map(|i| Mutex::new(WalJournal::new(i))).collect();
        let pool = crate::util::ThreadPool::new(2, "jtick-test");
        // Two dirty windows with more pushes in between.
        let appended =
            journal_tick(&journals, &pooled_masters, &pooled_wal, 1, Some(&pool)).unwrap();
        assert_eq!(appended, 3);
        for m in &pooled_masters {
            m.sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![999],
                grads: vec![1.0],
            })
            .unwrap();
        }
        assert_eq!(journal_tick(&journals, &pooled_masters, &pooled_wal, 2, Some(&pool)).unwrap(), 3);
        // Clean window: nothing appended, pooled or not.
        assert_eq!(journal_tick(&journals, &pooled_masters, &pooled_wal, 3, Some(&pool)).unwrap(), 0);

        let mut seq_journals: Vec<WalJournal> = (0..3).map(WalJournal::new).collect();
        for (j, m) in seq_journals.iter_mut().zip(&seq_masters) {
            j.poll(m, &seq_wal, 1).unwrap().unwrap();
        }
        for m in &seq_masters {
            m.sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![999],
                grads: vec![1.0],
            })
            .unwrap();
        }
        for (j, m) in seq_journals.iter_mut().zip(&seq_masters) {
            j.poll(m, &seq_wal, 2).unwrap().unwrap();
        }

        for p in 0..3u32 {
            let a = pooled_wal.fetch(p, 0, 16, std::time::Duration::ZERO).unwrap();
            let b = seq_wal.fetch(p, 0, 16, std::time::Duration::ZERO).unwrap();
            assert_eq!(a.len(), 2, "partition {p}");
            assert_eq!(a.len(), b.len());
            for (ra, rb) in a.iter().zip(&b) {
                assert_eq!(ra.offset, rb.offset);
                assert_eq!(ra.payload, rb.payload, "partition {p} diverged");
            }
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn gc_keeps_whole_chains() {
        let (s, base) = tmp_store();
        // Two full chains + the start of a third.
        seal(&s, 1, CkptKind::Base, 0);
        seal(&s, 2, CkptKind::Delta, 1);
        seal(&s, 3, CkptKind::Base, 0);
        seal(&s, 4, CkptKind::Delta, 3);
        seal(&s, 5, CkptKind::Base, 0);
        let removed = gc_chains(&s, "ctr", 2).unwrap();
        assert_eq!(removed, vec![1, 2]);
        assert_eq!(s.list_versions("ctr"), vec![3, 4, 5]);
        // Chains still resolve after GC.
        assert!(resolve_chain(&s, "ctr", 4).is_ok());
        // Keeping more chains than exist removes nothing.
        assert!(gc_chains(&s, "ctr", 5).unwrap().is_empty());
        std::fs::remove_dir_all(base).ok();
    }
}
