//! Elastic resharding: slot-based routing + live shard migration.
//!
//! The paper's router exists because "the resource requirements of the
//! two situations is inconsistent" (§4.1.4a) and clusters migrate
//! heterogeneously (§4.2.1d) — but a stateless `hash % N` router makes
//! changing `N` a full-stop re-checkpoint of the entire model. This
//! module replaces direct id→shard hashing with a **two-level slot map**
//! (Monolith-style movable ownership units):
//!
//! ```text
//!   id ──fxhash──► slot (fixed universe, e.g. 1024)
//!   slot ──SlotMap (versioned, epoch-stamped)──► shard
//! ```
//!
//! The slot hash never changes; only the small `slot → shard` table does,
//! so a rebalance re-routes exactly the ids in the moved slots (the
//! minimal-disruption property `it_reshard` proves) and every component
//! cuts over by swapping one `Arc<SlotMap>` — the epoch bump the paper's
//! second-level deployment story needs.
//!
//! **Live migration** ([`SlotTransfer`]): the donor streams a
//! slot-filtered base snapshot while it keeps training (PR 4's
//! dirty-epoch machinery, one stripe read lock at a time), catches the
//! recipient up through dirty-epoch delta rounds, then seals the moving
//! slots for a short hand-off window — sealed pushes are NACKed with a
//! typed [`Error::StaleRoute`] the client retries against the bumped
//! slot map, so updates are never silently dropped — takes one final
//! delta, and releases the donor (silent purge, no tombstones: the
//! recipient's checkpoint lineage owns the rows now, stamped dirty so its
//! next delta chunk seals them).
//!
//! The authoritative map lives in the [`MetaStore`]
//! (`/reshard/<model>/slotmap`, epoch-guarded publish) and is cached
//! epoch-stamped in every [`crate::sync::Router`] through a shared
//! [`SlotMapCell`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::codec::{Reader, Writer};
use crate::meta::MetaStore;
use crate::server::master::MasterShard;
use crate::util::hash::fxhash64;
use crate::{Error, Result};

/// Default virtual-slot universe. Large enough that one slot is a fine
/// rebalance quantum for any plausible shard count, small enough that a
/// full map is a few KiB in the meta store. Must be ≥ the largest shard
/// count the deployment will ever grow to (`reshard_slots` config knob).
pub const DEFAULT_SLOTS: usize = 1024;

/// Exposition granularity for per-slot heat: [`SlotHeat`] counters are
/// summed into at most this many `slot_bucket` series per direction, so
/// the scrape size stays fixed while the full-resolution counters remain
/// available to the rebalancer in-process.
pub const HEAT_BUCKETS: usize = 64;

/// Owning virtual slot for an id. Uses the *low* bits of `fxhash64(id)`
/// like the pre-slot router did (table striping keys on the high bits, so
/// slot choice stays independent of lock striping).
#[inline]
pub fn slot_of(id: u64, slots: usize) -> u16 {
    (fxhash64(id) % slots.max(1) as u64) as u16
}

// ---------------------------------------------------------------------------
// Slot sets
// ---------------------------------------------------------------------------

/// A set of virtual slots over a fixed universe (bitset; the migration
/// filter and the donor's sealed-slot gate).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotSet {
    universe: usize,
    bits: Vec<u64>,
    count: usize,
}

impl SlotSet {
    /// Empty set over `universe` slots.
    pub fn empty(universe: usize) -> SlotSet {
        let universe = universe.max(1);
        SlotSet { universe, bits: vec![0; (universe + 63) / 64], count: 0 }
    }

    /// Set holding `slots`; errors on a slot outside the universe.
    pub fn from_slots(slots: &[u16], universe: usize) -> Result<SlotSet> {
        let mut set = SlotSet::empty(universe);
        for &s in slots {
            if s as usize >= set.universe {
                return Err(Error::Routing(format!("slot {s} outside universe {universe}")));
            }
            set.insert(s);
        }
        Ok(set)
    }

    /// Every slot of the universe (full-state collection filter).
    pub fn full(universe: usize) -> SlotSet {
        let mut set = SlotSet::empty(universe);
        for s in 0..set.universe {
            set.insert(s as u16);
        }
        set
    }

    /// Add a slot (must be inside the universe).
    pub fn insert(&mut self, slot: u16) {
        debug_assert!((slot as usize) < self.universe);
        let (word, bit) = (slot as usize / 64, slot as usize % 64);
        if self.bits[word] & (1 << bit) == 0 {
            self.bits[word] |= 1 << bit;
            self.count += 1;
        }
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, slot: u16) -> bool {
        let idx = slot as usize;
        idx < self.universe && self.bits[idx / 64] & (1 << (idx % 64)) != 0
    }

    /// Slots in the set.
    pub fn len(&self) -> usize {
        self.count
    }

    /// True when no slot is set.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Universe size.
    pub fn universe(&self) -> usize {
        self.universe
    }

    /// Member slots in ascending order.
    pub fn slots(&self) -> Vec<u16> {
        (0..self.universe).map(|s| s as u16).filter(|&s| self.contains(s)).collect()
    }
}

// ---------------------------------------------------------------------------
// Slot map
// ---------------------------------------------------------------------------

/// Versioned slot→shard assignment. Epoch 0 is the canonical uniform map
/// (`slot % shards`); every rebalance bumps the epoch by one.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotMap {
    /// Routing epoch: strictly increasing across installs.
    pub epoch: u64,
    /// Shard count (max assignment + 1; grows when slots move to a new
    /// shard id).
    pub shards: u32,
    assignment: Vec<u32>,
}

impl SlotMap {
    /// The canonical epoch-0 map: `slot % shards`. With `shards` dividing
    /// the universe this reproduces the historical `hash % shards` routes
    /// exactly; either way the partition-subset optimization's modulo
    /// structure holds (see `sync::router::partitions_for_slave`).
    pub fn uniform(slots: usize, shards: u32) -> SlotMap {
        assert!(shards >= 1, "cluster needs at least one shard");
        let slots = slots.max(shards as usize).min(u16::MAX as usize + 1);
        SlotMap {
            epoch: 0,
            shards,
            assignment: (0..slots).map(|s| s as u32 % shards).collect(),
        }
    }

    /// Universe size.
    pub fn slots(&self) -> usize {
        self.assignment.len()
    }

    /// Owning shard of a slot.
    #[inline]
    pub fn shard_of_slot(&self, slot: u16) -> u32 {
        self.assignment[slot as usize % self.assignment.len()]
    }

    /// Owning slot of an id.
    #[inline]
    pub fn slot_of(&self, id: u64) -> u16 {
        slot_of(id, self.assignment.len())
    }

    /// Owning shard of an id (the two-level route).
    #[inline]
    pub fn shard_of(&self, id: u64) -> u32 {
        self.shard_of_slot(self.slot_of(id))
    }

    /// Slots owned by `shard`, ascending.
    pub fn slots_of(&self, shard: u32) -> Vec<u16> {
        (0..self.assignment.len())
            .map(|s| s as u16)
            .filter(|&s| self.shard_of_slot(s) == shard)
            .collect()
    }

    /// Slots per shard (load view for the rebalance planner).
    pub fn load(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.shards as usize];
        for &a in &self.assignment {
            counts[a as usize] += 1;
        }
        counts
    }

    /// True when this is still the canonical `slot % shards` layout (the
    /// partition-subset read optimization is only sound then).
    pub fn is_uniform(&self) -> bool {
        self.assignment.iter().enumerate().all(|(s, &a)| a == s as u32 % self.shards)
    }

    /// The map after applying `moves` (`(slot, new owner)`): epoch + 1,
    /// all other slots untouched (minimal disruption by construction).
    /// Moving to a shard id ≥ `shards` grows the cluster.
    pub fn rebalanced(&self, moves: &[(u16, u32)]) -> Result<SlotMap> {
        let mut assignment = self.assignment.clone();
        let mut shards = self.shards;
        for &(slot, to) in moves {
            if slot as usize >= assignment.len() {
                return Err(Error::Routing(format!(
                    "slot {slot} outside universe {}",
                    assignment.len()
                )));
            }
            assignment[slot as usize] = to;
            shards = shards.max(to + 1);
        }
        Ok(SlotMap { epoch: self.epoch + 1, shards, assignment })
    }

    /// Serialize (meta-store / RPC payload).
    pub fn encode(&self, w: &mut Writer) {
        w.put_varint(self.epoch);
        w.put_u32(self.shards);
        w.put_varint(self.assignment.len() as u64);
        for &a in &self.assignment {
            w.put_varint(a as u64);
        }
    }

    /// Deserialize; validates shape (assignments inside the shard count).
    pub fn decode(r: &mut Reader) -> Result<SlotMap> {
        let epoch = r.get_varint()?;
        let shards = r.get_u32()?;
        if shards == 0 {
            return Err(Error::Codec("slot map with zero shards".into()));
        }
        let n = r.get_varint()? as usize;
        if n == 0 || n > u16::MAX as usize + 1 {
            return Err(Error::Codec(format!("slot map universe {n} out of range")));
        }
        let mut assignment = Vec::with_capacity(n);
        for _ in 0..n {
            let a = r.get_varint()?;
            if a >= shards as u64 {
                return Err(Error::Codec(format!("slot assigned to shard {a} of {shards}")));
            }
            assignment.push(a as u32);
        }
        Ok(SlotMap { epoch, shards, assignment })
    }

    /// Serialized bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.encode(&mut w);
        w.into_bytes()
    }

    /// Parse serialized bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<SlotMap> {
        SlotMap::decode(&mut Reader::new(bytes))
    }
}

/// Shared, swappable slot-map cell: every [`crate::sync::Router`] clone
/// holds one, so a single [`SlotMapCell::install`] re-routes trainer
/// clients, pushers and shard guards mid-stream.
pub struct SlotMapCell {
    map: RwLock<Arc<SlotMap>>,
    epoch: AtomicU64,
    /// Per-slot access heat shared by every router clone (installs keep
    /// the universe, so the arrays never resize).
    heat: SlotHeat,
}

impl SlotMapCell {
    /// Cell seeded with `map`.
    pub fn new(map: SlotMap) -> SlotMapCell {
        let epoch = map.epoch;
        let heat = SlotHeat::new(map.slots());
        SlotMapCell { map: RwLock::new(Arc::new(map)), epoch: AtomicU64::new(epoch), heat }
    }

    /// Per-slot push/pull heat counters.
    pub fn heat(&self) -> &SlotHeat {
        &self.heat
    }

    /// Current map (cheap Arc clone; snapshot once per batch, not per id).
    pub fn snapshot(&self) -> Arc<SlotMap> {
        self.map.read().unwrap().clone()
    }

    /// Current routing epoch without taking the lock.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Swap in a newer map. Rejected unless the epoch strictly advances
    /// and the universe is unchanged (the slot hash must stay stable).
    pub fn install(&self, map: SlotMap) -> Result<Arc<SlotMap>> {
        let mut cur = self.map.write().unwrap();
        if map.epoch <= cur.epoch {
            return Err(Error::MetaConflict(format!(
                "slot-map epoch {} <= installed {}",
                map.epoch, cur.epoch
            )));
        }
        if map.slots() != cur.slots() {
            return Err(Error::Routing(format!(
                "slot universe changed: {} != {}",
                map.slots(),
                cur.slots()
            )));
        }
        let next = Arc::new(map);
        *cur = next.clone();
        self.epoch.store(next.epoch, Ordering::Release);
        Ok(next)
    }
}

/// Per-virtual-slot access counters: lock-free push/pull heat recorded by
/// the master's request path and exported (bucketed) through the metrics
/// registry. This is the designated input signal for the load-aware
/// rebalancer (ROADMAP item 1): hot slots show up here long before shard
/// row counts skew.
#[derive(Debug)]
pub struct SlotHeat {
    push: Vec<AtomicU64>,
    pull: Vec<AtomicU64>,
}

impl SlotHeat {
    fn new(slots: usize) -> SlotHeat {
        let mut push = Vec::with_capacity(slots);
        push.resize_with(slots, || AtomicU64::new(0));
        let mut pull = Vec::with_capacity(slots);
        pull.resize_with(slots, || AtomicU64::new(0));
        SlotHeat { push, pull }
    }

    /// Slot universe size the counters cover.
    pub fn slots(&self) -> usize {
        self.push.len()
    }

    /// Count one pushed row landing in `slot`.
    #[inline]
    pub fn record_push(&self, slot: u16) {
        if let Some(c) = self.push.get(slot as usize) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one pulled id served from `slot`.
    #[inline]
    pub fn record_pull(&self, slot: u16) {
        if let Some(c) = self.pull.get(slot as usize) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Pushed rows recorded for `slot`.
    pub fn pushes(&self, slot: u16) -> u64 {
        self.push.get(slot as usize).map(|c| c.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Pulled ids recorded for `slot`.
    pub fn pulls(&self, slot: u16) -> u64 {
        self.pull.get(slot as usize).map(|c| c.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Total (push, pull) heat summed over the bucket of slots `{s :
    /// s*buckets/slots == bucket}` — the exposition granularity: a fixed
    /// number of `slot_bucket` series regardless of universe size, while
    /// the full-resolution counters stay available programmatically for
    /// the rebalancer.
    pub fn bucket(&self, bucket: usize, buckets: usize) -> (u64, u64) {
        let slots = self.push.len();
        let buckets = buckets.clamp(1, slots.max(1));
        let mut push = 0u64;
        let mut pull = 0u64;
        for s in 0..slots {
            if s * buckets / slots == bucket {
                push += self.push[s].load(Ordering::Relaxed);
                pull += self.pull[s].load(Ordering::Relaxed);
            }
        }
        (push, pull)
    }
}

// ---------------------------------------------------------------------------
// Rebalance planning
// ---------------------------------------------------------------------------

/// The lowest-indexed `k` slots owned by `donor` (deterministic pick for
/// a targeted donor→recipient move).
pub fn pick_donor_slots(map: &SlotMap, donor: u32, k: usize) -> Result<Vec<u16>> {
    let owned = map.slots_of(donor);
    if owned.len() < k {
        return Err(Error::State(format!(
            "shard {donor} owns {} slots, cannot move {k}",
            owned.len()
        )));
    }
    Ok(owned[..k].to_vec())
}

/// Minimal-disruption rebalance toward `target_shards`: every surviving
/// shard keeps its lowest-indexed slots up to its target share; only the
/// surplus (and everything on shards being retired) moves, assigned to
/// under-target shards in ascending order. Deterministic, and the move
/// count equals the number of slots whose owner actually changes.
pub fn balance_moves(map: &SlotMap, target_shards: u32) -> Vec<(u16, u32)> {
    assert!(target_shards >= 1);
    let slots = map.slots();
    let base = slots / target_shards as usize;
    let rem = slots % target_shards as usize;
    let target_count =
        |shard: u32| base + if (shard as usize) < rem { 1 } else { 0 };
    let mut kept = vec![0usize; target_shards as usize];
    let mut surplus: Vec<u16> = Vec::new();
    for slot in (0..slots).map(|s| s as u16) {
        let owner = map.shard_of_slot(slot);
        if owner < target_shards && kept[owner as usize] < target_count(owner) {
            kept[owner as usize] += 1;
        } else {
            surplus.push(slot);
        }
    }
    let mut moves = Vec::with_capacity(surplus.len());
    let mut next = surplus.into_iter();
    for shard in 0..target_shards {
        while kept[shard as usize] < target_count(shard) {
            let slot = next.next().expect("surplus covers every deficit");
            moves.push((slot, shard));
            kept[shard as usize] += 1;
        }
    }
    debug_assert!(next.next().is_none(), "surplus left unassigned");
    moves
}

// ---------------------------------------------------------------------------
// Meta-store publication
// ---------------------------------------------------------------------------

/// Meta key holding a model's authoritative slot map.
pub fn meta_key(model: &str) -> String {
    format!("/reshard/{model}/slotmap")
}

/// Publish `map` as the authoritative assignment (epoch-guarded: a stale
/// epoch is rejected, so racing coordinators cannot roll the map back).
pub fn publish(meta: &MetaStore, model: &str, map: &SlotMap) -> Result<u64> {
    meta.put_if_newer(&meta_key(model), map.epoch, map.to_bytes())
}

/// Load the published map, if any.
pub fn load(meta: &MetaStore, model: &str) -> Result<Option<SlotMap>> {
    match meta.get_epochal(&meta_key(model)) {
        Some((_, bytes, _)) => Ok(Some(SlotMap::from_bytes(&bytes)?)),
        None => Ok(None),
    }
}

// ---------------------------------------------------------------------------
// Live migration
// ---------------------------------------------------------------------------

/// Catch-up loop knobs.
#[derive(Debug, Clone)]
pub struct MigrationOpts {
    /// Dirty-epoch catch-up rounds before sealing regardless of
    /// convergence.
    pub max_catchup_rounds: usize,
    /// Stop catching up once a round transfers at most this many rows
    /// (the sealed hand-off window then only has to drain a tail this
    /// small).
    pub catchup_threshold: usize,
}

impl Default for MigrationOpts {
    fn default() -> Self {
        MigrationOpts { max_catchup_rounds: 6, catchup_threshold: 64 }
    }
}

/// What a completed migration did.
#[derive(Debug, Clone, Default)]
pub struct MigrationReport {
    pub slots_moved: usize,
    /// Rows streamed by the slot-filtered base pass.
    pub base_rows: usize,
    pub catchup_rounds: usize,
    /// Rows re-streamed by the catch-up rounds (dirty while copying).
    pub catchup_rows: usize,
    /// Rows of the last catch-up round — the convergence signal (how
    /// much the sealed window will have to drain).
    pub last_round_rows: usize,
    /// Rows drained inside the sealed hand-off window.
    pub final_rows: usize,
    /// Rows silently purged from the donor after cutover.
    pub purged_rows: usize,
}

/// One live donor→recipient slot transfer. Drive the stages in order:
///
/// ```text
/// let mut t = SlotTransfer::new(donor, recipient, &slots, universe)?;
/// t.run_catchup(&opts)?;          // base copy + dirty rounds, donor trains on
/// t.seal()?;                      // moving slots NACK pushes from here
/// t.final_sync()?;                // recipient now byte-identical
/// /* caller: flush donor's sync window, drain consumers,
///    install the bumped slot map, publish it */
/// let report = t.finish()?;       // purge donor rows, lift the seal
/// ```
///
/// The coordinator composes this with the streaming pipeline
/// (`LocalCluster::migrate_slots`); the stages are separate so benches and
/// a remote orchestrator (the `MIGRATE_*` RPCs) can drive the same
/// protocol.
pub struct SlotTransfer<'a> {
    donor: &'a MasterShard,
    recipient: &'a MasterShard,
    set: SlotSet,
    since: Option<u64>,
    sealed: bool,
    report: MigrationReport,
}

impl<'a> SlotTransfer<'a> {
    /// Plan a transfer of `slots` (all currently on `donor`).
    pub fn new(
        donor: &'a MasterShard,
        recipient: &'a MasterShard,
        slots: &[u16],
        universe: usize,
    ) -> Result<SlotTransfer<'a>> {
        let set = SlotSet::from_slots(slots, universe)?;
        if set.is_empty() {
            return Err(Error::State("no slots to migrate".into()));
        }
        let report = MigrationReport { slots_moved: set.len(), ..MigrationReport::default() };
        Ok(SlotTransfer { donor, recipient, set, since: None, sealed: false, report })
    }

    /// Slots being moved.
    pub fn slot_set(&self) -> &SlotSet {
        &self.set
    }

    /// One copy round: cut the donor's epoch, stream everything in the
    /// moved slots stamped after the previous cut (everything at all on
    /// the first round), apply at the recipient (rows land dirty there so
    /// its next delta checkpoint seals them). Writers racing the scan
    /// stamp past the cut and are re-captured next round — duplicates,
    /// never losses (the PR 4 dirty-epoch contract).
    fn round(&mut self) -> Result<usize> {
        let cut = self.donor.cut_epoch();
        let chunk = self.donor.encode_slot_chunk(self.since, &self.set);
        self.recipient.apply_slot_chunk(&chunk.bytes)?;
        self.since = Some(cut);
        Ok(chunk.upserts + chunk.deletes)
    }

    /// Base copy + dirty-epoch catch-up rounds. The donor keeps training
    /// throughout: collection holds one stripe *read* lock at a time.
    pub fn run_catchup(&mut self, opts: &MigrationOpts) -> Result<()> {
        self.report.base_rows = self.round()?;
        self.report.last_round_rows = self.report.base_rows;
        for _ in 0..opts.max_catchup_rounds {
            let rows = self.round()?;
            self.report.catchup_rounds += 1;
            self.report.catchup_rows += rows;
            self.report.last_round_rows = rows;
            if rows <= opts.catchup_threshold {
                break;
            }
        }
        Ok(())
    }

    /// Seal the moving slots on the donor. Returns only after every
    /// in-flight push has drained (the seal takes the write side of the
    /// lock pushes hold in read mode across their apply), so everything
    /// applied before this call is visible to [`Self::final_sync`] and
    /// nothing can mutate the slots after it. Errors if another hand-off
    /// already holds the donor's seal (nothing is changed then — do not
    /// abort, that would lift the *other* migration's barrier).
    pub fn seal(&mut self) -> Result<()> {
        self.donor.seal_slots(self.set.clone())?;
        self.sealed = true;
        Ok(())
    }

    /// The final hand-off delta under the seal; afterwards the
    /// recipient's copy of the moved slots is byte-identical to the
    /// donor's (values *and* row metadata).
    pub fn final_sync(&mut self) -> Result<()> {
        debug_assert!(self.sealed, "final_sync before seal");
        self.report.final_rows = self.round()?;
        Ok(())
    }

    /// Release the donor: purge the moved rows silently (no tombstones,
    /// no dirty stamps — the recipient's lineage owns them now) and lift
    /// the seal. Call after the bumped slot map is installed **and** the
    /// recipient's copy is durable (WAL-journaled or checkpointed — the
    /// coordinator does this before releasing): after the purge, nothing
    /// but the recipient holds the rows, so a recipient crash inside an
    /// unjournaled window would otherwise lose them.
    pub fn finish(mut self) -> Result<MigrationReport> {
        self.report.purged_rows = self.donor.purge_slots(&self.set);
        if self.sealed {
            self.donor.unseal_slots();
        }
        Ok(self.report)
    }

    /// Abort a migration that failed mid-hand-off: lift the seal and keep
    /// the donor authoritative (nothing is purged; the recipient's copy
    /// is orphaned but harmless — it is never routed to, and a later
    /// retry's **base pass first purges it** before re-copying, so even
    /// rows the donor deleted in between cannot be resurrected). Safe to
    /// call at any stage before the slot-map cutover.
    pub fn abort(self) {
        if self.sealed {
            self.donor.unseal_slots();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;

    #[test]
    fn slot_heat_counts_and_buckets() {
        let cell = SlotMapCell::new(SlotMap::uniform(128, 4));
        let heat = cell.heat();
        assert_eq!(heat.slots(), 128);
        heat.record_push(5);
        heat.record_push(5);
        heat.record_pull(5);
        heat.record_push(127);
        heat.record_push(9999); // out of universe: ignored, not a panic
        assert_eq!(heat.pushes(5), 2);
        assert_eq!(heat.pulls(5), 1);
        assert_eq!(heat.pushes(9999), 0);
        // 64 buckets over 128 slots: slot 5 -> bucket 2, slot 127 -> 63.
        assert_eq!(heat.bucket(5 * 64 / 128, 64), (2, 1));
        assert_eq!(heat.bucket(63, 64), (1, 0));
        // Every record lands in exactly one bucket.
        let total: u64 = (0..64).map(|b| heat.bucket(b, 64).0).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn slot_set_basics() {
        let mut s = SlotSet::empty(100);
        assert!(s.is_empty());
        s.insert(0);
        s.insert(99);
        s.insert(99); // idempotent
        assert_eq!(s.len(), 2);
        assert!(s.contains(0) && s.contains(99) && !s.contains(50));
        assert_eq!(s.slots(), vec![0, 99]);
        assert!(SlotSet::from_slots(&[100], 100).is_err());
        assert_eq!(SlotSet::full(64).len(), 64);
    }

    #[test]
    fn uniform_map_matches_modulo_and_balances() {
        let m = SlotMap::uniform(1024, 4);
        assert!(m.is_uniform());
        assert_eq!(m.epoch, 0);
        for id in 0..10_000u64 {
            // With shards dividing the universe, two-level == one-level.
            assert_eq!(m.shard_of(id), (fxhash64(id) % 4) as u32);
        }
        assert_eq!(m.load(), vec![256; 4]);
        // Universe never smaller than the shard count.
        assert_eq!(SlotMap::uniform(2, 8).slots(), 8);
    }

    #[test]
    fn rebalanced_moves_only_named_slots_and_bumps_epoch() {
        let m = SlotMap::uniform(64, 4);
        let moved = m.slots_of(3);
        let moves: Vec<(u16, u32)> = moved.iter().map(|&s| (s, 1)).collect();
        let n = m.rebalanced(&moves).unwrap();
        assert_eq!(n.epoch, 1);
        assert!(!n.is_uniform());
        for s in 0..64u16 {
            if moved.contains(&s) {
                assert_eq!(n.shard_of_slot(s), 1);
            } else {
                assert_eq!(n.shard_of_slot(s), m.shard_of_slot(s), "slot {s} disrupted");
            }
        }
        assert!(n.slots_of(3).is_empty());
        // Growing: a move to a new shard id extends the cluster.
        let g = m.rebalanced(&[(0, 7)]).unwrap();
        assert_eq!(g.shards, 8);
        assert!(m.rebalanced(&[(200, 0)]).is_err());
    }

    #[test]
    fn encode_decode_round_trip_and_validation() {
        let m = SlotMap::uniform(128, 5).rebalanced(&[(3, 4), (9, 0)]).unwrap();
        let bytes = m.to_bytes();
        assert_eq!(SlotMap::from_bytes(&bytes).unwrap(), m);
        // Truncation errors cleanly.
        assert!(SlotMap::from_bytes(&bytes[..bytes.len() / 2]).is_err());
        // Out-of-range assignment rejected.
        let mut w = Writer::new();
        w.put_varint(1);
        w.put_u32(2);
        w.put_varint(1);
        w.put_varint(5); // shard 5 of 2
        assert!(SlotMap::from_bytes(&w.into_bytes()).is_err());
    }

    #[test]
    fn cell_installs_monotonically() {
        let cell = SlotMapCell::new(SlotMap::uniform(64, 4));
        assert_eq!(cell.epoch(), 0);
        let next = cell.snapshot().rebalanced(&[(0, 1)]).unwrap();
        cell.install(next.clone()).unwrap();
        assert_eq!(cell.epoch(), 1);
        assert_eq!(cell.snapshot().shard_of_slot(0), 1);
        // Same or older epoch rejected.
        assert!(cell.install(next).is_err());
        assert!(cell.install(SlotMap::uniform(64, 4)).is_err());
        // Universe change rejected.
        let mut other = SlotMap::uniform(32, 4);
        other.epoch = 9;
        assert!(cell.install(other).is_err());
    }

    #[test]
    fn balance_moves_is_minimal_and_even() {
        // Shrink 4 -> 3 over 64 slots: only shard 3's slots move.
        let m = SlotMap::uniform(64, 4);
        let moves = balance_moves(&m, 3);
        let n = m.rebalanced(&moves).unwrap();
        let diff = (0..64u16).filter(|&s| n.shard_of_slot(s) != m.shard_of_slot(s)).count();
        assert_eq!(diff, moves.len(), "a move re-assigned a slot to its current owner");
        let load = n.load();
        assert_eq!(load.iter().take(3).sum::<usize>(), 64);
        for shard in 0..3 {
            assert!((load[shard] as i64 - 64 / 3).abs() <= 1, "load {load:?}");
        }
        // Grow 4 -> 6: every new shard gets its share, survivors only
        // shed surplus.
        let moves = balance_moves(&m, 6);
        let g = m.rebalanced(&moves).unwrap();
        let load = g.load();
        for shard in 0..6 {
            assert!((load[shard] as i64 - 64 / 6).abs() <= 1, "load {load:?}");
        }
        // Determinism.
        assert_eq!(balance_moves(&m, 6), moves);
        // No-op when already balanced.
        assert!(balance_moves(&m, 4).is_empty());
    }

    #[test]
    fn pick_donor_slots_validates_ownership() {
        let m = SlotMap::uniform(64, 4);
        let picked = pick_donor_slots(&m, 2, 4).unwrap();
        assert_eq!(picked.len(), 4);
        assert!(picked.iter().all(|&s| m.shard_of_slot(s) == 2));
        assert!(pick_donor_slots(&m, 2, 17).is_err()); // owns only 16
    }

    #[test]
    fn meta_publish_is_epoch_guarded() {
        let meta = MetaStore::new(Arc::new(ManualClock::new(0)));
        let m0 = SlotMap::uniform(64, 2);
        // Epoch 0 publishes only onto an absent key.
        publish(&meta, "ctr", &m0).unwrap();
        assert!(publish(&meta, "ctr", &m0).is_err(), "same epoch re-published");
        let m1 = m0.rebalanced(&[(5, 1)]).unwrap();
        publish(&meta, "ctr", &m1).unwrap();
        assert!(publish(&meta, "ctr", &m0).is_err(), "rollback accepted");
        let loaded = load(&meta, "ctr").unwrap().unwrap();
        assert_eq!(loaded, m1);
        assert_eq!(load(&meta, "other").unwrap(), None);
    }
}
