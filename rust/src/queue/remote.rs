//! Remote queue access: the broker-side RPC service + the client-side
//! [`SyncLog`] implementation used by distributed masters/slaves.

use std::sync::Arc;
use std::time::Duration;

use super::log::SyncLog;
use super::{Record, Topic};
use crate::codec::{Reader, Writer};
use crate::net::{Channel, Service};
use crate::{Error, Result};

/// RPC method ids (broker service).
pub mod methods {
    pub const APPEND: u16 = 20;
    pub const FETCH: u16 = 21;
    pub const LATEST: u16 = 22;
    pub const EARLIEST: u16 = 23;
    pub const PARTITIONS: u16 = 24;
}

/// Broker-side service exposing one topic.
pub struct QueueService {
    pub topic: Arc<Topic>,
}

impl Service for QueueService {
    fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        let mut r = Reader::new(payload);
        let mut w = Writer::new();
        match method {
            methods::APPEND => {
                let partition = r.get_u32()?;
                let ts = r.get_u64()?;
                let data = r.get_bytes()?.to_vec();
                let off = SyncLog::append(&*self.topic, partition, ts, data)?;
                w.put_u64(off);
            }
            methods::FETCH => {
                let partition = r.get_u32()?;
                let offset = r.get_u64()?;
                let max = r.get_u32()? as usize;
                let timeout = Duration::from_millis(r.get_u32()? as u64);
                let records = SyncLog::fetch(&*self.topic, partition, offset, max, timeout)?;
                w.put_varint(records.len() as u64);
                for rec in records {
                    w.put_u64(rec.offset);
                    w.put_u64(rec.ts_ms);
                    w.put_bytes(&rec.payload);
                }
            }
            methods::LATEST => {
                let partition = r.get_u32()?;
                w.put_u64(self.topic.latest_offset(partition)?);
            }
            methods::EARLIEST => {
                let partition = r.get_u32()?;
                w.put_u64(SyncLog::earliest_offset(&*self.topic, partition)?);
            }
            methods::PARTITIONS => {
                w.put_u32(Topic::partition_count(&self.topic) as u32);
            }
            m => return Err(Error::Rpc(format!("queue: unknown method {m}"))),
        }
        Ok(w.into_bytes())
    }
}

/// Client-side [`SyncLog`] over a [`Channel`] to the broker.
pub struct RemoteLog {
    channel: Channel,
    partitions: usize,
}

impl RemoteLog {
    /// Connect and learn the partition count.
    pub fn connect(channel: Channel) -> Result<RemoteLog> {
        let resp = channel.call(methods::PARTITIONS, &[])?;
        let partitions = Reader::new(&resp).get_u32()? as usize;
        Ok(RemoteLog { channel, partitions })
    }
}

impl SyncLog for RemoteLog {
    fn partition_count(&self) -> usize {
        self.partitions
    }

    fn append(&self, partition: u32, ts_ms: u64, payload: Vec<u8>) -> Result<u64> {
        let mut w = Writer::with_capacity(payload.len() + 24);
        w.put_u32(partition);
        w.put_u64(ts_ms);
        w.put_bytes(&payload);
        let resp = self.channel.call(methods::APPEND, &w.into_bytes())?;
        Reader::new(&resp).get_u64()
    }

    fn fetch(
        &self,
        partition: u32,
        offset: u64,
        max: usize,
        timeout: Duration,
    ) -> Result<Vec<Record>> {
        let mut w = Writer::new();
        w.put_u32(partition);
        w.put_u64(offset);
        w.put_u32(max as u32);
        w.put_u32(timeout.as_millis() as u32);
        let resp = self.channel.call(methods::FETCH, &w.into_bytes());
        let resp = match resp {
            Ok(r) => r,
            // Offset errors travel as Rpc strings; reconstruct the type the
            // scatter relies on for its retention-gap recovery.
            Err(Error::Rpc(msg)) if msg.contains("offset out of range") => {
                return Err(Error::OffsetOutOfRange(msg));
            }
            Err(e) => return Err(e),
        };
        let mut r = Reader::new(&resp);
        let n = r.get_varint()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let offset = r.get_u64()?;
            let ts_ms = r.get_u64()?;
            let payload = Arc::new(r.get_bytes()?.to_vec());
            out.push(Record { offset, ts_ms, payload });
        }
        Ok(out)
    }

    fn latest_offset(&self, partition: u32) -> Result<u64> {
        let mut w = Writer::new();
        w.put_u32(partition);
        let resp = self.channel.call(methods::LATEST, &w.into_bytes())?;
        Reader::new(&resp).get_u64()
    }

    fn earliest_offset(&self, partition: u32) -> Result<u64> {
        let mut w = Writer::new();
        w.put_u32(partition);
        let resp = self.channel.call(methods::EARLIEST, &w.into_bytes())?;
        Reader::new(&resp).get_u64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::Queue;

    fn remote_pair() -> (Arc<Topic>, RemoteLog) {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("t", 3).unwrap();
        let svc = Arc::new(QueueService { topic: topic.clone() });
        let remote = RemoteLog::connect(Channel::local(svc)).unwrap();
        (topic, remote)
    }

    #[test]
    fn remote_mirrors_local_log() {
        let (topic, remote) = remote_pair();
        assert_eq!(remote.partition_count(), 3);
        let off = remote.append(1, 42, b"hello".to_vec()).unwrap();
        assert_eq!(off, 0);
        assert_eq!(topic.partition(1).unwrap().latest_offset(), 1);
        let recs = remote.fetch(1, 0, 10, Duration::ZERO).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(*recs[0].payload, b"hello".to_vec());
        assert_eq!(recs[0].ts_ms, 42);
        assert_eq!(remote.latest_offset(1).unwrap(), 1);
        assert_eq!(remote.earliest_offset(1).unwrap(), 0);
    }

    #[test]
    fn remote_offset_errors_preserve_type() {
        let (_topic, remote) = remote_pair();
        let err = remote.fetch(0, 99, 1, Duration::ZERO).unwrap_err();
        assert!(matches!(err, Error::OffsetOutOfRange(_)), "{err:?}");
    }

    #[test]
    fn remote_over_tcp() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("t", 1).unwrap();
        let server = crate::net::RpcServer::serve(
            "127.0.0.1:0",
            Arc::new(QueueService { topic }),
        )
        .unwrap();
        let ch = Channel::remote(&server.addr().to_string(), Duration::from_secs(5));
        let remote = RemoteLog::connect(ch).unwrap();
        remote.append(0, 1, vec![7; 100]).unwrap();
        let recs = remote.fetch(0, 0, 10, Duration::ZERO).unwrap();
        assert_eq!(recs[0].payload.len(), 100);
    }
}
