//! [`SyncLog`]: the queue surface the sync pipeline consumes.
//!
//! Pusher and scatter are written against this trait so the same pipeline
//! runs embedded (direct [`Topic`] access, `LocalCluster`) or distributed
//! (RPC to the broker process, [`super::remote::RemoteLog`]).

use std::time::Duration;

use super::{Record, Topic};
use crate::Result;

/// Partitioned, offset-addressed log.
pub trait SyncLog: Send + Sync {
    /// Number of partitions.
    fn partition_count(&self) -> usize;
    /// Append a payload; returns its offset.
    fn append(&self, partition: u32, ts_ms: u64, payload: Vec<u8>) -> Result<u64>;
    /// Fetch up to `max` records from `offset` (blocking up to `timeout`).
    fn fetch(&self, partition: u32, offset: u64, max: usize, timeout: Duration)
        -> Result<Vec<Record>>;
    /// Log-end offset.
    fn latest_offset(&self, partition: u32) -> Result<u64>;
    /// Earliest retained offset.
    fn earliest_offset(&self, partition: u32) -> Result<u64>;
}

impl SyncLog for Topic {
    fn partition_count(&self) -> usize {
        Topic::partition_count(self)
    }

    fn append(&self, partition: u32, ts_ms: u64, payload: Vec<u8>) -> Result<u64> {
        Ok(self.partition(partition as usize)?.append(ts_ms, payload))
    }

    fn fetch(
        &self,
        partition: u32,
        offset: u64,
        max: usize,
        timeout: Duration,
    ) -> Result<Vec<Record>> {
        self.partition(partition as usize)?.fetch(offset, max, timeout)
    }

    fn latest_offset(&self, partition: u32) -> Result<u64> {
        Ok(self.partition(partition as usize)?.latest_offset())
    }

    fn earliest_offset(&self, partition: u32) -> Result<u64> {
        Ok(self.partition(partition as usize)?.earliest_offset())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::Queue;

    #[test]
    fn topic_implements_synclog() {
        let q = Queue::new(1 << 20);
        let topic = q.create_topic("t", 2).unwrap();
        let log: &dyn SyncLog = &*topic;
        assert_eq!(log.partition_count(), 2);
        assert_eq!(log.append(1, 5, b"x".to_vec()).unwrap(), 0);
        assert_eq!(log.latest_offset(1).unwrap(), 1);
        assert_eq!(log.earliest_offset(1).unwrap(), 0);
        let recs = log.fetch(1, 0, 10, Duration::ZERO).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(*recs[0].payload, b"x".to_vec());
        assert!(log.append(9, 0, vec![]).is_err());
    }
}
