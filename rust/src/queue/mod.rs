//! Partitioned durable-log queue (the Kafka substitute, §4.1).
//!
//! The streaming synchronization pipeline decouples master and slave
//! through "distributed external queues" with partition-level routing:
//! the pusher maps master shard ids onto partitions, slaves subscribe to
//! exactly the partitions their shards need (§4.1.3–4.1.4). This module
//! provides that surface: topics → partitions → offset-addressed records,
//! blocking fetch, consumer-group offset commits, bounded retention, and
//! seek/rewind (the domino downgrade replays from an offset stored in the
//! checkpoint, §4.3.2).
//!
//! Substitution note (DESIGN.md §2): records are kept in memory with
//! bounded retention instead of on-disk segments — every *behaviour* the
//! paper's mechanisms rely on (offsets, replay, lag, partition routing)
//! is preserved; broker-crash durability is out of scope of the paper's
//! claims (its Kafka is an external managed service).

pub mod log;
pub mod remote;
pub mod wal;

pub use log::SyncLog;
pub use remote::{QueueService, RemoteLog};
pub use wal::{default_wal_sync_every, WalLog};

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Duration;

use crate::{Error, Result};

/// One queued record.
#[derive(Debug, Clone)]
pub struct Record {
    pub offset: u64,
    pub ts_ms: u64,
    pub payload: Arc<Vec<u8>>,
}

#[derive(Debug, Default)]
struct PartitionState {
    /// Offset of `records[0]` (earlier records trimmed by retention).
    base_offset: u64,
    records: VecDeque<Record>,
    bytes: usize,
}

/// A single partition: an offset-addressed in-memory log.
pub struct Partition {
    state: Mutex<PartitionState>,
    data_ready: Condvar,
    /// Retention: keep at most this many bytes (oldest trimmed first).
    max_bytes: usize,
}

impl Partition {
    fn new(max_bytes: usize) -> Partition {
        Partition {
            state: Mutex::new(PartitionState::default()),
            data_ready: Condvar::new(),
            max_bytes,
        }
    }

    /// Append a record; returns its offset.
    pub fn append(&self, ts_ms: u64, payload: Vec<u8>) -> u64 {
        let mut s = self.state.lock().unwrap();
        let offset = s.base_offset + s.records.len() as u64;
        s.bytes += payload.len();
        s.records.push_back(Record { offset, ts_ms, payload: Arc::new(payload) });
        // Retention by bytes.
        while s.bytes > self.max_bytes && s.records.len() > 1 {
            let dropped = s.records.pop_front().unwrap();
            s.bytes -= dropped.payload.len();
            s.base_offset += 1;
        }
        drop(s);
        self.data_ready.notify_all();
        offset
    }

    /// Fetch up to `max` records starting at `offset`. Blocks up to
    /// `timeout` waiting for data; returns an empty vec on timeout.
    /// Errors with [`Error::OffsetOutOfRange`] if `offset` was trimmed.
    pub fn fetch(&self, offset: u64, max: usize, timeout: Duration) -> Result<Vec<Record>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut s = self.state.lock().unwrap();
        loop {
            if offset < s.base_offset {
                return Err(Error::OffsetOutOfRange(format!(
                    "offset {offset} < earliest {}",
                    s.base_offset
                )));
            }
            let end = s.base_offset + s.records.len() as u64;
            if offset < end {
                let start = (offset - s.base_offset) as usize;
                let take = (s.records.len() - start).min(max);
                return Ok(s.records.iter().skip(start).take(take).cloned().collect());
            }
            if offset > end {
                return Err(Error::OffsetOutOfRange(format!(
                    "offset {offset} > latest {end}"
                )));
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(Vec::new());
            }
            let (guard, _t) = self.data_ready.wait_timeout(s, deadline - now).unwrap();
            s = guard;
        }
    }

    /// Next offset that will be assigned (== log end).
    pub fn latest_offset(&self) -> u64 {
        let s = self.state.lock().unwrap();
        s.base_offset + s.records.len() as u64
    }

    /// Earliest retained offset.
    pub fn earliest_offset(&self) -> u64 {
        self.state.lock().unwrap().base_offset
    }

    /// Records currently retained.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().records.len()
    }

    /// True when no records are retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop everything before `offset` (checkpoint-aligned trim).
    pub fn trim_until(&self, offset: u64) {
        let mut s = self.state.lock().unwrap();
        while s.base_offset < offset {
            match s.records.pop_front() {
                Some(r) => {
                    s.bytes -= r.payload.len();
                    s.base_offset += 1;
                }
                None => {
                    s.base_offset = offset;
                    break;
                }
            }
        }
    }
}

/// A named topic: fixed partition count at creation (like Kafka).
pub struct Topic {
    pub name: String,
    partitions: Vec<Arc<Partition>>,
}

impl Topic {
    /// Partition handle.
    pub fn partition(&self, idx: usize) -> Result<&Arc<Partition>> {
        self.partitions
            .get(idx)
            .ok_or_else(|| Error::Routing(format!("partition {idx} of {}", self.partitions.len())))
    }

    /// Number of partitions.
    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    /// Total log-end offsets summed over partitions (metrics).
    pub fn total_records(&self) -> u64 {
        self.partitions.iter().map(|p| p.latest_offset()).sum()
    }
}

/// The broker: topics + consumer-group offset storage.
pub struct Queue {
    topics: RwLock<HashMap<String, Arc<Topic>>>,
    /// (group, topic, partition) -> committed offset.
    commits: Mutex<BTreeMap<(String, String, u32), u64>>,
    default_retention: usize,
}

impl Default for Queue {
    fn default() -> Self {
        Self::new(256 << 20)
    }
}

impl Queue {
    /// New broker; `default_retention` caps each partition's bytes.
    pub fn new(default_retention: usize) -> Queue {
        Queue {
            topics: RwLock::new(HashMap::new()),
            commits: Mutex::new(BTreeMap::new()),
            default_retention,
        }
    }

    /// Create (or fetch, if existing with same partition count) a topic.
    pub fn create_topic(&self, name: &str, partitions: usize) -> Result<Arc<Topic>> {
        let mut topics = self.topics.write().unwrap();
        if let Some(t) = topics.get(name) {
            if t.partition_count() != partitions {
                return Err(Error::State(format!(
                    "topic {name} exists with {} partitions, wanted {partitions}",
                    t.partition_count()
                )));
            }
            return Ok(t.clone());
        }
        let topic = Arc::new(Topic {
            name: name.to_string(),
            partitions: (0..partitions)
                .map(|_| Arc::new(Partition::new(self.default_retention)))
                .collect(),
        });
        topics.insert(name.to_string(), topic.clone());
        Ok(topic)
    }

    /// Topic handle.
    pub fn topic(&self, name: &str) -> Result<Arc<Topic>> {
        self.topics
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::NotFound(format!("topic {name}")))
    }

    /// Commit a consumer-group offset.
    pub fn commit(&self, group: &str, topic: &str, partition: u32, offset: u64) {
        self.commits
            .lock()
            .unwrap()
            .insert((group.to_string(), topic.to_string(), partition), offset);
    }

    /// Last committed offset for a group/partition.
    pub fn committed(&self, group: &str, topic: &str, partition: u32) -> Option<u64> {
        self.commits
            .lock()
            .unwrap()
            .get(&(group.to_string(), topic.to_string(), partition))
            .copied()
    }

    /// Consumer lag for a group across all partitions of a topic.
    pub fn lag(&self, group: &str, topic: &str) -> Result<u64> {
        let t = self.topic(topic)?;
        let mut total = 0;
        for (i, p) in t.partitions.iter().enumerate() {
            let committed = self.committed(group, topic, i as u32).unwrap_or(0);
            total += p.latest_offset().saturating_sub(committed);
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q() -> Queue {
        Queue::new(1 << 20)
    }

    #[test]
    fn append_fetch_round_trip() {
        let q = q();
        let t = q.create_topic("sync", 2).unwrap();
        let p = t.partition(0).unwrap();
        assert_eq!(p.append(1, b"a".to_vec()), 0);
        assert_eq!(p.append(2, b"b".to_vec()), 1);
        let recs = p.fetch(0, 10, Duration::ZERO).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(*recs[0].payload, b"a".to_vec());
        assert_eq!(recs[1].offset, 1);
        // Partial fetch from the middle.
        let recs = p.fetch(1, 10, Duration::ZERO).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(*recs[0].payload, b"b".to_vec());
    }

    #[test]
    fn fetch_at_end_times_out_empty() {
        let q = q();
        let t = q.create_topic("s", 1).unwrap();
        let p = t.partition(0).unwrap();
        p.append(0, b"x".to_vec());
        let recs = p.fetch(1, 10, Duration::from_millis(20)).unwrap();
        assert!(recs.is_empty());
    }

    #[test]
    fn fetch_beyond_end_is_error() {
        let q = q();
        let t = q.create_topic("s", 1).unwrap();
        let p = t.partition(0).unwrap();
        assert!(p.fetch(5, 1, Duration::ZERO).is_err());
    }

    #[test]
    fn blocking_fetch_wakes_on_append() {
        let q = Arc::new(q());
        let t = q.create_topic("s", 1).unwrap();
        let p = t.partition(0).unwrap().clone();
        let p2 = p.clone();
        let h = std::thread::spawn(move || p2.fetch(0, 10, Duration::from_secs(5)).unwrap());
        std::thread::sleep(Duration::from_millis(30));
        p.append(0, b"wake".to_vec());
        let recs = h.join().unwrap();
        assert_eq!(recs.len(), 1);
    }

    #[test]
    fn retention_trims_oldest_and_rejects_stale_reads() {
        let q = Queue::new(64); // tiny retention
        let t = q.create_topic("s", 1).unwrap();
        let p = t.partition(0).unwrap();
        for i in 0..100u64 {
            p.append(i, vec![0u8; 16]);
        }
        assert!(p.earliest_offset() > 0);
        assert!(p.len() * 16 <= 64 + 16);
        let err = p.fetch(0, 1, Duration::ZERO).unwrap_err();
        assert!(matches!(err, Error::OffsetOutOfRange(_)), "{err}");
        // Latest data still readable.
        let latest = p.latest_offset();
        assert!(!p.fetch(latest - 1, 1, Duration::ZERO).unwrap().is_empty());
    }

    #[test]
    fn trim_until_respects_offsets() {
        let q = q();
        let t = q.create_topic("s", 1).unwrap();
        let p = t.partition(0).unwrap();
        for i in 0..10u64 {
            p.append(i, b"r".to_vec());
        }
        p.trim_until(7);
        assert_eq!(p.earliest_offset(), 7);
        assert_eq!(p.len(), 3);
        assert_eq!(p.fetch(7, 10, Duration::ZERO).unwrap().len(), 3);
    }

    #[test]
    fn topic_misuse_errors() {
        let q = q();
        q.create_topic("a", 2).unwrap();
        assert!(q.create_topic("a", 3).is_err()); // partition mismatch
        assert!(q.create_topic("a", 2).is_ok()); // idempotent
        assert!(q.topic("missing").is_err());
        let t = q.topic("a").unwrap();
        assert!(t.partition(5).is_err());
    }

    #[test]
    fn consumer_group_commits_and_lag() {
        let q = q();
        let t = q.create_topic("sync", 2).unwrap();
        for i in 0..10u64 {
            t.partition(0).unwrap().append(i, b"x".to_vec());
        }
        for i in 0..4u64 {
            t.partition(1).unwrap().append(i, b"x".to_vec());
        }
        assert_eq!(q.lag("slave-a", "sync").unwrap(), 14);
        q.commit("slave-a", "sync", 0, 10);
        q.commit("slave-a", "sync", 1, 1);
        assert_eq!(q.committed("slave-a", "sync", 0), Some(10));
        assert_eq!(q.lag("slave-a", "sync").unwrap(), 3);
        // Independent group.
        assert_eq!(q.lag("slave-b", "sync").unwrap(), 14);
    }

    #[test]
    fn concurrent_producers_unique_offsets() {
        let q = Arc::new(q());
        let t = q.create_topic("s", 1).unwrap();
        let mut handles = Vec::new();
        for p in 0..4u8 {
            let part = t.partition(0).unwrap().clone();
            handles.push(std::thread::spawn(move || {
                (0..500).map(|i| part.append(0, vec![p, i as u8])).collect::<Vec<u64>>()
            }));
        }
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 2_000, "duplicate offsets assigned");
        assert_eq!(t.partition(0).unwrap().latest_offset(), 2_000);
    }

    #[test]
    fn replay_is_deterministic() {
        // The domino-downgrade path: read [offset, end) twice, same data.
        let q = q();
        let t = q.create_topic("s", 1).unwrap();
        let p = t.partition(0).unwrap();
        for i in 0..20u64 {
            p.append(i, i.to_le_bytes().to_vec());
        }
        let a = p.fetch(5, 100, Duration::ZERO).unwrap();
        let b = p.fetch(5, 100, Duration::ZERO).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.offset, y.offset);
            assert_eq!(x.payload, y.payload);
        }
    }
}
