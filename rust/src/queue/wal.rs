//! [`WalLog`]: a file-backed, partitioned write-ahead log implementing
//! the same [`SyncLog`] surface the streaming pipeline already consumes.
//!
//! The in-memory [`super::Topic`] substitutes for the external managed
//! queue — fine for the paper's streaming-sync claims, useless as a
//! durability substrate: it dies with the process. The incremental
//! checkpoint engine (`storage::incremental`) needs a log that survives a
//! crash so the gap between the last sealed delta chunk and the crash
//! point can be replayed. [`WalLog`] is that log: one append-only file
//! per partition, every record CRC-framed (`codec::frame`), offsets
//! identical in semantics to a [`super::Partition`]'s.
//!
//! Crash tolerance: an append interrupted mid-write leaves a partial or
//! CRC-broken final frame. On open the tail is truncated at the first
//! unreadable frame and the log continues from there — exactly the
//! bounded-loss contract the checkpoint chain closes (the torn record's
//! rows are still dirty in the next delta, or already sealed in a chunk).
//! A corrupt *header* is not recoverable and errors loudly instead of
//! silently presenting an empty log.
//!
//! Retention: [`WalLog::trim_until`] drops everything below an offset
//! (called after each checkpoint seal records its WAL offsets), so the
//! file only ever holds the tail since the last sealed chunk.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::codec::{frame, unframe};
use crate::queue::log::SyncLog;
use crate::queue::Record;
use crate::util::{mono_ns, Histogram};
use crate::{Error, Result};

const MAGIC: &[u8; 4] = b"WAL1";

/// Default fsync cadence (`WEIPS_WAL_SYNC_EVERY`; the cluster config's
/// `wal_sync_every` knob wins where a config is present). 0 = flush to
/// the OS only — append latency stays minimal and the torn-tail
/// truncation on open still bounds what a *process* crash can lose; a
/// power loss can additionally lose the unsynced OS cache.
pub fn default_wal_sync_every() -> u64 {
    use std::sync::OnceLock;
    static N: OnceLock<u64> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("WEIPS_WAL_SYNC_EVERY").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
    })
}

struct WalPartition {
    path: PathBuf,
    /// Append handle (the file is re-read wholesale only at open/trim).
    file: File,
    /// Offset of `records[0]` (records below it were trimmed).
    base_offset: u64,
    records: Vec<Record>,
    /// Appends since open/trim (drives the fsync cadence).
    appends: u64,
}

impl WalPartition {
    fn header_frame(base_offset: u64) -> Vec<u8> {
        let mut header = Vec::with_capacity(12);
        header.extend_from_slice(MAGIC);
        header.extend_from_slice(&base_offset.to_le_bytes());
        frame(&header)
    }

    fn record_frame(ts_ms: u64, payload: &[u8]) -> Vec<u8> {
        let mut body = Vec::with_capacity(payload.len() + 8);
        body.extend_from_slice(&ts_ms.to_le_bytes());
        body.extend_from_slice(payload);
        frame(&body)
    }

    fn open(path: PathBuf) -> Result<WalPartition> {
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e.into()),
        };
        let mut base_offset = 0u64;
        let mut records = Vec::new();
        let mut consumed = 0usize;
        if !bytes.is_empty() {
            // The header must parse; a log whose first frame is broken is
            // not a torn tail but a corrupt file — surface it.
            match unframe(&bytes) {
                Ok(Some((payload, used))) if payload.len() == 12 && &payload[..4] == MAGIC => {
                    base_offset = u64::from_le_bytes(payload[4..12].try_into().unwrap());
                    consumed = used;
                }
                _ => {
                    return Err(Error::Checkpoint(format!(
                        "{}: corrupt WAL header",
                        path.display()
                    )))
                }
            }
            // Records until the torn tail: a partial or CRC-broken frame
            // (crash mid-append) truncates the log there.
            while consumed < bytes.len() {
                match unframe(&bytes[consumed..]) {
                    Ok(Some((payload, used))) if payload.len() >= 8 => {
                        let ts_ms = u64::from_le_bytes(payload[..8].try_into().unwrap());
                        records.push(Record {
                            offset: base_offset + records.len() as u64,
                            ts_ms,
                            payload: Arc::new(payload[8..].to_vec()),
                        });
                        consumed += used;
                    }
                    _ => break,
                }
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        if bytes.is_empty() {
            let mut file = file;
            file.write_all(&Self::header_frame(0))?;
            file.flush()?;
            return Ok(WalPartition { path, file, base_offset: 0, records, appends: 0 });
        }
        if consumed < bytes.len() {
            // Drop the torn tail so the next append starts on a frame
            // boundary.
            file.set_len(consumed as u64)?;
        }
        Ok(WalPartition { path, file, base_offset, records, appends: 0 })
    }

    /// Append one record. `sync_every > 0` fsyncs the file on every
    /// n-th append — the power-loss durability knob; 0 keeps the
    /// flush-only fast path. Returns the record offset and, when this
    /// append fsynced, the fsync wall time in ns (metrics input).
    fn append(
        &mut self,
        ts_ms: u64,
        payload: Vec<u8>,
        sync_every: u64,
    ) -> Result<(u64, Option<u64>)> {
        self.file.write_all(&Self::record_frame(ts_ms, &payload))?;
        self.file.flush()?;
        self.appends += 1;
        let mut fsync_ns = None;
        if sync_every > 0 && self.appends % sync_every == 0 {
            let start = mono_ns();
            self.file.sync_data()?;
            fsync_ns = Some(mono_ns().saturating_sub(start));
        }
        let offset = self.base_offset + self.records.len() as u64;
        self.records.push(Record { offset, ts_ms, payload: Arc::new(payload) });
        Ok((offset, fsync_ns))
    }

    fn fetch(&self, offset: u64, max: usize) -> Result<Vec<Record>> {
        if offset < self.base_offset {
            return Err(Error::OffsetOutOfRange(format!(
                "wal offset {offset} < earliest {}",
                self.base_offset
            )));
        }
        let end = self.base_offset + self.records.len() as u64;
        if offset > end {
            return Err(Error::OffsetOutOfRange(format!("wal offset {offset} > latest {end}")));
        }
        let start = (offset - self.base_offset) as usize;
        let take = (self.records.len() - start).min(max);
        Ok(self.records[start..start + take].to_vec())
    }

    fn trim_until(&mut self, offset: u64) -> Result<()> {
        let end = self.base_offset + self.records.len() as u64;
        let new_base = offset.clamp(self.base_offset, end);
        if new_base == self.base_offset {
            return Ok(());
        }
        let drop_n = (new_base - self.base_offset) as usize;
        self.records.drain(..drop_n);
        self.base_offset = new_base;
        // Rewrite the file atomically: header with the new base, then the
        // surviving tail.
        let tmp = self.path.with_extension("tmp");
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&Self::header_frame(new_base))?;
            for r in &self.records {
                f.write_all(&Self::record_frame(r.ts_ms, &r.payload))?;
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, &self.path)?;
        self.file = OpenOptions::new().create(true).append(true).open(&self.path)?;
        Ok(())
    }
}

/// Scrape-facing WAL accounting: the metrics samplers hold a `Weak` on
/// this, so the series die with the log.
#[derive(Default)]
struct WalStats {
    appends: AtomicU64,
    fsyncs: AtomicU64,
    /// Appends since the last fsync — the fsync lag a power loss could
    /// lose. Grows without bound in flush-only mode (by design).
    unsynced: AtomicU64,
}

/// Durable partitioned WAL (one file per partition under `dir`).
pub struct WalLog {
    partitions: Vec<Mutex<WalPartition>>,
    /// fsync cadence: sync every n-th append (0 = flush-only).
    sync_every: u64,
    stats: Arc<WalStats>,
    /// Registry histogram (shared across WAL instances with the same
    /// labels); records fsync wall time in ns.
    fsync_hist: Arc<Histogram>,
}

impl WalLog {
    /// Open (or create) a WAL with `partitions` files under `dir`,
    /// recovering each partition's readable prefix and truncating torn
    /// tails. Uses the default fsync cadence
    /// ([`default_wal_sync_every`]).
    pub fn open(dir: impl Into<PathBuf>, partitions: usize) -> Result<WalLog> {
        Self::open_with(dir, partitions, default_wal_sync_every())
    }

    /// [`Self::open`] with an explicit fsync cadence (`wal_sync_every`
    /// knob): fsync the partition file after every n-th append; 0 =
    /// flush-only (append latency over power-loss durability).
    pub fn open_with(
        dir: impl Into<PathBuf>,
        partitions: usize,
        sync_every: u64,
    ) -> Result<WalLog> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let mut parts = Vec::with_capacity(partitions.max(1));
        for p in 0..partitions.max(1) {
            parts.push(Mutex::new(WalPartition::open(dir.join(format!("p{p}.wal")))?));
        }
        let stats = Arc::new(WalStats::default());
        // The WAL journals master-shard update windows, so its durability
        // series live under the master role. Re-opening a WAL (recovery,
        // tests) replaces the samplers with the live instance's.
        let labels = [("role", "master".to_string())];
        let counters: [(&'static str, fn(&WalStats) -> &AtomicU64); 3] = [
            ("weips_wal_appends_total", |s| &s.appends),
            ("weips_wal_fsyncs_total", |s| &s.fsyncs),
            ("weips_wal_unsynced_appends", |s| &s.unsynced),
        ];
        for (name, get) in counters {
            let weak = Arc::downgrade(&stats);
            crate::metrics::register_fn(
                name,
                &labels,
                Box::new(move || {
                    weak.upgrade().map(|s| get(&s).load(Ordering::Relaxed) as f64)
                }),
            );
        }
        let fsync_hist = crate::metrics::histogram("weips_wal_fsync_duration_seconds", &labels);
        // Readiness probe: /healthz reports `degraded` when unsynced
        // appends exceed the configured bound. Only meaningful with a
        // periodic fsync cadence — in flush-only mode (`sync_every == 0`)
        // the counter grows without bound by design.
        if sync_every > 0 {
            let weak = Arc::downgrade(&stats);
            crate::metrics::register_health(
                "wal_unsynced_appends",
                format!("sync_every={sync_every}"),
                Box::new(move || {
                    weak.upgrade().map(|s| s.unsynced.load(Ordering::Relaxed) as f64)
                }),
            );
        }
        Ok(WalLog { partitions: parts, sync_every, stats, fsync_hist })
    }

    /// (appends, fsyncs, appends-since-last-fsync) — the counters behind
    /// the `weips_wal_*` series, readable without a scrape.
    pub fn sync_counters(&self) -> (u64, u64, u64) {
        (
            self.stats.appends.load(Ordering::Relaxed),
            self.stats.fsyncs.load(Ordering::Relaxed),
            self.stats.unsynced.load(Ordering::Relaxed),
        )
    }

    fn partition(&self, idx: u32) -> Result<&Mutex<WalPartition>> {
        self.partitions.get(idx as usize).ok_or_else(|| {
            Error::Routing(format!("wal partition {idx} of {}", self.partitions.len()))
        })
    }

    /// Drop everything below `offset` in one partition (checkpoint-seal
    /// trim: the sealed chunks cover it).
    pub fn trim_until(&self, partition: u32, offset: u64) -> Result<()> {
        self.partition(partition)?.lock().unwrap().trim_until(offset)
    }

    /// Log-end offset per partition — recorded into the checkpoint
    /// manifest at seal time so recovery knows where the replay tail
    /// starts.
    pub fn latest_offsets(&self) -> Vec<u64> {
        self.partitions
            .iter()
            .map(|p| {
                let p = p.lock().unwrap();
                p.base_offset + p.records.len() as u64
            })
            .collect()
    }
}

impl SyncLog for WalLog {
    fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    fn append(&self, partition: u32, ts_ms: u64, payload: Vec<u8>) -> Result<u64> {
        let (offset, fsync_ns) =
            self.partition(partition)?.lock().unwrap().append(ts_ms, payload, self.sync_every)?;
        self.stats.appends.fetch_add(1, Ordering::Relaxed);
        match fsync_ns {
            Some(ns) => {
                self.stats.fsyncs.fetch_add(1, Ordering::Relaxed);
                self.stats.unsynced.store(0, Ordering::Relaxed);
                self.fsync_hist.record(ns);
            }
            None => {
                self.stats.unsynced.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(offset)
    }

    fn fetch(
        &self,
        partition: u32,
        offset: u64,
        max: usize,
        _timeout: Duration, // never blocks: a WAL has no live producer to wait on
    ) -> Result<Vec<Record>> {
        self.partition(partition)?.lock().unwrap().fetch(offset, max)
    }

    fn latest_offset(&self, partition: u32) -> Result<u64> {
        let p = self.partition(partition)?.lock().unwrap();
        Ok(p.base_offset + p.records.len() as u64)
    }

    fn earliest_offset(&self, partition: u32) -> Result<u64> {
        Ok(self.partition(partition)?.lock().unwrap().base_offset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "weips-wal-{}-{:x}",
            std::process::id(),
            crate::util::mono_ns()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fsync_cadence_counters() {
        let dir = tmp_dir();
        let wal = WalLog::open_with(&dir, 1, 2).unwrap();
        for i in 0..5u64 {
            wal.append(0, i, vec![1]).unwrap();
        }
        let (appends, fsyncs, unsynced) = wal.sync_counters();
        assert_eq!(appends, 5);
        assert_eq!(fsyncs, 2, "cadence 2 fsyncs on appends 2 and 4");
        assert_eq!(unsynced, 1, "one append since the last fsync");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn append_survives_reopen() {
        let dir = tmp_dir();
        {
            let wal = WalLog::open(&dir, 2).unwrap();
            assert_eq!(wal.append(0, 10, b"a".to_vec()).unwrap(), 0);
            assert_eq!(wal.append(0, 11, b"bb".to_vec()).unwrap(), 1);
            assert_eq!(wal.append(1, 12, b"c".to_vec()).unwrap(), 0);
        }
        let wal = WalLog::open(&dir, 2).unwrap();
        assert_eq!(wal.latest_offset(0).unwrap(), 2);
        assert_eq!(wal.earliest_offset(0).unwrap(), 0);
        let recs = wal.fetch(0, 0, 10, Duration::ZERO).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(*recs[0].payload, b"a".to_vec());
        assert_eq!(recs[1].ts_ms, 11);
        assert_eq!(wal.fetch(1, 0, 10, Duration::ZERO).unwrap().len(), 1);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let dir = tmp_dir();
        {
            let wal = WalLog::open(&dir, 1).unwrap();
            wal.append(0, 1, b"keep".to_vec()).unwrap();
            wal.append(0, 2, b"torn".to_vec()).unwrap();
        }
        // Chop bytes off the end: the last frame becomes unreadable.
        let path = dir.join("p0.wal");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let wal = WalLog::open(&dir, 1).unwrap();
        assert_eq!(wal.latest_offset(0).unwrap(), 1);
        let recs = wal.fetch(0, 0, 10, Duration::ZERO).unwrap();
        assert_eq!(*recs[0].payload, b"keep".to_vec());
        // And appends continue on a clean frame boundary.
        wal.append(0, 3, b"next".to_vec()).unwrap();
        drop(wal);
        let wal = WalLog::open(&dir, 1).unwrap();
        assert_eq!(wal.latest_offset(0).unwrap(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fsync_cadence_keeps_log_readable() {
        // Functional coverage of the `wal_sync_every` knob: syncing every
        // other append changes durability, never contents or offsets.
        let dir = tmp_dir();
        {
            let wal = WalLog::open_with(&dir, 1, 2).unwrap();
            for i in 0..5u64 {
                assert_eq!(wal.append(0, i, vec![i as u8]).unwrap(), i);
            }
        }
        let wal = WalLog::open(&dir, 1).unwrap();
        assert_eq!(wal.latest_offset(0).unwrap(), 5);
        let recs = wal.fetch(0, 0, 10, Duration::ZERO).unwrap();
        assert_eq!(recs.len(), 5);
        assert_eq!(*recs[3].payload, vec![3u8]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corrupt_header_errors_cleanly() {
        let dir = tmp_dir();
        {
            WalLog::open(&dir, 1).unwrap();
        }
        let path = dir.join("p0.wal");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[9] ^= 0xFF; // flip a magic/header byte inside the frame
        std::fs::write(&path, bytes).unwrap();
        assert!(WalLog::open(&dir, 1).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn trim_preserves_offsets_across_reopen() {
        let dir = tmp_dir();
        {
            let wal = WalLog::open(&dir, 1).unwrap();
            for i in 0..10u64 {
                wal.append(0, i, vec![i as u8]).unwrap();
            }
            wal.trim_until(0, 7).unwrap();
            assert_eq!(wal.earliest_offset(0).unwrap(), 7);
            assert_eq!(wal.latest_offset(0).unwrap(), 10);
            assert!(wal.fetch(0, 3, 10, Duration::ZERO).is_err());
            let recs = wal.fetch(0, 7, 10, Duration::ZERO).unwrap();
            assert_eq!(recs.len(), 3);
            assert_eq!(recs[0].offset, 7);
            // Trimming to an already-trimmed or future offset is clamped.
            wal.trim_until(0, 2).unwrap();
            assert_eq!(wal.earliest_offset(0).unwrap(), 7);
        }
        let wal = WalLog::open(&dir, 1).unwrap();
        assert_eq!(wal.earliest_offset(0).unwrap(), 7);
        assert_eq!(wal.latest_offset(0).unwrap(), 10);
        assert_eq!(*wal.fetch(0, 9, 1, Duration::ZERO).unwrap()[0].payload, vec![9u8]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn synclog_surface_and_bad_partition() {
        let dir = tmp_dir();
        let wal = WalLog::open(&dir, 2).unwrap();
        let log: &dyn SyncLog = &wal;
        assert_eq!(log.partition_count(), 2);
        assert!(log.append(9, 0, vec![]).is_err());
        assert!(log.fetch(9, 0, 1, Duration::ZERO).is_err());
        assert!(log.latest_offset(9).is_err());
        // Fetch at log end returns empty, not an error (poll semantics).
        assert!(log.fetch(0, 0, 10, Duration::ZERO).unwrap().is_empty());
        std::fs::remove_dir_all(dir).ok();
    }
}
