//! Crate-wide error type (hand-implemented `Display`/`Error`; no derive
//! crates in the offline build environment).

use std::fmt;

/// Errors produced by WeiPS subsystems.
#[derive(Debug)]
pub enum Error {
    /// Wire / checkpoint decoding failed.
    Codec(String),
    /// I/O error (sockets, checkpoint files, queue segments).
    Io(std::io::Error),
    /// RPC-level failure (timeout, connection reset, remote fault).
    Rpc(String),
    /// Request routed to a shard/partition that does not exist.
    Routing(String),
    /// Queue consumer asked for an offset outside the retained range.
    OffsetOutOfRange(String),
    /// Metadata store conflict (CAS failure / stale version).
    MetaConflict(String),
    /// Checkpoint missing or corrupt.
    Checkpoint(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Configuration file invalid.
    Config(String),
    /// Node is not in a state where the operation is legal.
    State(String),
    /// Referenced model/version/table is unknown.
    NotFound(String),
    /// Service deliberately rejecting load (backpressure / degraded).
    Unavailable(String),
    /// Push routed with a stale slot-map epoch (the slot moved shards or
    /// is sealed for a live migration hand-off). Never a data loss: the
    /// server rejects *before* applying anything, and clients re-split by
    /// the current slot map and retry.
    StaleRoute(String),
    /// QoS admission control shed this request: the request's class is at
    /// its in-flight cap and the server chose to reject rather than queue.
    /// Rejected *before* any state change; bulk callers back off and
    /// retry, predict callers fail over to a replica.
    Overloaded(String),
}

impl Error {
    /// True for routing-epoch rejections, which callers retry with a
    /// refreshed slot map instead of surfacing. Typed end to end: the RPC
    /// layer carries a dedicated status byte so remote callers see
    /// [`Error::StaleRoute`] too, not a stringly [`Error::Rpc`].
    pub fn is_stale_route(&self) -> bool {
        matches!(self, Error::StaleRoute(_))
    }

    /// True for QoS admission-control sheds. Typed end to end like
    /// [`Error::StaleRoute`]: the RPC layer carries a dedicated status
    /// byte so remote callers can distinguish "server is shedding my
    /// class" (back off / fail over) from a real fault.
    pub fn is_overloaded(&self) -> bool {
        matches!(self, Error::Overloaded(_))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Rpc(m) => write!(f, "rpc error: {m}"),
            Error::Routing(m) => write!(f, "routing error: {m}"),
            Error::OffsetOutOfRange(m) => write!(f, "offset out of range: {m}"),
            Error::MetaConflict(m) => write!(f, "meta conflict: {m}"),
            Error::Checkpoint(m) => write!(f, "checkpoint error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::State(m) => write!(f, "illegal state: {m}"),
            Error::NotFound(m) => write!(f, "not found: {m}"),
            Error::Unavailable(m) => write!(f, "unavailable: {m}"),
            Error::StaleRoute(m) => write!(f, "stale route: {m}"),
            Error::Overloaded(m) => write!(f, "overloaded: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Routing("shard 7 of 4".into());
        assert!(e.to_string().contains("shard 7 of 4"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::Other, "boom");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
