//! Crate-wide error type.

/// Errors produced by WeiPS subsystems.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Wire / checkpoint decoding failed.
    #[error("codec error: {0}")]
    Codec(String),
    /// I/O error (sockets, checkpoint files, queue segments).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// RPC-level failure (timeout, connection reset, remote fault).
    #[error("rpc error: {0}")]
    Rpc(String),
    /// Request routed to a shard/partition that does not exist.
    #[error("routing error: {0}")]
    Routing(String),
    /// Queue consumer asked for an offset outside the retained range.
    #[error("offset out of range: {0}")]
    OffsetOutOfRange(String),
    /// Metadata store conflict (CAS failure / stale version).
    #[error("meta conflict: {0}")]
    MetaConflict(String),
    /// Checkpoint missing or corrupt.
    #[error("checkpoint error: {0}")]
    Checkpoint(String),
    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// Configuration file invalid.
    #[error("config error: {0}")]
    Config(String),
    /// Node is not in a state where the operation is legal.
    #[error("illegal state: {0}")]
    State(String),
    /// Referenced model/version/table is unknown.
    #[error("not found: {0}")]
    NotFound(String),
    /// Service deliberately rejecting load (backpressure / degraded).
    #[error("unavailable: {0}")]
    Unavailable(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Routing("shard 7 of 4".into());
        assert!(e.to_string().contains("shard 7 of 4"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::Other, "boom");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
